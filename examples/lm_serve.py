"""Serve a small LM with batched requests through the continuous-batching
engine — using a reduced variant of an assigned architecture with a
CCE-compressed vocabulary table and the factored logits head.

Run:  PYTHONPATH=src python examples/lm_serve.py [--arch qwen2-1.5b]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    print(f"serving reduced {args.arch}: {cfg.n_layers}L d={cfg.d_model} "
          f"emb={cfg.emb_method} (factored logits head)")
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, buffers, max_batch=args.max_batch,
                         max_seq=64)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 12)).astype(np.int32),
            max_tokens=int(rng.integers(4, 10)),
        ))
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({engine.ticks} decode ticks, continuous batching over "
          f"{args.max_batch} slots)")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: {len(r.prompt)}-token prompt -> {r.generated}")


if __name__ == "__main__":
    main()
