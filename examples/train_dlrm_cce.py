"""End-to-end driver: train DLRM with CCE-compressed tables on the
synthetic Criteo-like clickstream for a few hundred steps, with
checkpointing, sketch-based frequency tracking (count-min + heavy
hitters at vocab-independent memory, cell counting fused INTO the
donated train step — zero extra dispatches), ENTROPY/DRIFT-TRIGGERED
clustering (the adaptive form of the paper's interleaved recipe — a
periodic fallback schedule stays on), an injected failure, and
restart-exact recovery.  Every trigger evaluation is logged (entropy,
drift, fired-or-not) so the adaptive schedule is observable, and the
quickstart opens by measuring the launch-fusion win (per-feature loop vs
ONE unified supertable launch, DESIGN.md §6).

Run:  PYTHONPATH=src python examples/train_dlrm_cce.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.obs import RunLog, TelemetryConfig
from repro.obs.runlog import default_manifest
from repro.optim import sgd
from repro.stream import ClusterTrigger, make_step_cell_counter
from repro.train.loop import (
    FailureInjector, Trainer, init_state, make_train_step, merge_buffers,
    split_buffers,
)


def _time_steps(step_fn, state, batch, n=8):
    s, _ = step_fn(state, batch)  # compile
    jax.block_until_ready(s.params)
    t0 = time.perf_counter()
    for _ in range(n):
        s, _ = step_fn(s, batch)
    jax.block_until_ready(s.params)
    return (time.perf_counter() - t0) / n * 1e3  # ms/step


def show_fusion_win(cfg, args):
    """Launches/step and step latency, per-feature loop vs the unified
    single-launch collection (both on the jnp lookup path so the numbers
    mean something on CPU; on TPU the unified path is the Pallas kernel)."""
    batch0 = next(clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0), args.batch))
    batch0 = {k: np.asarray(v)[None] for k, v in batch0.items() if k != "step"}
    opt = sgd(momentum=0.9)
    stats = {}
    for label, mode in (("per-feature loop", "loop"), ("unified", "univ")):
        c = dataclasses.replace(cfg, emb_fuse=mode, emb_use_kernel=False)
        p, b = dlrm.init(jax.random.PRNGKey(0), c)
        dyn, static = split_buffers(b)

        def loss_fn(pp, bb, mb, _c=c):
            return dlrm.bce_loss(pp, bb, _c, mb), {}

        step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05),
                               static, donate=True)
        ms = _time_steps(step, init_state(p, opt, dyn), batch0)
        stats[label] = (c.collection.n_lookup_launches, ms)
        print(f"  {label:17s}: {c.collection.n_lookup_launches:2d} heavy "
              f"lookup launches/step, {ms:6.1f} ms/step")
    speedup = stats["per-feature loop"][1] / stats["unified"][1]
    print(f"  -> ONE fused launch, {speedup:.1f}x faster step\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cap", type=int, default=512)
    # --obs RUN.jsonl: in-step telemetry + structured run log (the SAME
    # log survives the injected crash below — resume-replayed events
    # dedupe, so the log reads as one contiguous run)
    ap.add_argument("--obs", default=None, metavar="RUN.jsonl")
    args = ap.parse_args()

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=args.cap)
    print(f"DLRM with CCE tables: {cfg.n_emb_params()} embedding params "
          f"({cfg.compression():.1f}x compression)")
    print("launch fusion (before/after):")
    show_fusion_win(cfg, args)

    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0)

    # sketch-backed tracking (only the CCE features carry sketches),
    # windowed for the adaptive trigger; the cell counter is embedded in
    # the donated train step below, so tracking costs ZERO extra device
    # dispatches (the async fold only does host head/ring bookkeeping)
    tracker = dlrm.make_id_tracker(
        cfg, dlrm_criteo.reduced_stream(window=max(4, args.steps // 20),
                                        async_fold=True),
    )
    telemetry = TelemetryConfig() if args.obs else None
    runlog = (
        RunLog(args.obs, manifest=default_manifest("dlrm_criteo_reduced"))
        if args.obs else None
    )
    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static,
                           sketch_fn=make_step_cell_counter(tracker),
                           telemetry=telemetry, donate=True)
    state = init_state(params, opt, dyn)
    trigger = ClusterTrigger(entropy_drop=0.1, drift_threshold=0.25, warmup=2)
    print(f"sketch tracker: {tracker.nbytes / 1e3:.0f} kB for vocabs "
          f"{cfg.vocab_sizes} (dense histograms would be "
          f"{sum(cfg.vocab_sizes) * 8 / 1e3:.0f} kB); cell counting rides "
          f"the train step's single launch")

    def cluster_fn(key, p, b, opt_state):
        return dlrm.cluster_tables(key, p, b, cfg, opt_state,
                                   id_counts=tracker.counts)

    ckpt_dir = tempfile.mkdtemp(prefix="dlrm_cce_")
    ckpt_every = max(10, args.steps // 6)
    fail_step = 2 * args.steps // 3  # crashes after >=1 checkpoint exists
    trainer = Trainer(
        step, state, static,
        clickstream_batches(data_cfg, args.batch),
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        cluster_fn=cluster_fn, cluster_every=args.steps // 4, cluster_max=3,
        id_tracker=tracker, trigger=trigger,
        failures=FailureInjector((fail_step,)),
        migrations=dlrm.checkpoint_migrations(cfg),
        runlog=runlog,
    )

    try:
        trainer.run(args.steps)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint")
        restored = trainer.restore_latest()
        print(f"   resumed at step {restored}")
        trainer.failures = None
        trainer.data_iter = clickstream_batches(
            data_cfg, args.batch, start_step=restored)
        trainer.run(args.steps - restored)

    print("trigger log (one line per closed window):")
    for ev in trigger.events:
        mark = f"FIRED ({ev.reason})" if ev.fire else "held"
        print(f"  step {ev.step:4d}  entropy {ev.entropy:6.3f}  "
              f"drift {ev.drift:5.3f}  {mark}")

    losses = [h["loss"] for h in trainer.history]
    test = next(clickstream_batches(data_cfg, 2048, host_id=1, n_hosts=2))
    buffers = merge_buffers(trainer.state.ebuf, trainer.static_buffers)
    bce = float(dlrm.bce_loss(trainer.state.params, buffers, cfg, test))
    print(f"train loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}; "
          f"test BCE {bce:.4f}; clusterings {trainer.clusters_done} "
          f"({trigger.fired} trigger-fired); "
          f"stragglers flagged {len(trainer.monitor.flagged)}; "
          f"steady-state step {trainer.monitor.mean * 1e3:.1f} ms "
          f"({cfg.collection.n_lookup_launches} heavy lookup launch/step, "
          f"sketch delta in-step)")
    if runlog is not None:
        runlog.close()
        print(f"run log: {args.obs}  "
              f"(summarize: python -m repro.obs summarize {args.obs})")


if __name__ == "__main__":
    main()
