"""End-to-end driver: train DLRM with CCE-compressed tables on the
synthetic Criteo-like clickstream for a few hundred steps, with
checkpointing, sketch-based frequency tracking (count-min + heavy
hitters at vocab-independent memory, device-side async updates),
ENTROPY/DRIFT-TRIGGERED clustering (the adaptive form of the paper's
interleaved recipe — a periodic fallback schedule stays on), an injected
failure, and restart-exact recovery.  Every trigger evaluation is logged
(entropy, drift, fired-or-not) so the adaptive schedule is observable.

Run:  PYTHONPATH=src python examples/train_dlrm_cce.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.stream import ClusterTrigger
from repro.train.loop import (
    FailureInjector, Trainer, init_state, make_train_step, merge_buffers,
    split_buffers,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cap", type=int, default=512)
    args = ap.parse_args()

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=args.cap)
    print(f"DLRM with CCE tables: {cfg.n_emb_params()} embedding params "
          f"({cfg.compression():.1f}x compression)")
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0)

    # sketch-backed tracking (only the CCE features carry sketches) with
    # async device-side updates, windowed for the adaptive trigger
    tracker = dlrm.make_id_tracker(
        cfg, dlrm_criteo.reduced_stream(window=max(4, args.steps // 20),
                                        async_fold=True),
    )
    trigger = ClusterTrigger(entropy_drop=0.1, drift_threshold=0.25, warmup=2)
    print(f"sketch tracker: {tracker.nbytes / 1e3:.0f} kB for vocabs "
          f"{cfg.vocab_sizes} (dense histograms would be "
          f"{sum(cfg.vocab_sizes) * 8 / 1e3:.0f} kB)")

    def cluster_fn(key, p, b, opt_state):
        return dlrm.cluster_tables(key, p, b, cfg, opt_state,
                                   id_counts=tracker.counts)

    ckpt_dir = tempfile.mkdtemp(prefix="dlrm_cce_")
    ckpt_every = max(10, args.steps // 6)
    fail_step = 2 * args.steps // 3  # crashes after >=1 checkpoint exists
    trainer = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static,
        clickstream_batches(data_cfg, args.batch),
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        cluster_fn=cluster_fn, cluster_every=args.steps // 4, cluster_max=3,
        id_tracker=tracker, trigger=trigger,
        failures=FailureInjector((fail_step,)),
        migrations=dlrm.checkpoint_migrations(cfg),
    )

    try:
        trainer.run(args.steps)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint")
        restored = trainer.restore_latest()
        print(f"   resumed at step {restored}")
        trainer.failures = None
        trainer.data_iter = clickstream_batches(
            data_cfg, args.batch, start_step=restored)
        trainer.run(args.steps - restored)

    print("trigger log (one line per closed window):")
    for ev in trigger.events:
        mark = f"FIRED ({ev.reason})" if ev.fire else "held"
        print(f"  step {ev.step:4d}  entropy {ev.entropy:6.3f}  "
              f"drift {ev.drift:5.3f}  {mark}")

    losses = [h["loss"] for h in trainer.history]
    test = next(clickstream_batches(data_cfg, 2048, host_id=1, n_hosts=2))
    buffers = merge_buffers(trainer.state.ebuf, trainer.static_buffers)
    bce = float(dlrm.bce_loss(trainer.state.params, buffers, cfg, test))
    print(f"train loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}; "
          f"test BCE {bce:.4f}; clusterings {trainer.clusters_done} "
          f"({trigger.fired} trigger-fired); "
          f"stragglers flagged {len(trainer.monitor.flagged)}")


if __name__ == "__main__":
    main()
