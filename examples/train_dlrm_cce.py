"""End-to-end driver: train DLRM with CCE-compressed tables on the
synthetic Criteo-like clickstream for a few hundred steps, with
checkpointing, clustering interleaved (the paper's training recipe), an
injected failure, and restart-exact recovery.

Run:  PYTHONPATH=src python examples/train_dlrm_cce.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.train.freq import IdFrequencyTracker
from repro.train.loop import (
    FailureInjector, Trainer, init_state, make_train_step, merge_buffers,
    split_buffers,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--cap", type=int, default=512)
    args = ap.parse_args()

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=args.cap)
    print(f"DLRM with CCE tables: {cfg.n_emb_params()} embedding params "
          f"({cfg.compression():.1f}x compression)")
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0)

    tracker = IdFrequencyTracker(cfg.vocab_sizes)

    def cluster_fn(key, p, b, opt_state):
        return dlrm.cluster_tables(key, p, b, cfg, opt_state,
                                   id_counts=tracker.counts)

    ckpt_dir = tempfile.mkdtemp(prefix="dlrm_cce_")
    ckpt_every = max(10, args.steps // 6)
    fail_step = 2 * args.steps // 3  # crashes after >=1 checkpoint exists
    trainer = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static,
        clickstream_batches(data_cfg, args.batch),
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        cluster_fn=cluster_fn, cluster_every=args.steps // 4, cluster_max=3,
        id_tracker=tracker, failures=FailureInjector((fail_step,)),
        migrations=dlrm.checkpoint_migrations(cfg),
    )

    try:
        trainer.run(args.steps)
    except RuntimeError as e:
        print(f"!! {e} — restoring from checkpoint")
        restored = trainer.restore_latest()
        print(f"   resumed at step {restored}")
        trainer.failures = None
        trainer.data_iter = clickstream_batches(
            data_cfg, args.batch, start_step=restored)
        trainer.run(args.steps - restored)

    losses = [h["loss"] for h in trainer.history]
    test = next(clickstream_batches(data_cfg, 2048, host_id=1, n_hosts=2))
    buffers = merge_buffers(trainer.state.ebuf, trainer.static_buffers)
    bce = float(dlrm.bce_loss(trainer.state.params, buffers, cfg, test))
    print(f"train loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}; "
          f"test BCE {bce:.4f}; clusterings {trainer.clusters_done}; "
          f"stragglers flagged {len(trainer.monitor.flagged)}")


if __name__ == "__main__":
    main()
