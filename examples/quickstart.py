"""Quickstart: the paper's algorithm in 60 lines.

Builds a CCE embedding table, trains it inside a toy model, runs the
clustering transition mid-training (Algorithm 3), and shows the collapse
diagnostics.  Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.cce import CCE

VOCAB, DIM, BUDGET = 10_000, 32, 16_384

# 1. A CCE table under a parameter budget (vs 320k params for a full table)
table = CCE.from_budget(VOCAB, DIM, BUDGET, c=4)
print(f"CCE table: k={table.k} rows x {table.c} columns, "
      f"{table.n_params} params = {VOCAB * DIM / table.n_params:.0f}x compression")

key = jax.random.PRNGKey(0)
params, buffers = table.init(key)

# 2. Toy task: ids in the same latent group share a target vector
groups = jax.random.randint(key, (VOCAB,), 0, 64)
targets = jax.random.normal(jax.random.fold_in(key, 1), (64, DIM))


def loss_fn(params, ids):
    emb = table.lookup(params, buffers, ids)
    return jnp.mean((emb - targets[groups[ids]]) ** 2)


@jax.jit
def step(params, ids):
    loss, g = jax.value_and_grad(loss_fn)(params, ids)
    return jax.tree.map(lambda p, g: p - 0.3 * g, params, g), loss


def train(params, buffers, steps):
    for i in range(steps):
        ids = jax.random.randint(jax.random.fold_in(key, 100 + i), (512,), 0, VOCAB)
        params, loss = step(params, ids)
    return params, float(loss)


# 3. Train -> cluster (Algorithm 3) -> train
params, l0 = train(params, buffers, 150)
print(f"before clustering: loss={l0:.4f}  "
      f"entropies={table.collapse_entropies(buffers)}")

params, buffers = table.cluster(jax.random.fold_in(key, 7), params, buffers)
step = jax.jit(step)  # pointer buffers changed -> re-jit against new closure

params, l1 = train(params, buffers, 150)
print(f"after  clustering: loss={l1:.4f}  "
      f"entropies={table.collapse_entropies(buffers)}")
assert l1 < l0, "clustering should help on clusterable data"
print("OK: the clustering transition improved the fit (the paper's claim).")
