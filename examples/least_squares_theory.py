"""Theorem 3.1 live: dense & sparse CCE for least squares vs the proven
bound and the exact optimum (Figure 1b / Figure 8 of the paper).

Run:  PYTHONPATH=src python examples/least_squares_theory.py
"""
import jax
import numpy as np

from repro.core import least_squares as ls

key = jax.random.PRNGKey(0)
n, d1, d2, k, iters = 1500, 300, 10, 30, 20
X = jax.random.normal(key, (n, d1))
Y = jax.random.normal(jax.random.fold_in(key, 1), (n, d2))

opt, T_star = ls.optimal_loss(X, Y)
bound = np.asarray(ls.theorem_bound(X, Y, k, iters))
dense = ls.dense_cce(jax.random.fold_in(key, 2), X, Y, k, iters)
smart = ls.dense_cce(jax.random.fold_in(key, 2), X, Y, k, iters, smart_noise=True)
sparse = ls.sparse_cce(jax.random.fold_in(key, 3), X, Y, k, iters)

print(f"optimal loss: {float(opt):.1f}   (memory for exact solve: "
      f"{d1 * d2} floats; CCE iterate: {k * d2} floats = {d1 / k:.0f}x less)")
print(f"{'iter':>4} {'thm bound':>12} {'dense CCE':>12} {'smart noise':>12} {'sparse CCE':>12}")
for i in range(0, iters + 1, 2):
    print(f"{i:>4} {bound[i]:>12.1f} {float(dense.losses[i]):>12.1f} "
          f"{float(smart.losses[i]):>12.1f} {float(sparse.losses[i]):>12.1f}")

assert float(dense.losses[-1]) < 1.1 * float(opt)
print("\nOK: dense CCE reached the optimum within 10%; the bound held; "
      "smart (SVD-aligned) noise converged fastest (Appendix B).")
