"""Figure 4a/4b: test BCE vs parameter budget, per compression method.

CPU-scale faithful analogue: synthetic Criteo-like clickstream with planted
cluster structure, DLRM backbone, SGD, a sweep of embedding-parameter caps,
and (4a) multi-epoch training with CCE clustering interleaved vs (4b) a
single-pass budget.  Reports test BCE per (method, budget).

Emits CSV rows: method,budget,test_bce.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.train.loop import (
    Trainer, init_state, make_train_step, merge_buffers, split_buffers,
)

METHODS = ("full", "hash", "ce", "cce")
# budgets chosen so CCE's k spans the planted concept count (n_latent=32):
# below k ~= n_latent clustering cannot separate the latent groups and the
# paper's regime doesn't apply (cap 1024 -> k=32 per column)
BUDGETS = (256, 1024, 4096)


def train_one(method: str, cap: int, *, steps: int = 150, seed: int = 0,
              cluster_every: int = 40, batch: int = 64):
    cfg = dlrm_criteo.reduced(emb_method=method, cap=cap)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed)

    cluster_fn = None
    if method == "cce" and cluster_every:
        def cluster_fn(key, p, b):
            return dlrm.cluster_tables(key, p, b, cfg)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static,
                 clickstream_batches(data_cfg, batch),
                 cluster_fn=cluster_fn, cluster_every=cluster_every,
                 cluster_max=3, seed=seed)
    tr.run(steps)
    test = next(clickstream_batches(data_cfg, 1024, host_id=1, n_hosts=2))
    buffers = merge_buffers(tr.state.ebuf, tr.static_buffers)
    return float(dlrm.bce_loss(tr.state.params, buffers, cfg, test)), cfg


def main(out=print, steps: int = 150, seeds=(0,)):
    out("method,budget,n_emb_params,test_bce")
    results = {}
    for method in METHODS:
        budgets = (0,) if method == "full" else BUDGETS
        for cap in budgets:
            bces = []
            for s in seeds:
                bce, cfg = train_one(method, cap, steps=steps, seed=s)
                bces.append(bce)
            results[(method, cap)] = float(np.mean(bces))
            out(f"{method},{cap},{cfg.n_emb_params()},{np.mean(bces):.5f}")
    return results


if __name__ == "__main__":
    main()
