"""Table 1: Embedding-compression factor to reach baseline BCE.

For each method, sweep parameter caps and find the smallest budget whose
test BCE <= the full-table baseline's BCE (linear interpolation between
sampled budgets, like the paper's extrapolation when no budget reaches it).
Compression is measured both ways the paper reports it: over the summed
vocabularies and over the largest table (§Reproducibility discusses the
discrepancy between the two).

Emits CSV rows: method,budget_needed,compression_sum,compression_largest.
"""
import numpy as np

from benchmarks.bench_fig4 import train_one
from repro.configs import dlrm_criteo

METHODS = ("hash", "ce", "cce")
BUDGETS = (256, 1024, 4096)


def budget_to_reach(baseline_bce, budgets, bces):
    """Smallest (interpolated) budget with bce <= baseline."""
    for i, (b, v) in enumerate(zip(budgets, bces)):
        if v <= baseline_bce:
            if i == 0:
                return b
            b0, v0 = budgets[i - 1], bces[i - 1]
            t = (v0 - baseline_bce) / max(v0 - v, 1e-9)
            return b0 + t * (b - b0)
    # extrapolate linearly from the last two points (the paper's optimistic
    # bound); cap at 32x the largest tested budget
    if len(bces) >= 2 and bces[-2] > bces[-1]:
        slope = (bces[-1] - bces[-2]) / (budgets[-1] - budgets[-2])
        need = budgets[-1] + (baseline_bce - bces[-1]) / slope
        return min(max(need, budgets[-1]), 32 * budgets[-1])
    return float("inf")


def main(out=print, steps: int = 150, seeds=(0,)):
    cfg0 = dlrm_criteo.reduced()
    base_bces = [train_one("full", 0, steps=steps, seed=s)[0] for s in seeds]
    baseline = float(np.mean(base_bces))
    out(f"# full-table baseline BCE: {baseline:.5f}")
    out("method,budget_needed,compression_sum,compression_largest")
    results = {}
    vocab_total = sum(v * cfg0.emb_dim for v in cfg0.vocab_sizes)
    vmax = max(cfg0.vocab_sizes) * cfg0.emb_dim
    for method in METHODS:
        bces = [float(np.mean([train_one(method, b, steps=steps, seed=s)[0]
                               for s in seeds])) for b in BUDGETS]
        need = budget_to_reach(baseline, BUDGETS, bces)
        if np.isinf(need):
            out(f"{method},never,-,-")
            results[method] = None
            continue
        cfg = dlrm_criteo.reduced(emb_method=method, cap=int(need))
        comp_sum = vocab_total / max(1, cfg.n_emb_params())
        comp_big = vmax / max(1, min(int(need), vmax))
        results[method] = (need, comp_sum, comp_big)
        out(f"{method},{need:.0f},{comp_sum:.1f},{comp_big:.1f}")
    return results


if __name__ == "__main__":
    main()
