"""Appendix F: clustering schedules (ct = number of clusterings, cf =
steps between clusterings).  The paper's findings to reproduce in
miniature: more clusterings help; the model needs 'rest' after the last
clustering (schedules that cluster too late do worse).

Emits CSV rows: ct,cf,test_bce.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.train.loop import (
    Trainer, init_state, make_train_step, merge_buffers, split_buffers,
)

SCHEDULES = (  # (ct, cf) at 200 training steps
    (0, 0),
    (1, 60),
    (2, 40),
    (3, 40),
    (3, 60),  # late clustering: little rest before the end
)


def run_schedule(ct, cf, *, steps=200, seed=0):
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=1024)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed)

    def cluster_fn(key, p, b):
        return dlrm.cluster_tables(key, p, b, cfg)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static,
                 clickstream_batches(data_cfg, 64),
                 cluster_fn=cluster_fn if ct else None,
                 cluster_every=cf, cluster_max=ct, seed=seed)
    tr.run(steps)
    test = next(clickstream_batches(data_cfg, 1024, host_id=1, n_hosts=2))
    buffers = merge_buffers(tr.state.ebuf, tr.static_buffers)
    return float(dlrm.bce_loss(tr.state.params, buffers, cfg, test))


def main(out=print, steps=200, seeds=(0,)):
    out("ct,cf,test_bce")
    results = {}
    for ct, cf in SCHEDULES:
        bce = float(np.mean([run_schedule(ct, cf, steps=steps, seed=s)
                             for s in seeds]))
        results[(ct, cf)] = bce
        out(f"{ct},{cf},{bce:.5f}")
    return results


if __name__ == "__main__":
    main()
