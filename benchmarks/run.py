"""Run every benchmark; print consolidated CSV.  One section per paper
table/figure + the kernel microbench.  ``--fast`` trims training steps so
the suite finishes in a few minutes on 1 CPU core (CI mode — the numbers
stay directionally meaningful; full mode for the committed results).
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    steps = 60 if args.fast else 150

    from benchmarks import (
        bench_fig4,
        bench_kernels,
        bench_least_squares,
        bench_schedules,
        bench_table1,
    )

    seeds = (0,) if args.fast else (0, 1)
    sections = {
        "least_squares (Fig 1b/6/8, Thm 3.1)": lambda: bench_least_squares.main(),
        "fig4 (BCE vs budget per method)": lambda: bench_fig4.main(
            steps=steps, seeds=seeds),
        "table1 (compression to baseline)": lambda: bench_table1.main(
            steps=steps, seeds=seeds),
        "schedules (Appendix F)": lambda: bench_schedules.main(
            steps=max(120, steps)),
        "kernels (microbench)": lambda: bench_kernels.main(),
    }
    for name, fn in sections.items():
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the suite going, report at the end
            print(f"SECTION FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"# section time: {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
