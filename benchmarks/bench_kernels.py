"""Kernel microbenchmarks: the fused CCE lookup and kmeans-assign kernels
vs their pure-jnp references (CPU interpret mode — wall times here are NOT
TPU times; the structural claim is identical results + the blocked
structure; the roofline for the kernels is derived analytically below).

Emits CSV rows: name,us_per_call,bytes_model,flops_model.

``--collection`` (or a plain ``python benchmarks/bench_kernels.py`` run)
additionally benches the EmbeddingCollection refactor end-to-end: a
26-feature DLRM embedding step, legacy per-feature loop vs grouped
supertables, launches-per-step counted, results written to
``BENCH_collection.json`` (uploaded as a CI artifact).
"""
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def timeit(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(out=print):
    key = jax.random.PRNGKey(0)
    rows = []

    # CCE lookup at a DLRM-ish shape
    c, B, T, k, dsub = 4, 4096, 2, 2048, 16
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)
    t_ref = timeit(jax.jit(ref.cce_lookup_ref), idx, tables)
    t_ker = timeit(jax.jit(ops.cce_lookup), idx, tables)
    # TPU-model traffic: tables tiles (c*T*k*dsub) + out (B*c*dsub), f32
    bytes_model = 4 * (c * T * k * dsub + B * c * dsub + c * B * T)
    flops_model = 2 * c * T * B * dsub  # gather-as-matmul useful adds
    rows.append(("cce_lookup_ref", t_ref, bytes_model, flops_model))
    rows.append(("cce_lookup_kernel_interp", t_ker, bytes_model, flops_model))

    # kmeans assign at clustering scale
    n, kc, d = 4096, 512, 16
    x = jax.random.normal(key, (n, d), jnp.float32)
    cen = jax.random.normal(jax.random.fold_in(key, 1), (kc, d), jnp.float32)
    t_ref = timeit(jax.jit(ref.kmeans_assign_ref), x, cen)
    t_ker = timeit(jax.jit(ops.kmeans_assign), x, cen)
    bytes_model = 4 * (n * d + kc * d + n)
    flops_model = 2 * n * kc * d
    rows.append(("kmeans_assign_ref", t_ref, bytes_model, flops_model))
    rows.append(("kmeans_assign_kernel_interp", t_ker, bytes_model, flops_model))

    # the transition's full-vocab assignment pass (CCE.assign_all): one
    # chunked materialization, per-column assign via the jnp path vs the
    # Pallas kernel route
    from repro.core.cce import CCE

    cce = CCE(d1=8192, d2=64, k=256, c=4)
    cparams, cbuffers = cce.init(key)
    cents = jax.random.normal(
        jax.random.fold_in(key, 2), (cce.c, cce.k, cce.dsub), jnp.float32
    )
    t_jnp = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=False)),
        cparams, cbuffers, cents,
    )
    t_ker = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=True)),
        cparams, cbuffers, cents,
    )
    bytes_model = 4 * (cce.c * cce.d1 * cce.dsub + cce.c * cce.k * cce.dsub
                       + cce.c * cce.d1)
    flops_model = 2 * cce.c * cce.d1 * cce.k * cce.dsub
    rows.append(("cce_assign_all_jnp", t_jnp, bytes_model, flops_model))
    rows.append(("cce_assign_all_kernel_interp", t_ker, bytes_model, flops_model))

    out("name,us_per_call,bytes_model,flops_model")
    for r in rows:
        out(f"{r[0]},{r[1]:.0f},{r[2]},{r[3]}")
    return rows


def bench_collection(out=print, json_path="BENCH_collection.json",
                     batch=256, reps=3):
    """Looped vs fused DLRM embedding step (the PR's structural claim).

    A 26-feature DLRM at Criteo-shaped (CI-capped) vocabs; measures the
    embedding forward+backward and the full DLRM loss step under (a) the
    legacy per-feature lookup loop and (b) the grouped collection —
    fused-jnp and fused-kernel variants — and counts heavy lookup
    launches per step (n_features -> n_groups).  On CPU the kernel runs
    in interpret mode, so its WALL TIME is not meaningful off-TPU; the
    launch counts and the looped-vs-fused-jnp times are.
    """
    import dataclasses

    import numpy as np

    from repro.configs import dlrm_criteo
    from repro.models import dlrm
    from repro.models.dlrm import DLRMConfig

    vocabs = tuple(min(v, 20_000) for v in dlrm_criteo.CRITEO_KAGGLE_VOCABS)
    cfg = DLRMConfig(
        vocab_sizes=vocabs, n_dense=13, emb_dim=16,
        bottom_mlp=(64, 32, 16), top_mlp=(64, 1),
        emb_method="cce", emb_param_cap=2048,
    )
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch_tree = {
        "dense": jnp.asarray(rng.normal(size=(batch, 13)), jnp.float32),
        "sparse": jnp.asarray(
            np.stack([rng.integers(0, v, batch) for v in vocabs], axis=1),
            jnp.int32,
        ),
        "label": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }
    sparse = batch_tree["sparse"]
    co = jax.random.normal(jax.random.PRNGKey(1), (batch, cfg.n_sparse, 16))
    per_p = jax.tree.map(jnp.asarray, coll.unstack_params(params["emb"]))
    per_b = coll.unstack_buffers(buffers["emb"])

    def emb_looped(pp):  # the pre-collection hot loop: 26 lookups
        outv = jnp.stack(
            [
                coll.tables[i].lookup(pp[i], per_b[i], sparse[:, i])
                for i in range(coll.n_features)
            ],
            axis=1,
        )
        return jnp.sum(outv * co)

    def emb_fused(ep, use_kernel):
        outv = coll.lookup_all(ep, buffers["emb"], sparse, use_kernel=use_kernel)
        return jnp.sum(outv * co)

    t_loop = timeit(jax.jit(jax.grad(emb_looped)), per_p, reps=reps)
    t_jnp = timeit(
        jax.jit(jax.grad(lambda ep: emb_fused(ep, False))), params["emb"], reps=reps
    )
    t_ker = timeit(
        jax.jit(jax.grad(lambda ep: emb_fused(ep, True))), params["emb"], reps=reps
    )

    def e2e_fused(p):
        return dlrm.bce_loss(
            p, buffers, dataclasses.replace(cfg, emb_use_kernel=False), batch_tree
        )

    def e2e_looped(p):
        # the pre-collection dlrm.forward: per-feature lookups spliced into
        # the same interaction + MLP stack
        x0 = batch_tree["dense"]
        for i, layer in enumerate(p["bottom"]):
            x0 = x0 @ layer["w"] + layer["b"]
            x0 = jax.nn.relu(x0)
        vecs = [x0] + [
            coll.tables[i].lookup(p["emb"][i], per_b[i], sparse[:, i])
            for i in range(coll.n_features)
        ]
        V = jnp.stack(vecs, axis=1)
        inter = jnp.einsum("bie,bje->bij", V, V)
        iu, ju = jnp.triu_indices(V.shape[1], k=1)
        feats = jnp.concatenate([x0, inter[:, iu, ju]], axis=-1)
        x = feats
        for i, layer in enumerate(p["top"]):
            x = x @ layer["w"] + layer["b"]
            if i < len(p["top"]) - 1:
                x = jax.nn.relu(x)
        lg = x[:, 0]
        y = batch_tree["label"]
        return jnp.mean(
            jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg)))
        )

    t_e2e_fused = timeit(jax.jit(jax.grad(e2e_fused)), params, reps=reps)
    params_loop = dict(params, emb=per_p)
    t_e2e_loop = timeit(jax.jit(jax.grad(e2e_looped)), params_loop, reps=reps)

    result = {
        "backend": jax.default_backend(),
        "note": "CPU kernel times are interpret-mode (validation), not TPU",
        "batch": batch,
        "n_features": coll.n_features,
        "n_groups": coll.n_groups,
        "launches_per_step": {"looped": coll.n_features,
                              "fused": coll.n_lookup_launches},
        "groups": [
            {"kind": g.kind, "features": list(g.features)} for g in coll.groups
        ],
        "emb_fwd_bwd_us": {"looped": t_loop, "fused_jnp": t_jnp,
                           "fused_kernel_interp": t_ker},
        "e2e_dlrm_step_us": {"looped": t_e2e_loop, "fused_jnp": t_e2e_fused},
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out("collection: " + json.dumps(result["launches_per_step"]))
    out(f"emb fwd+bwd us: looped={t_loop:.0f} fused_jnp={t_jnp:.0f} "
        f"fused_kernel_interp={t_ker:.0f}")
    out(f"e2e dlrm step us: looped={t_e2e_loop:.0f} fused_jnp={t_e2e_fused:.0f}")
    out(f"wrote {json_path}")
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--collection", action="store_true",
                    help="only the looped-vs-fused collection bench")
    ap.add_argument("--json", default="BENCH_collection.json")
    args = ap.parse_args()
    if not args.collection:
        main()
    bench_collection(json_path=args.json)
