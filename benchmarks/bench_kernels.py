"""Kernel microbenchmarks: the fused CCE lookup and kmeans-assign kernels
vs their pure-jnp references (CPU interpret mode — wall times here are NOT
TPU times; the structural claim is identical results + the blocked
structure; the roofline for the kernels is derived analytically below).

Emits CSV rows: name,us_per_call,bytes_model,flops_model.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def timeit(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(out=print):
    key = jax.random.PRNGKey(0)
    rows = []

    # CCE lookup at a DLRM-ish shape
    c, B, T, k, dsub = 4, 4096, 2, 2048, 16
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)
    t_ref = timeit(jax.jit(ref.cce_lookup_ref), idx, tables)
    t_ker = timeit(jax.jit(ops.cce_lookup), idx, tables)
    # TPU-model traffic: tables tiles (c*T*k*dsub) + out (B*c*dsub), f32
    bytes_model = 4 * (c * T * k * dsub + B * c * dsub + c * B * T)
    flops_model = 2 * c * T * B * dsub  # gather-as-matmul useful adds
    rows.append(("cce_lookup_ref", t_ref, bytes_model, flops_model))
    rows.append(("cce_lookup_kernel_interp", t_ker, bytes_model, flops_model))

    # kmeans assign at clustering scale
    n, kc, d = 4096, 512, 16
    x = jax.random.normal(key, (n, d), jnp.float32)
    cen = jax.random.normal(jax.random.fold_in(key, 1), (kc, d), jnp.float32)
    t_ref = timeit(jax.jit(ref.kmeans_assign_ref), x, cen)
    t_ker = timeit(jax.jit(ops.kmeans_assign), x, cen)
    bytes_model = 4 * (n * d + kc * d + n)
    flops_model = 2 * n * kc * d
    rows.append(("kmeans_assign_ref", t_ref, bytes_model, flops_model))
    rows.append(("kmeans_assign_kernel_interp", t_ker, bytes_model, flops_model))

    # the transition's full-vocab assignment pass (CCE.assign_all): one
    # chunked materialization, per-column assign via the jnp path vs the
    # Pallas kernel route
    from repro.core.cce import CCE

    cce = CCE(d1=8192, d2=64, k=256, c=4)
    cparams, cbuffers = cce.init(key)
    cents = jax.random.normal(
        jax.random.fold_in(key, 2), (cce.c, cce.k, cce.dsub), jnp.float32
    )
    t_jnp = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=False)),
        cparams, cbuffers, cents,
    )
    t_ker = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=True)),
        cparams, cbuffers, cents,
    )
    bytes_model = 4 * (cce.c * cce.d1 * cce.dsub + cce.c * cce.k * cce.dsub
                       + cce.c * cce.d1)
    flops_model = 2 * cce.c * cce.d1 * cce.k * cce.dsub
    rows.append(("cce_assign_all_jnp", t_jnp, bytes_model, flops_model))
    rows.append(("cce_assign_all_kernel_interp", t_ker, bytes_model, flops_model))

    out("name,us_per_call,bytes_model,flops_model")
    for r in rows:
        out(f"{r[0]},{r[1]:.0f},{r[2]},{r[3]}")
    return rows


if __name__ == "__main__":
    main()
