"""Kernel microbenchmarks: the fused CCE lookup and kmeans-assign kernels
vs their pure-jnp references (CPU interpret mode — wall times here are NOT
TPU times; the structural claim is identical results + the blocked
structure; the roofline for the kernels is derived analytically below).

Emits CSV rows: name,us_per_call,bytes_model,flops_model.

``--collection`` (or a plain ``python benchmarks/bench_kernels.py`` run)
additionally benches the EmbeddingCollection refactor end-to-end: a
26-feature DLRM embedding step, legacy per-feature loop vs grouped
supertables, launches-per-step counted, results written to
``BENCH_collection.json`` (uploaded as a CI artifact).

``--stream`` benches the streaming-statistics subsystem: dense vs sketch
frequency tracker memory (at the real Criteo vocabularies) and observe()
throughput (sync conservative vs async device path), written to
``BENCH_stream.json`` (also a CI artifact).

``--fuse`` benches the launch-fusion trajectory (DESIGN.md §6): the same
26-feature DLRM embedding step under the per-feature loop, the PR-3
3-group collection, and the unified single-launch supertable (plus the
host-translated-rows variant) — launches/step and emb fwd+bwd latency,
written to ``BENCH_fuse.json`` (also a CI artifact).

``--shard`` compares the replicated vs model-sharded DLRM train step at
the FULL Criteo vocabularies, AOT only (abstract lower + compile — zero
array allocation, so the 12.8 GB replicated state never exists): pallas
launches, per-kind collective counts and ICI/DCN bytes from
``repro.launch.hlo_cost``, and per-device state bytes (supertable slab,
optimizer moments, pointer tables) from the step's own output shardings,
written to ``BENCH_shard.json`` (also a CI artifact).  Needs a
multi-device runtime; the CLI re-execs itself under a forced 4-device
CPU when launched on one device.

``--obs`` benches the in-step telemetry's overhead on the reduced DLRM
step — off, on, and on with the async metrics pump draining — written
to ``BENCH_obs.json`` (also a CI artifact; the claim is <= 2%).

``--serve`` benches the DLRM serve engine (DESIGN.md §11) under
synthetic Zipf(1.0) traffic at a 10M-id space: per-request p50/p99
latency for head traffic (fully cache-hit, launch-free), mixed Zipf
traffic, and a cache-disabled baseline (every batch pays the fused
launch), plus cache-hit rates and launches per batch — written to
``BENCH_serve.json`` with the per-request run log in
``BENCH_serve_run.jsonl`` (both CI artifacts).
"""
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def timeit(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(out=print):
    key = jax.random.PRNGKey(0)
    rows = []

    # CCE lookup at a DLRM-ish shape
    c, B, T, k, dsub = 4, 4096, 2, 2048, 16
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)
    t_ref = timeit(jax.jit(ref.cce_lookup_ref), idx, tables)
    t_ker = timeit(jax.jit(ops.cce_lookup), idx, tables)
    # TPU-model traffic: tables tiles (c*T*k*dsub) + out (B*c*dsub), f32
    bytes_model = 4 * (c * T * k * dsub + B * c * dsub + c * B * T)
    flops_model = 2 * c * T * B * dsub  # gather-as-matmul useful adds
    rows.append(("cce_lookup_ref", t_ref, bytes_model, flops_model))
    rows.append(("cce_lookup_kernel_interp", t_ker, bytes_model, flops_model))

    # kmeans assign at clustering scale
    n, kc, d = 4096, 512, 16
    x = jax.random.normal(key, (n, d), jnp.float32)
    cen = jax.random.normal(jax.random.fold_in(key, 1), (kc, d), jnp.float32)
    t_ref = timeit(jax.jit(ref.kmeans_assign_ref), x, cen)
    t_ker = timeit(jax.jit(ops.kmeans_assign), x, cen)
    bytes_model = 4 * (n * d + kc * d + n)
    flops_model = 2 * n * kc * d
    rows.append(("kmeans_assign_ref", t_ref, bytes_model, flops_model))
    rows.append(("kmeans_assign_kernel_interp", t_ker, bytes_model, flops_model))

    # the transition's full-vocab assignment pass (CCE.assign_all): one
    # chunked materialization, per-column assign via the jnp path vs the
    # Pallas kernel route
    from repro.core.cce import CCE

    cce = CCE(d1=8192, d2=64, k=256, c=4)
    cparams, cbuffers = cce.init(key)
    cents = jax.random.normal(
        jax.random.fold_in(key, 2), (cce.c, cce.k, cce.dsub), jnp.float32
    )
    t_jnp = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=False)),
        cparams, cbuffers, cents,
    )
    t_ker = timeit(
        jax.jit(lambda p, b, c: cce.assign_all(p, b, c, chunk_size=2048,
                                               use_kernel=True)),
        cparams, cbuffers, cents,
    )
    bytes_model = 4 * (cce.c * cce.d1 * cce.dsub + cce.c * cce.k * cce.dsub
                       + cce.c * cce.d1)
    flops_model = 2 * cce.c * cce.d1 * cce.k * cce.dsub
    rows.append(("cce_assign_all_jnp", t_jnp, bytes_model, flops_model))
    rows.append(("cce_assign_all_kernel_interp", t_ker, bytes_model, flops_model))

    out("name,us_per_call,bytes_model,flops_model")
    for r in rows:
        out(f"{r[0]},{r[1]:.0f},{r[2]},{r[3]}")
    return rows


def bench_collection(out=print, json_path="BENCH_collection.json",
                     batch=256, reps=3):
    """Looped vs fused DLRM embedding step (the PR's structural claim).

    A 26-feature DLRM at Criteo-shaped (CI-capped) vocabs; measures the
    embedding forward+backward and the full DLRM loss step under (a) the
    legacy per-feature lookup loop and (b) the grouped collection —
    fused-jnp and fused-kernel variants — and counts heavy lookup
    launches per step (n_features -> n_groups).  On CPU the kernel runs
    in interpret mode, so its WALL TIME is not meaningful off-TPU; the
    launch counts and the looped-vs-fused-jnp times are.
    """
    import dataclasses

    import numpy as np

    from repro.configs import dlrm_criteo
    from repro.models import dlrm
    from repro.models.dlrm import DLRMConfig

    vocabs = tuple(min(v, 20_000) for v in dlrm_criteo.CRITEO_KAGGLE_VOCABS)
    cfg = DLRMConfig(
        vocab_sizes=vocabs, n_dense=13, emb_dim=16,
        bottom_mlp=(64, 32, 16), top_mlp=(64, 1),
        emb_method="cce", emb_param_cap=2048,
    )
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch_tree = {
        "dense": jnp.asarray(rng.normal(size=(batch, 13)), jnp.float32),
        "sparse": jnp.asarray(
            np.stack([rng.integers(0, v, batch) for v in vocabs], axis=1),
            jnp.int32,
        ),
        "label": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }
    sparse = batch_tree["sparse"]
    co = jax.random.normal(jax.random.PRNGKey(1), (batch, cfg.n_sparse, 16))
    per_p = jax.tree.map(jnp.asarray, coll.unstack_params(params["emb"]))
    per_b = coll.unstack_buffers(buffers["emb"])

    def emb_looped(pp):  # the pre-collection hot loop: 26 lookups
        outv = jnp.stack(
            [
                coll.tables[i].lookup(pp[i], per_b[i], sparse[:, i])
                for i in range(coll.n_features)
            ],
            axis=1,
        )
        return jnp.sum(outv * co)

    def emb_fused(ep, use_kernel):
        outv = coll.lookup_all(ep, buffers["emb"], sparse, use_kernel=use_kernel)
        return jnp.sum(outv * co)

    t_loop = timeit(jax.jit(jax.grad(emb_looped)), per_p, reps=reps)
    t_jnp = timeit(
        jax.jit(jax.grad(lambda ep: emb_fused(ep, False))), params["emb"], reps=reps
    )
    t_ker = timeit(
        jax.jit(jax.grad(lambda ep: emb_fused(ep, True))), params["emb"], reps=reps
    )

    def e2e_fused(p):
        return dlrm.bce_loss(
            p, buffers, dataclasses.replace(cfg, emb_use_kernel=False), batch_tree
        )

    def e2e_looped(p):
        # the pre-collection dlrm.forward: per-feature lookups spliced into
        # the same interaction + MLP stack
        x0 = batch_tree["dense"]
        for i, layer in enumerate(p["bottom"]):
            x0 = x0 @ layer["w"] + layer["b"]
            x0 = jax.nn.relu(x0)
        vecs = [x0] + [
            coll.tables[i].lookup(p["emb"][i], per_b[i], sparse[:, i])
            for i in range(coll.n_features)
        ]
        V = jnp.stack(vecs, axis=1)
        inter = jnp.einsum("bie,bje->bij", V, V)
        iu, ju = jnp.triu_indices(V.shape[1], k=1)
        feats = jnp.concatenate([x0, inter[:, iu, ju]], axis=-1)
        x = feats
        for i, layer in enumerate(p["top"]):
            x = x @ layer["w"] + layer["b"]
            if i < len(p["top"]) - 1:
                x = jax.nn.relu(x)
        lg = x[:, 0]
        y = batch_tree["label"]
        return jnp.mean(
            jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg)))
        )

    t_e2e_fused = timeit(jax.jit(jax.grad(e2e_fused)), params, reps=reps)
    params_loop = dict(params, emb=per_p)
    t_e2e_loop = timeit(jax.jit(jax.grad(e2e_looped)), params_loop, reps=reps)

    result = {
        "backend": jax.default_backend(),
        "note": "CPU kernel times are interpret-mode (validation), not TPU",
        "batch": batch,
        "n_features": coll.n_features,
        "n_groups": coll.n_groups,
        "launches_per_step": {"looped": coll.n_features,
                              "fused": coll.n_lookup_launches},
        "groups": [
            {"kind": g.kind, "features": list(g.features)} for g in coll.groups
        ],
        "emb_fwd_bwd_us": {"looped": t_loop, "fused_jnp": t_jnp,
                           "fused_kernel_interp": t_ker},
        "e2e_dlrm_step_us": {"looped": t_e2e_loop, "fused_jnp": t_e2e_fused},
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out("collection: " + json.dumps(result["launches_per_step"]))
    out(f"emb fwd+bwd us: looped={t_loop:.0f} fused_jnp={t_jnp:.0f} "
        f"fused_kernel_interp={t_ker:.0f}")
    out(f"e2e dlrm step us: looped={t_e2e_loop:.0f} fused_jnp={t_e2e_fused:.0f}")
    out(f"wrote {json_path}")
    return result


def bench_fuse(out=print, json_path="BENCH_fuse.json", batch=256, reps=3):
    """Looped vs 3-group vs unified embedding step (the launch-fusion
    trajectory, DESIGN.md §6).

    The same Criteo-shaped (CI-capped) 26-feature DLRM tables run under
    all three collection modes; per mode the embedding forward+backward is
    timed on the fused-jnp path (meaningful on CPU; the kernel path is
    interpret mode off-TPU and is timed separately for the fused modes as
    a structural check only) and the heavy launch count is recorded.  A
    fourth variant feeds HOST-translated rows to the unified collection —
    the pod-scale dataflow where the device never gathers the pointer
    tables.
    """
    import numpy as np

    from repro.configs import dlrm_criteo
    from repro.core.collection import EmbeddingCollection
    from repro.data import HostTranslator
    from repro.models.dlrm import DLRMConfig

    vocabs = tuple(min(v, 20_000) for v in dlrm_criteo.CRITEO_KAGGLE_VOCABS)
    cfg = DLRMConfig(
        vocab_sizes=vocabs, n_dense=13, emb_dim=16,
        bottom_mlp=(64, 32, 16), top_mlp=(64, 1),
        emb_method="cce", emb_param_cap=2048,
    )
    tables = cfg.collection.tables
    rng = np.random.default_rng(0)
    sparse_np = np.stack(
        [rng.integers(0, v, batch) for v in vocabs], axis=1
    ).astype(np.int32)
    sparse = jnp.asarray(sparse_np)
    co = jax.random.normal(jax.random.PRNGKey(1), (batch, cfg.n_sparse, 16))
    key = jax.random.PRNGKey(0)

    modes = {"looped": "loop", "grouped3": "group", "unified": "univ"}
    launches, times = {}, {}
    univ = None
    for name, mode in modes.items():
        coll = EmbeddingCollection.build(tables, mode=mode)
        params, buffers = coll.init(key)
        launches[name] = coll.n_lookup_launches

        def emb_loss(p, uk, _coll=coll, _buf=buffers):
            outv = _coll.lookup_all(p, _buf, sparse, use_kernel=uk)
            return jnp.sum(outv * co)

        times[name] = {
            "fused_jnp": timeit(
                jax.jit(jax.grad(lambda p: emb_loss(p, False))), params,
                reps=reps,
            )
        }
        if mode != "loop":  # structural check only off-TPU (interpret)
            times[name]["kernel_interp"] = timeit(
                jax.jit(jax.grad(lambda p: emb_loss(p, True))), params,
                reps=reps,
            )
        if mode == "univ":
            univ = (coll, params, buffers)

    # unified + host-translated rows: the device program consumes only
    # the pre-translated (B, cols, T) tensor
    coll, params, buffers = univ
    translator = HostTranslator(coll, buffers)
    t0 = time.perf_counter()
    rows_np = translator.rows(sparse_np)
    translate_us = (time.perf_counter() - t0) * 1e6
    rows = jnp.asarray(rows_np)

    def emb_loss_rows(p):
        outv = coll.lookup_all(p, buffers, None, use_kernel=False, rows=rows)
        return jnp.sum(outv * co)

    times["unified_host_rows"] = {
        "fused_jnp": timeit(jax.jit(jax.grad(emb_loss_rows)), params, reps=reps),
        "host_translate_us": translate_us,
    }
    launches["unified_host_rows"] = launches["unified"]

    result = {
        "backend": jax.default_backend(),
        "note": ("CPU kernel times are interpret-mode (validation), not "
                 "TPU; the structural claim is launches/step"),
        "batch": batch,
        "n_features": cfg.n_sparse,
        "launches_per_step": launches,
        "emb_fwd_bwd_us": times,
        "rows_tensor": {"cols": coll.rows_n_cols, "T": coll.rows_n_tables},
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out("fuse: launches/step " + json.dumps(launches))
    out("emb fwd+bwd us (fused_jnp): " + json.dumps(
        {k: round(v["fused_jnp"]) for k, v in times.items()}))
    out(f"wrote {json_path}")
    return result


def bench_stream(out=print, json_path="BENCH_stream.json",
                 batch=4096, n_batches=32):
    """Dense vs sketch frequency tracker: state memory and observe()
    throughput (the streaming-statistics subsystem's structural claim —
    DESIGN.md §5).

    Memory is measured at the REAL Criteo vocabularies (the dense
    tracker's cost is what it would be in production; its arrays are
    lazily-zero so allocating them is safe to measure, the sketch is
    measured live).  Throughput runs on a capped-vocab Zipf stream: dense
    ``np.add.at`` vs sketch conservative update vs the async device path
    (jitted segment-sum + background fold — the number that matters is
    the HOT-PATH cost, i.e. how long ``observe`` blocks the step loop;
    the fold drains off-thread and is charged separately via flush).
    """
    import dataclasses

    import numpy as np

    from repro.configs import dlrm_criteo
    from repro.models import dlrm
    from repro.models.dlrm import DLRMConfig
    from repro.stream import IdFrequencyTracker

    # --- memory at full Criteo scale (no data needed; async_fold off —
    # the tracker is read once for nbytes, no folder thread/jit needed) --
    full_cfg = dlrm_criteo.CONFIG
    sketch_full = dlrm.make_id_tracker(
        full_cfg, dataclasses.replace(dlrm_criteo.STREAM, async_fold=False)
    )
    dense_bytes = sum(v * 8 for v in full_cfg.vocab_sizes)  # int64 per row
    mem = {
        "vocab_rows": int(sum(full_cfg.vocab_sizes)),
        "dense_bytes": dense_bytes,
        "sketch_bytes": int(sketch_full.nbytes),
        "ratio": dense_bytes / max(1, sketch_full.nbytes),
        "stream_config": dataclasses.asdict(dlrm_criteo.STREAM),
    }

    # --- update throughput on a Zipf stream (capped vocabs) ---------------
    vocabs = tuple(min(v, 100_000) for v in dlrm_criteo.CRITEO_KAGGLE_VOCABS)
    cfg = DLRMConfig(vocab_sizes=vocabs, emb_method="cce", emb_param_cap=2048)
    rng = np.random.default_rng(0)
    batches = [
        {"sparse": np.stack(
            [rng.zipf(1.2, batch) % v for v in vocabs], axis=1
        ).astype(np.int64)}
        for _ in range(n_batches)
    ]

    def run(tracker):
        tracker.observe(batches[0])  # warm (jit compile on the async path)
        getattr(tracker, "flush", lambda: None)()
        t0 = time.perf_counter()
        for b in batches:
            tracker.observe(b)
        hot = time.perf_counter() - t0
        getattr(tracker, "flush", lambda: None)()
        return hot, time.perf_counter() - t0

    stream_cfg = dlrm_criteo.reduced_stream(window=0)
    hot_dense, _ = run(IdFrequencyTracker(vocabs))
    hot_sketch, _ = run(dlrm.make_id_tracker(cfg, stream_cfg))
    hot_async, total_async = run(
        dlrm.make_id_tracker(
            cfg, dataclasses.replace(stream_cfg, async_fold=True)
        )
    )
    # the async design's structural claim: the hot path is ONE jitted
    # dispatch + an enqueue.  Measure the dispatch alone (few in flight,
    # so the device queue never backs up) — on a real accelerator this is
    # the whole hot-path cost; on CPU the "device" is the host, so the
    # sustained async numbers above contend with the fold thread for the
    # same cores and understate the design.
    async_tr = dlrm.make_id_tracker(
        cfg, dataclasses.replace(stream_cfg, async_fold=True)
    )
    cols = np.ascontiguousarray(
        batches[0]["sparse"][:, list(async_tr.tracked)]
    )
    jcols = jnp.asarray(cols, jnp.int32)
    jax.block_until_ready(async_tr._cell_counter(jcols))
    t0 = time.perf_counter()
    for _ in range(8):
        async_tr._cell_counter(jcols)
    dispatch_us = (time.perf_counter() - t0) / 8 * 1e6

    ids_per_batch = batch * len(vocabs)
    thr = {
        "batch": batch,
        "n_features": len(vocabs),
        "ids_per_batch": ids_per_batch,
        "observe_us_per_batch": {
            "dense": hot_dense / n_batches * 1e6,
            "sketch_sync": hot_sketch / n_batches * 1e6,
            "sketch_async_hot_path": hot_async / n_batches * 1e6,
            "sketch_async_with_fold": total_async / n_batches * 1e6,
            "async_dispatch_only": dispatch_us,
        },
        "ids_per_sec_hot_path": {
            "dense": ids_per_batch * n_batches / hot_dense,
            "sketch_sync": ids_per_batch * n_batches / hot_sketch,
            "sketch_async": ids_per_batch * n_batches / hot_async,
        },
    }
    result = {
        "backend": jax.default_backend(),
        "note": ("on CPU the 'device' is the host: sustained async numbers "
                 "contend with the fold thread for the same cores; "
                 "async_dispatch_only is the structural hot-path cost"),
        "memory": mem,
        "throughput": thr,
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out(f"memory: dense {dense_bytes / 1e6:.0f} MB vs sketch "
        f"{mem['sketch_bytes'] / 1e6:.1f} MB ({mem['ratio']:.0f}x) over "
        f"{mem['vocab_rows']} vocab rows")
    out("observe us/batch: " + json.dumps(
        {k: round(v) for k, v in thr["observe_us_per_batch"].items()}))
    out(f"wrote {json_path}")
    return result


def bench_obs(out=print, json_path="BENCH_obs.json", steps=30, batch=512,
              reps=5):
    """Telemetry overhead on the reduced DLRM train step (DESIGN.md §10).

    Three variants of the SAME jitted step loop: telemetry off, telemetry
    on (metrics returned but never read — the async-dispatch steady
    state), and telemetry on with the ``MetricsPump`` draining every
    record lag steps late.  The telemetry reductions fuse into the step's
    single program (the ``train_step_telemetry`` audit spec pins the
    launch count), so the claim is <= 2% step-time overhead; min-of-reps
    suppresses host noise."""
    from repro.configs import dlrm_criteo
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.models import dlrm
    from repro.obs import MetricsPump, TelemetryConfig
    from repro.optim import sgd
    from repro.train.loop import init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    raw = next(clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0), batch
    ))
    batch_tree = {k: jnp.asarray(v)[None] for k, v in raw.items()
                  if k != "step"}

    def build(telemetry):
        return jax.jit(make_train_step(
            loss_fn, opt, lambda s: jnp.float32(0.05), static,
            telemetry=telemetry,
        ))

    def run_loop(step_fn, pump=None):
        """min-of-reps wall time per step for a `steps`-long loop."""
        best = float("inf")
        for _ in range(reps):
            state = init_state(params, opt, dyn)
            # warm: compile outside the timed region
            state, m = step_fn(state, batch_tree)
            jax.block_until_ready(state)
            t0 = time.perf_counter()
            for s in range(steps):
                state, m = step_fn(state, batch_tree)
                if pump is not None:
                    pump.push(s, m)
            if pump is not None:
                pump.flush()
            jax.block_until_ready(state)
            best = min(best, (time.perf_counter() - t0) / steps * 1e6)
        return best

    t_off = run_loop(build(None))
    t_on = run_loop(build(TelemetryConfig()))
    t_pump = run_loop(build(TelemetryConfig()), pump=MetricsPump(lag=8))

    result = {
        "backend": jax.default_backend(),
        "steps": steps,
        "batch": batch,
        "reps": reps,
        "step_us": {"off": t_off, "on": t_on, "on_pump_drain": t_pump},
        "overhead_pct": {
            "on": (t_on - t_off) / t_off * 100,
            "on_pump_drain": (t_pump - t_off) / t_off * 100,
        },
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out(f"obs: step us off={t_off:.0f} on={t_on:.0f} on+pump={t_pump:.0f}")
    out("overhead pct: " + json.dumps(
        {k: round(v, 2) for k, v in result["overhead_pct"].items()}))
    out(f"wrote {json_path}")
    return result


def bench_shard(out=print, json_path="BENCH_shard.json"):
    """Replicated vs model-sharded DLRM train step at full Criteo scale.

    Everything here is ahead-of-time: the step is built from
    ShapeDtypeStructs, lowered, and compiled — no array is ever
    allocated, so the full-vocabulary comparison runs on a laptop.  Per
    variant we report the structural numbers the sharding PR claims:
    pallas launches per step (unchanged by sharding), the per-kind
    collective counts + ICI/DCN bytes of the partitioned module
    (``hlo_cost.analyze``), per-device entry-parameter bytes
    (``hlo_cost.liveness``), and the exact per-device state footprint —
    supertable slab, optimizer moments, pointer/stat buffers — read off
    the step's own output shardings via ``Sharding.shard_shape``."""
    import dataclasses
    import math

    from repro.analysis import walker
    from repro.configs import dlrm_criteo
    from repro.launch import hlo_cost
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_dlrm_train_step
    from repro.optim import sgd

    n = jax.device_count()
    assert n > 1, "bench_shard needs a multi-device runtime (CLI forces 4)"

    def subtree_bytes(shape_tree, shard_tree):
        shapes = jax.tree_util.tree_leaves(shape_tree)
        shards = jax.tree_util.tree_leaves(shard_tree)
        glob = sum(s.size * s.dtype.itemsize for s in shapes)
        per = sum(
            math.prod(sh.shard_shape(s.shape)) * s.dtype.itemsize
            for s, sh in zip(shapes, shards)
        )
        return {"global": glob, "per_device": per}

    variants = {}
    for name, model in (("replicated", 1), ("sharded", n)):
        cfg = dataclasses.replace(dlrm_criteo.CONFIG, emb_k_multiple=model)
        mesh = make_host_mesh(data=1, model=model)
        jitted, (state_shape, batch_struct), (state_sh, _) = (
            build_dlrm_train_step(
                cfg, mesh, batch_size=32, accum=1,
                optimizer=sgd(momentum=0.9),
            )
        )
        launches = walker.count_primitive(
            jax.make_jaxpr(jitted)(state_shape, batch_struct), "pallas_call"
        )
        text = jitted.lower(state_shape, batch_struct).compile().as_text()
        cost = hlo_cost.analyze(text)
        live = hlo_cost.liveness(text)
        variants[name] = {
            "model_shards": model,
            "pallas_launches": launches,
            "collectives": {k: int(v) for k, v in sorted(cost.coll.items())},
            "ici_bytes": cost.ici_bytes,
            "dcn_bytes": cost.dcn_bytes,
            "entry_param_bytes_per_device": live.param_bytes,
            "state_bytes": {
                "total": subtree_bytes(state_shape, state_sh),
                "emb_slab": subtree_bytes(
                    state_shape.params["emb"], state_sh.params["emb"]
                ),
                "opt_moments": subtree_bytes(state_shape.opt, state_sh.opt),
                "emb_buffers": subtree_bytes(state_shape.ebuf, state_sh.ebuf),
            },
        }
        out(f"{name}: launches={launches} "
            f"collectives={variants[name]['collectives']} "
            f"state/device={variants[name]['state_bytes']['total']['per_device'] / 1e6:.1f} MB")

    rep = variants["replicated"]["state_bytes"]["total"]["per_device"]
    shd = variants["sharded"]["state_bytes"]["total"]["per_device"]
    result = {
        "backend": jax.default_backend(),
        "n_devices": n,
        "config": "dlrm_criteo (full Criteo vocabularies, AOT — no arrays)",
        "variants": variants,
        "per_device_state_ratio": rep / shd if shd else None,
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out(f"per-device state: replicated {rep / 1e6:.1f} MB -> "
        f"sharded {shd / 1e6:.1f} MB ({rep / shd:.2f}x)")
    out(f"wrote {json_path}")
    return result


def bench_serve(out=print, json_path="BENCH_serve.json",
                run_log_path="BENCH_serve_run.jsonl",
                vocab_sizes=(10_000_000, 100_000, 1_000),
                n_requests=256, max_batch=16, zipf_s=1.0, heavy=4096):
    """Serve-path latency under Zipf traffic (serve/dlrm.py, ROADMAP 2).

    Three traffic scenarios through identical engines (CPU wall times —
    structural claims, not TPU latencies):

    * ``head``: every id drawn from the SpaceSaving head the cache holds
      — fully-hit batches, ZERO launches (the millions-of-users case the
      cache exists for: the heavy head answered without the supertable).
    * ``zipf``: bounded-Zipf(s) ids over the full vocab — mixed batches,
      compacted cold sub-batch per launch, realistic hit rates.
    * ``uncached``: the same Zipf traffic with the cache disabled —
      every batch pays the fused launch.

    The gated claim: head (cache-hit) p50 strictly below the uncached
    fused-launch p50."""
    import numpy as np

    from repro.models.dlrm import DLRMConfig
    from repro.models import dlrm
    from repro.obs.runlog import LatencyHistogram, RunLog
    from repro.serve.dlrm import DLRMServeEngine, ServeRequest
    from repro.stream import StreamConfig

    cfg = DLRMConfig(
        vocab_sizes=vocab_sizes, n_dense=13, emb_dim=16,
        bottom_mlp=(64, 16), top_mlp=(64, 1),
        emb_method="cce", emb_param_cap=4096 * 16,
    )
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    tracker = dlrm.make_id_tracker(cfg, StreamConfig(
        width=1 << 12, heavy=heavy, window=64, async_fold=False,
    ))
    rng = np.random.default_rng(0)

    # bounded Zipf(s): inverse-CDF over the harmonic weights — np.random
    # .zipf needs s > 1 and is unbounded, neither fits a fixed id space
    cdfs = []
    for v in vocab_sizes:
        w = 1.0 / np.arange(1, v + 1, dtype=np.float64) ** zipf_s
        cdf = np.cumsum(w)
        cdfs.append(cdf / cdf[-1])

    def zipf_batch(n):
        return np.stack(
            [np.searchsorted(c, rng.random(n)).astype(np.int64) for c in cdfs],
            axis=1,
        )

    tracker.observe({"sparse": zipf_batch(8192)})  # warm the heads

    def drive(eng, sparse, label):
        eng.hist = LatencyHistogram()
        eng.hist_hit = LatencyHistogram()
        eng.hist_cold = LatencyHistogram()
        eng.counters.clear()
        dense = rng.normal(size=(len(sparse), cfg.n_dense)).astype(np.float32)
        for s in range(0, len(sparse), max_batch):
            for i in range(s, min(s + max_batch, len(sparse))):
                eng.submit(ServeRequest(uid=i, dense=dense[i], sparse=sparse[i]))
            eng.drain()
        stats = eng.flush_stats()
        res = {
            "p50_s": eng.hist.percentile(50),
            "p99_s": eng.hist.percentile(99),
            **{k: stats[k] for k in (
                "n_requests", "n_batches", "n_launches", "launches_per_batch",
                "hit_rate_requests", "hit_rate_ids",
            )},
        }
        out(f"serve[{label}]: p50 {res['p50_s'] * 1e3:.2f} ms  "
            f"p99 {res['p99_s'] * 1e3:.2f} ms  "
            f"hit {res['hit_rate_requests']:.0%} req / "
            f"{res['hit_rate_ids']:.0%} ids  "
            f"launches/batch {res['launches_per_batch']:.2f}")
        return res

    with RunLog(run_log_path, manifest={"config": "bench_serve"}) as rl:
        cached = DLRMServeEngine(
            params, buffers, cfg, tracker=tracker, max_batch=max_batch,
            latency_budget_s=0.0, run_log=rl,
        )
        uncached = DLRMServeEngine(
            params, buffers, cfg, cache=False, max_batch=max_batch,
            latency_budget_s=0.0, run_log=rl,
        )
        # head traffic: Zipf over each feature's CACHED ids, so every
        # batch is answerable without the supertable
        head_cols = []
        for f in range(cfg.n_sparse):
            ids = cached.cache.ids[f]
            w = 1.0 / np.arange(1, ids.size + 1, dtype=np.float64) ** zipf_s
            cdf = np.cumsum(w)
            ranks = np.searchsorted(cdf / cdf[-1], rng.random(n_requests))
            head_cols.append(ids[ranks])
        head_sparse = np.stack(head_cols, axis=1)
        zipf_sparse = zipf_batch(n_requests)

        # compile outside the timed scenarios (hit + cold programs)
        cached.predict(np.zeros((max_batch, cfg.n_dense), np.float32),
                       head_sparse[:max_batch])
        cached.predict(np.zeros((max_batch, cfg.n_dense), np.float32),
                       zipf_sparse[:max_batch])
        uncached.predict(np.zeros((max_batch, cfg.n_dense), np.float32),
                         zipf_sparse[:max_batch])

        result = {
            "backend": jax.default_backend(),
            "vocab_sizes": list(vocab_sizes),
            "zipf_s": zipf_s,
            "n_requests": n_requests,
            "max_batch": max_batch,
            "cache_slots": cached.cache.n_slots,
            "head": drive(cached, head_sparse, "head"),
            "zipf": drive(cached, zipf_sparse, "zipf"),
            "uncached": drive(uncached, zipf_sparse, "uncached"),
        }
    result["hit_p50_below_uncached_p50"] = bool(
        result["head"]["p50_s"] < result["uncached"]["p50_s"]
    )
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    out(f"cache-hit p50 below uncached p50: "
        f"{result['hit_p50_below_uncached_p50']}")
    out(f"wrote {json_path} + {run_log_path}")
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--collection", action="store_true",
                    help="only the looped-vs-fused collection bench")
    ap.add_argument("--stream", action="store_true",
                    help="only the dense-vs-sketch tracker bench")
    ap.add_argument("--fuse", action="store_true",
                    help="only the looped/3-group/unified launch bench")
    ap.add_argument("--shard", action="store_true",
                    help="replicated-vs-sharded AOT comparison (multi-device)")
    ap.add_argument("--obs", action="store_true",
                    help="telemetry off/on/on+pump step-overhead bench")
    ap.add_argument("--serve", action="store_true",
                    help="serve-engine latency under Zipf traffic "
                         "(hot cache vs uncached)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.serve:
        bench_serve(json_path=args.json or "BENCH_serve.json")
    elif args.obs:
        bench_obs(json_path=args.json or "BENCH_obs.json")
    elif args.stream:
        bench_stream(json_path=args.json or "BENCH_stream.json")
    elif args.collection:
        bench_collection(json_path=args.json or "BENCH_collection.json")
    elif args.fuse:
        bench_fuse(json_path=args.json or "BENCH_fuse.json")
    elif args.shard:
        if jax.device_count() < 2:
            # jax is initialized by now — device count is baked in.  Re-exec
            # with a forced 4-device CPU topology instead.
            import os
            import subprocess
            import sys

            env = dict(
                os.environ,
                JAX_PLATFORMS="cpu",
                XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4"),
            )
            sys.exit(subprocess.call(
                [sys.executable, __file__, "--shard",
                 "--json", args.json or "BENCH_shard.json"],
                env=env,
            ))
        bench_shard(json_path=args.json or "BENCH_shard.json")
    else:
        main()
        bench_collection(json_path=args.json or "BENCH_collection.json")
        bench_stream(json_path="BENCH_stream.json")
        bench_fuse(json_path="BENCH_fuse.json")
