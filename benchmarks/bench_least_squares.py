"""Figure 1b / Figure 8 / Figure 6: CCE for least squares.

Compares, at the paper's setting (scaled to CPU: n=2000, d1=400, d2=10):
  * dense CCE (Alg. 1) vs the Theorem 3.1 bound vs the optimal loss,
  * smart (SVD-aligned) noise vs plain noise (Fig. 6),
  * sparse CCE (Alg. 2) vs post-hoc K-means factorization of the exact
    solution with 1 or 2 ones per row (the Fig. 1b comparison lines).

Emits CSV rows: name,iteration,loss.
"""
import time

import jax
import numpy as np

from repro.core import least_squares as ls


def run(n=2000, d1=400, d2=10, k=40, iters=25, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky, kr = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d1))
    Y = jax.random.normal(ky, (n, d2))
    rows = []
    opt, T_star = ls.optimal_loss(X, Y)
    rows.append(("optimal", 0, float(opt)))

    bound = np.asarray(ls.theorem_bound(X, Y, k, iters))
    for i, b in enumerate(bound):
        rows.append(("theorem_3_1_bound", i, float(b)))

    t0 = time.time()
    dense = ls.dense_cce(kr, X, Y, k, iters)
    t_dense = time.time() - t0
    for i, loss_val in enumerate(np.asarray(dense.losses)):
        rows.append(("dense_cce", i, float(loss_val)))

    smart = ls.dense_cce(kr, X, Y, k, iters, smart_noise=True)
    for i, loss_val in enumerate(np.asarray(smart.losses)):
        rows.append(("dense_cce_smart_noise", i, float(loss_val)))

    t0 = time.time()
    sparse = ls.sparse_cce(kr, X, Y, k, iters)
    t_sparse = time.time() - t0
    for i, loss_val in enumerate(np.asarray(sparse.losses)):
        rows.append(("sparse_cce", i, float(loss_val)))

    for ones in (1, 2):
        T = ls.kmeans_factorize(kr, T_star, k, ones_per_row=ones)
        rows.append((f"kmeans_factorize_{ones}ones", iters, float(ls.loss(X, T, Y))))

    meta = {"dense_s": t_dense, "sparse_s": t_sparse,
            "final_dense_over_opt": float(dense.losses[-1] / opt),
            "final_sparse_over_opt": float(sparse.losses[-1] / opt)}
    return rows, meta


def main(out=print):
    rows, meta = run()
    out("name,iteration,loss")
    for r in rows:
        out(f"{r[0]},{r[1]},{r[2]:.6f}")
    out(f"# meta: {meta}")
    return meta


if __name__ == "__main__":
    main()
