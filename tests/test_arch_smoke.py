"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward/train step on CPU — output shapes + no NaNs
(the full configs are exercised via the dry-run only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ARCHS = list(configs.ARCHS)


def _batch(cfg, key, B=2, S=8):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.ones((B, cfg.n_patches, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, buffers, cfg, batch)
    S_text = batch["tokens"].shape[1]
    want = (2, S_text, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks else (2, S_text, cfg.vocab)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, _ = lm.next_token_loss(params, buffers, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: lm.next_token_loss(p, buffers, cfg, batch)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params, buffers = lm.init(key, cfg)
    B = 2
    cache = lm.init_cache(cfg, B, 16)
    tok = (
        jax.random.randint(key, (B, cfg.n_codebooks), 0, cfg.vocab)
        if cfg.n_codebooks
        else jax.random.randint(key, (B,), 0, cfg.vocab)
    )
    logits, cache2 = lm.decode_step(
        params, buffers, cfg, tok, jnp.zeros((B,), jnp.int32), cache
    )
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """The exact assigned hyperparameters (cheap dataclass checks)."""
    cfg = configs.get(arch)
    spec = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


def test_moe_extras():
    q = configs.get("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.top_k) == (128, 8)
    p = configs.get("phi3.5-moe-42b-a6.6b")
    assert (p.n_experts, p.top_k) == (16, 2)
    h = configs.get("hymba-1.5b")
    assert h.ssm_state == 16 and h.subquadratic
    x = configs.get("xlstm-1.3b")
    assert x.is_recurrent and x.subquadratic
