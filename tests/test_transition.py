"""The clustering-transition subsystem (Alg. 3 as rebuilt in this repo):

* optimizer-moment remap/reset across ``cluster()`` — no stale-moment
  leakage through the 4-arg Trainer protocol,
* single-pass full-vocab assignment — chunked bit-matches unchunked, and
  exactly ONE full-vocab materialization per transition,
* the Pallas ``kmeans_assign`` kernel route (interpret mode on CPU)
  matches the jnp path,
* the shard_map'd distributed transition reproduces the serial one on a
  1-device axis, and runs both phases (weighted k-means + full-vocab
  assignment) sharded on a forced 4-device host,
* count-WEIGHTED k-means: a weighted Lloyd step equals the unweighted
  step on the expanded multiset, and the transition feeds unique observed
  ids + counts instead of a with-replacement sample,
* restart-exact resume across a transition (params AND remapped moments).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.core.cce import CCE
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.optim.remap import remap_opt_state
from repro.train.loop import (
    FailureInjector,
    Trainer,
    init_state,
    make_train_step,
    split_buffers,
)


@pytest.fixture(scope="module")
def cce_state():
    # d1 > 256*k so the k-means sample is a strict subset of the vocab and
    # the full-vocab pass is distinguishable from the sample pass
    cce = CCE(d1=3000, d2=16, k=8, c=4, seed_salt=3)
    params, buffers = cce.init(jax.random.PRNGKey(0))
    return cce, params, buffers


# --- single-pass, chunked, kernel-backed assignment --------------------------


def test_chunked_assignment_bitmatches_unchunked(cce_state):
    cce, params, buffers = cce_state
    cents = jax.random.normal(jax.random.PRNGKey(1), (cce.c, cce.k, cce.dsub))
    a_full = cce.assign_all(params, buffers, cents, use_kernel=False)
    a_chunk = cce.assign_all(params, buffers, cents, chunk_size=97, use_kernel=False)
    assert a_full.shape == (cce.c, cce.d1)
    np.testing.assert_array_equal(np.asarray(a_full), np.asarray(a_chunk))


def test_cluster_is_single_full_vocab_pass(monkeypatch, cce_state):
    cce, params, buffers = cce_state
    calls = []
    orig = CCE.materialize

    def spy(self, p, b, ids):
        calls.append(int(ids.shape[0]))
        return orig(self, p, b, ids)

    monkeypatch.setattr(CCE, "materialize", spy)
    cce.cluster(jax.random.PRNGKey(3), params, buffers)
    assert sum(1 for n in calls if n == cce.d1) == 1, calls
    # chunked: the vocab is streamed, (c, d1, dsub) never materializes
    calls.clear()
    cce.cluster(jax.random.PRNGKey(3), params, buffers, chunk_size=500)
    assert max(calls) < cce.d1 and sum(n for n in calls if n <= 500) == cce.d1


def test_cluster_kernel_path_matches_jnp(cce_state):
    cce, params, buffers = cce_state
    p_j, b_j = cce.cluster(jax.random.PRNGKey(2), params, buffers, use_kernel=False)
    p_k, b_k = cce.cluster(jax.random.PRNGKey(2), params, buffers, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(p_j["tables"]), np.asarray(p_k["tables"]), rtol=1e-6
    )
    agree = (np.asarray(b_j["ptr"]) == np.asarray(b_k["ptr"])).mean()
    assert agree > 0.99  # float-order ties may flip the rare equidistant row


def test_cluster_sharded_single_device_matches_serial(cce_state):
    cce, params, buffers = cce_state
    mesh = jax.make_mesh((1,), ("data",))
    p_s, b_s = cce.cluster_sharded(jax.random.PRNGKey(6), params, buffers, mesh)
    p_r, b_r = cce.cluster(jax.random.PRNGKey(6), params, buffers)
    np.testing.assert_allclose(
        np.asarray(p_s["tables"]), np.asarray(p_r["tables"]), rtol=1e-5, atol=1e-6
    )
    agree = (np.asarray(b_s["ptr"]) == np.asarray(b_r["ptr"])).mean()
    assert agree > 0.99
    np.testing.assert_array_equal(np.asarray(b_s["hs"]), np.asarray(b_r["hs"]))


# --- moment remap ------------------------------------------------------------


def test_remap_moments_is_cluster_mean(cce_state):
    cce, params, buffers = cce_state
    moments = {
        "tables": jax.random.normal(jax.random.PRNGKey(4), params["tables"].shape)
    }
    _, b2 = cce.cluster(jax.random.PRNGKey(5), params, buffers)
    rm = cce.remap_moments(moments, buffers, b2)
    mt = np.asarray(rm["tables"])
    assert float(np.abs(mt[:, 1]).max()) == 0.0  # fresh helper: zero moments
    # reference: materialize per-id moments under the OLD pointers, then
    # mean per NEW cluster
    per_id = np.asarray(cce.materialize(moments, buffers, jnp.arange(cce.d1)))
    ptr = np.asarray(b2["ptr"])
    for i in range(cce.c):
        for j in range(cce.k):
            sel = per_id[i][ptr[i] == j]
            want = sel.mean(0) if len(sel) else np.zeros(cce.dsub, np.float32)
            np.testing.assert_allclose(mt[i, 0, j], want, rtol=1e-5, atol=1e-6)
    # streaming the remap changes nothing (up to f32 accumulation order)
    rm2 = cce.remap_moments(moments, buffers, b2, chunk_size=113)
    np.testing.assert_allclose(np.asarray(rm2["tables"]), mt, rtol=1e-4, atol=1e-5)


def test_remap_opt_state_policies():
    opt = {"m": {"w": jnp.ones(3)}, "t": jnp.zeros((), jnp.int32) + 5}
    out = remap_opt_state(opt, lambda mom, slot: jax.tree.map(lambda x: 2 * x, mom))
    assert float(out["m"]["w"][0]) == 2.0
    assert int(out["t"]) == 5  # scalar slots untouched: bias correction continuous
    assert remap_opt_state(opt, None, policy="keep") is opt
    assert remap_opt_state({}, None) == {}  # plain SGD
    with pytest.raises(ValueError):
        remap_opt_state(opt, None, policy="bogus")


def test_cluster_tables_remaps_and_resets():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    opt = jax.tree.map(
        lambda x: jnp.full_like(x, 0.5), sgd(momentum=0.9).init(params)
    )
    p2, b2, opt2 = dlrm.cluster_tables(
        jax.random.PRNGKey(1), params, buffers, cfg, opt
    )
    # non-embedding moments flow through untouched
    np.testing.assert_array_equal(
        np.asarray(opt2["m"]["bottom"][0]["w"]),
        np.asarray(opt["m"]["bottom"][0]["w"]),
    )
    for i in range(cfg.n_sparse):
        if isinstance(cfg.table(i), CCE):
            m = np.asarray(coll.feature_params(opt2["m"]["emb"], i)["tables"])
            assert float(np.abs(m[:, 1]).max()) == 0.0  # helper slab zeroed
            # per-id moment is 0.5 (main) + 0.5 (helper) = 1.0 everywhere, so
            # every non-empty cluster's remapped moment is exactly 1.0
            ptr = np.asarray(coll.feature_buffers(b2["emb"], i)["ptr"])
            for col in range(ptr.shape[0]):
                nonempty = np.unique(ptr[col])
                np.testing.assert_allclose(m[col, 0, nonempty], 1.0, rtol=1e-6)
    _, _, opt3 = dlrm.cluster_tables(
        jax.random.PRNGKey(1), params, buffers, cfg, opt, policy="reset"
    )
    for i in range(cfg.n_sparse):
        if isinstance(cfg.table(i), CCE):
            m3 = np.asarray(coll.feature_params(opt3["m"]["emb"], i)["tables"])
            assert float(np.abs(m3).max()) == 0.0


# --- frequency-weighted k-means sampling -------------------------------------


def test_id_frequency_tracker():
    from repro.train.freq import IdFrequencyTracker

    tr = IdFrequencyTracker((10, 5))
    assert tr.sample_ids(0, 0, 8) is None  # nothing observed: uniform fallback
    tr.observe({"sparse": np.array([[1, 2], [1, 3], [7, 2]])})
    tr.observe({"sparse": np.array([[1, 2]])})
    assert tr.counts[0][1] == 3 and tr.counts[0][7] == 1
    s = tr.sample_ids(42, 0, 1000)
    assert set(np.unique(s)) <= {1, 7}
    # frequency-weighted: id 1 (3 of 4 observations) dominates the sample
    assert (s == 1).mean() > 0.5
    np.testing.assert_array_equal(s, tr.sample_ids(42, 0, 1000))  # deterministic
    # checkpoint round-trip
    tr2 = IdFrequencyTracker((10, 5))
    tr2.load_state_tree(tr.state_tree())
    np.testing.assert_array_equal(tr2.counts[0], tr.counts[0])


def test_points_from_counts_is_weighted_not_sampled():
    from repro.train.freq import points_from_counts

    counts = np.array([0, 3, 0, 1, 5, 0])
    ids, w = points_from_counts(counts, 10, seed=0)
    np.testing.assert_array_equal(ids, [1, 3, 4])  # every observed id ONCE
    np.testing.assert_array_equal(w, [3.0, 1.0, 5.0])  # counts ARE the weights
    assert points_from_counts(np.zeros(4), 10, 0) is None  # uniform fallback
    # over-cap: stratified, deterministic, unbiased — the head enters
    # exactly, the uniform tail is Horvitz-Thompson-inflated
    big = np.arange(100)  # id i observed i times; ids 96..99 are the head
    ids1, w1 = points_from_counts(big, 10, seed=7)
    ids2, w2 = points_from_counts(big, 10, seed=7)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(w1, w2)
    assert len(ids1) == len(np.unique(ids1)) == 10
    head = np.arange(95, 100)  # top n/2 counts included with certainty
    assert set(head) <= set(ids1)
    lut = dict(zip(ids1, w1))
    for i in head:
        assert lut[i] == big[i]  # exact counts for the head
    # tail: count * (|rest| / n_tail); 99 observed ids - 5 head = 94 rest
    for i in set(ids1) - set(head):
        np.testing.assert_allclose(lut[i], big[i] * 94 / 5)
    # E[total weight] == total observed mass (unbiasedness, in expectation)
    tots = [points_from_counts(big, 10, seed=s)[1].sum() for s in range(300)]
    np.testing.assert_allclose(np.mean(tots), big.sum(), rtol=0.05)


def test_weighted_lloyd_equals_multiset_lloyd():
    """A weighted Lloyd iteration on unique points IS the unweighted
    iteration on the multiset — the exact form of the epoch-boundary
    sample that with-replacement draws only approximate."""
    from repro.core import kmeans as km

    x = jax.random.normal(jax.random.PRNGKey(0), (12, 4))
    w = jnp.asarray([3.0, 1, 2, 1, 1, 4, 1, 2, 1, 1, 5, 1])
    c0 = jax.random.normal(jax.random.PRNGKey(1), (3, 4))
    c_w, _, in_w = km._lloyd_step(x, c0, 3, weights=w)
    c_d, _, in_d = km._lloyd_step(jnp.repeat(x, w.astype(int), axis=0), c0, 3)
    np.testing.assert_allclose(np.asarray(c_w), np.asarray(c_d), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(in_w), float(in_d), rtol=1e-4)


def test_weighted_kmeans_follows_the_mass():
    """Centroids must track the weight, not the point count: many light
    points vs one heavy point."""
    from repro.core import kmeans as km

    light = jax.random.normal(jax.random.PRNGKey(2), (63, 2)) * 0.05
    heavy = jnp.array([[10.0, 10.0]])
    x = jnp.concatenate([light, heavy])
    w = jnp.concatenate([jnp.ones(63), jnp.asarray([1000.0])])
    res = km.kmeans(jax.random.PRNGKey(3), x, 2, niter=20, weights=w)
    d_heavy = np.linalg.norm(np.asarray(res.centroids) - np.array([10, 10]), axis=1)
    assert d_heavy.min() < 0.1  # one centroid sits ON the heavy point


def test_transition_uses_count_weighted_sample(cce_state, monkeypatch):
    """With a histogram, cluster() must receive the UNIQUE observed ids
    plus weights (not a with-replacement multiset)."""
    from repro.train.transition import transition_table

    cce, params, buffers = cce_state
    counts = np.zeros(cce.d1)
    counts[[7, 13, 99]] = [5, 1, 2]
    seen = {}
    orig = CCE.cluster

    def spy(self, key, p, b, **kw):
        seen.update(kw)
        return orig(self, key, p, b, **kw)

    monkeypatch.setattr(CCE, "cluster", spy)
    transition_table(cce, jax.random.PRNGKey(0), params, buffers, counts=counts)
    np.testing.assert_array_equal(np.asarray(seen["sample_ids"]), [7, 13, 99])
    np.testing.assert_array_equal(np.asarray(seen["sample_weights"]), [5.0, 1.0, 2.0])


# --- sharded full-vocab assignment (forced multi-device) ----------------------


def test_assign_all_sharded_matches_serial_on_one_device(cce_state):
    cce, params, buffers = cce_state
    mesh = jax.make_mesh((1,), ("data",))
    cents = jax.random.normal(jax.random.PRNGKey(1), (cce.c, cce.k, cce.dsub))
    a_serial = cce.assign_all(params, buffers, cents, use_kernel=False)
    a_shard = cce.assign_all_sharded(
        params, buffers, cents, mesh, chunk_size=97, use_kernel=False
    )
    np.testing.assert_array_equal(np.asarray(a_serial), np.asarray(a_shard))


@pytest.mark.slow
def test_cluster_sharded_on_forced_four_device_host():
    """The whole sharded transition — distributed weighted k-means AND the
    sharded full-vocab assignment — on a real 4-device (forced host) mesh,
    in a subprocess so the flag is set before jax initializes."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core.cce import CCE

        assert jax.device_count() == 4, jax.devices()
        cce = CCE(d1=303, d2=16, k=8, c=2, seed_salt=1)
        params, buffers = cce.init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        ids = jnp.asarray(rng.integers(0, 303, 256))
        w = jnp.asarray(rng.integers(1, 5, 256), jnp.float32)
        p_s, b_s = cce.cluster_sharded(
            jax.random.PRNGKey(3), params, buffers, mesh,
            sample_ids=ids, sample_weights=w, chunk_size=50,
        )
        # after the transition the main table IS the centroids, so the
        # sharded full-vocab assignment must reproduce a serial assign
        # against them (up to float-tie flips)
        cents = p_s["tables"][:, 0].astype(jnp.float32)
        want = np.asarray(cce.assign_all(params, buffers, cents, use_kernel=False))
        got = np.asarray(b_s["ptr"])
        assert got.shape == want.shape == (2, 303)
        assert (got == want).mean() > 0.99, (got != want).sum()
        assert float(np.abs(np.asarray(p_s["tables"][:, 1])).max()) == 0.0
        print("MULTIDEVICE-OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [os.path.join(os.path.dirname(__file__), "..", "src"),
                      env.get("PYTHONPATH")])
    )
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "MULTIDEVICE-OK" in r.stdout


# --- the Trainer protocol ----------------------------------------------------


def _setup(seed=0, cap=512):
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=cap)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 32
    )
    return cfg, step, state, static, data


def test_cce_buffers_are_fully_dynamic():
    """The transition rewrites ptr, hs AND epoch; all three must ride the
    dynamic ebuf through the jitted step — a static (python-int) leaf would
    leave the step training against pre-transition hash functions (the
    seed's silent regression)."""
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    _, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    _, (treedef, static_items) = split_buffers(buffers)
    assert static_items == (), static_items


def test_trainer_threads_opt_through_transition():
    cfg, step, state, static, data = _setup()

    def cluster_fn(key, p, b, opt):
        return dlrm.cluster_tables(key, p, b, cfg, opt)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 cluster_fn=cluster_fn, cluster_every=10, cluster_max=1)
    tr.run(10)  # the transition fires after the final step
    assert tr.clusters_done == 1
    for i in range(cfg.n_sparse):
        if isinstance(cfg.table(i), CCE):
            m = np.asarray(
                cfg.collection.feature_params(tr.state.opt["m"]["emb"], i)["tables"]
            )
            assert float(np.abs(m[:, 1]).max()) == 0.0  # no stale helper moments


def test_restart_exact_across_transition(tmp_path):
    """Crash AFTER a transition, restore from the pre-transition
    checkpoint, replay — the transition (clustering, fresh hashes, moment
    remap) re-runs deterministically and the final state is bitwise equal
    to the uninterrupted run."""

    from repro.train.freq import IdFrequencyTracker

    def make(cfg, tracker):
        def cluster_fn(key, p, b, opt):
            return dlrm.cluster_tables(key, p, b, cfg, opt,
                                       id_counts=tracker.counts)

        return dict(cluster_fn=cluster_fn, cluster_every=6, cluster_max=2,
                    id_tracker=tracker, seed=1)

    def run(fail: bool):
        cfg, step, state, static, data = _setup(seed=1)
        tracker = IdFrequencyTracker(cfg.vocab_sizes)
        tr = Trainer(
            jax.jit(step, donate_argnums=(0,)), state, static, data,
            ckpt_dir=str(tmp_path / ("a" if fail else "b")), ckpt_every=5,
            failures=FailureInjector((8,)) if fail else None,
            **make(cfg, tracker),
        )
        if fail:
            with pytest.raises(RuntimeError):
                tr.run(12)
            cfg2, step2, _, static2, _ = _setup(seed=1)
            tracker2 = IdFrequencyTracker(cfg2.vocab_sizes)
            tr2 = Trainer(
                jax.jit(step2, donate_argnums=(0,)), tr.state, static2,
                clickstream_batches(
                    ClickstreamConfig(vocab_sizes=cfg2.vocab_sizes, seed=1),
                    32, start_step=5,
                ),
                ckpt_dir=str(tmp_path / "a"), **make(cfg2, tracker2),
            )
            restored = tr2.restore_latest()
            assert restored == 5 and tr2.clusters_done == 0
            assert int(tracker2.counts[0].sum()) == 5 * 32  # histograms resumed
            tr2.run(12 - restored)
            return tr2.state
        tr.run(12)
        return tr.state

    s_fail = run(True)
    s_clean = run(False)
    for a, b in zip(jax.tree.leaves(s_fail.params), jax.tree.leaves(s_clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_fail.opt), jax.tree.leaves(s_clean.opt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_three_arg_cluster_fn_still_supported():
    cfg, step, state, static, data = _setup()

    def cluster_fn(key, p, b):
        return dlrm.cluster_tables(key, p, b, cfg)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 cluster_fn=cluster_fn, cluster_every=5, cluster_max=1)
    hist = tr.run(6)
    assert tr.clusters_done == 1 and np.isfinite(hist[-1]["loss"])
