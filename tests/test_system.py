"""End-to-end behaviour: the paper's central claim in miniature.

On synthetic clickstream data with PLANTED cluster structure and equal
parameter budgets, interleaved clustering (the CCE mechanism) must help,
and every compressed method must train to a usable BCE.  This is Figure
4's qualitative content at CPU scale (the quantitative Criteo numbers need
the real datasets + GPU-hours; see EXPERIMENTS.md §Scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.train.loop import Trainer, init_state, make_train_step, split_buffers


def _train(emb_method: str, steps: int = 120, cap: int = 256, seed: int = 0,
           cluster_every: int = 0):
    cfg = dlrm_criteo.reduced(emb_method=emb_method, cap=cap)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed)

    cluster_fn = None
    tracker = None
    if emb_method == "cce" and cluster_every:
        # the transition's k-means samples from the OBSERVED id
        # distribution (the paper's epoch-boundary sample), via the
        # SKETCH-backed tracker — exact heavy-hitter head + unbiased
        # sketch tail at vocab-independent memory (the production
        # configuration; the dense tracker is the test-scale reference)
        # — and the optimizer moments ride through the new assignments
        tracker = dlrm.make_id_tracker(
            cfg, dlrm_criteo.reduced_stream(window=0))

        def cluster_fn(key, params, buffers, opt):
            return dlrm.cluster_tables(key, params, buffers, cfg, opt,
                                       id_counts=tracker.counts)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state,
                 static, clickstream_batches(data_cfg, 64),
                 cluster_fn=cluster_fn, cluster_every=cluster_every,
                 cluster_max=3, id_tracker=tracker, seed=seed)
    tr.run(steps)
    # eval on held-out stream (host_id=1)
    test_iter = clickstream_batches(data_cfg, 512, host_id=1, n_hosts=2)
    batch = next(test_iter)
    from repro.train.loop import merge_buffers

    buffers = merge_buffers(tr.state.ebuf, tr.static_buffers)
    return float(dlrm.bce_loss(tr.state.params, buffers, cfg, batch))


@pytest.mark.slow
def test_cce_with_clustering_beats_without():
    """The paper's core mechanism: interleaved clustering helps."""
    seeds = [0, 1]
    with_c = np.mean([_train("cce", cluster_every=30, seed=s) for s in seeds])
    without = np.mean([_train("cce", cluster_every=0, seed=s) for s in seeds])
    assert with_c <= without + 0.005, (with_c, without)


@pytest.mark.slow
def test_compressed_tables_train_to_reasonable_bce():
    bce = _train("ce")
    assert bce < 0.69  # strictly better than predicting 0.5
    bce_hash = _train("hash")
    assert bce_hash < 0.69
