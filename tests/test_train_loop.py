"""Training loop: accumulation equivalence, fault tolerance, restart-exact
resume, straggler monitor, CCE clustering callback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.train.loop import (
    FailureInjector,
    StragglerMonitor,
    Trainer,
    init_state,
    make_train_step,
    merge_buffers,
    split_buffers,
)


def _setup(emb="cce", accum=1, seed=0):
    cfg = dlrm_criteo.reduced(emb_method=emb, cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static,
                           accum=accum)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 32 * accum
    )
    return cfg, step, state, static, data


def test_loss_decreases():
    cfg, step, state, static, data = _setup()
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data)
    hist = tr.run(40)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.01, (first, last)


def test_split_merge_roundtrip():
    cfg = dlrm_criteo.reduced()
    _, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)
    back = merge_buffers(dyn, static)
    assert jax.tree.structure(back) == jax.tree.structure(buffers)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(buffers)):
        if hasattr(a, "shape"):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert a == b


def test_grad_accum_equivalence():
    """accum=2 over the same 64 samples == accum=1 (up to float assoc)."""
    _, step1, state1, static, _ = _setup(accum=1)
    _, step2, state2, _, _ = _setup(accum=2)
    data = next(clickstream_batches(
        ClickstreamConfig(seed=3,
                          vocab_sizes=dlrm_criteo.reduced().vocab_sizes), 64))
    b1 = {k: np.asarray(v)[None] for k, v in data.items() if k != "step"}
    b2 = {k: np.asarray(v).reshape(2, 32, *np.asarray(v).shape[1:])
          for k, v in data.items() if k != "step"}
    s1, m1 = step1(state1, b1)
    s2, m2 = step2(state2, b2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_checkpoint_restart_exact(tmp_path):
    """Kill at step 7 (injected), restore, replay — final params bitwise
    equal to an uninterrupted run (deterministic data by (seed, step))."""
    def run(fail: bool):
        cfg, step, state, static, data = _setup(seed=1)
        tr = Trainer(
            jax.jit(step, donate_argnums=(0,)), state, static, data,
            ckpt_dir=str(tmp_path / ("a" if fail else "b")),
            ckpt_every=5,
            failures=FailureInjector((7,)) if fail else None,
        )
        if fail:
            with pytest.raises(RuntimeError):
                tr.run(12)
            # restart: restore + rebuild the data stream from the step
            restored = tr.restore_latest()
            assert restored == 5
            cfg2, step2, _, static2, _ = _setup(seed=1)
            data2 = clickstream_batches(
                ClickstreamConfig(vocab_sizes=cfg2.vocab_sizes, seed=1),
                32, start_step=restored,
            )
            tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), tr.state,
                          static2, data2, ckpt_dir=str(tmp_path / "a"))
            tr2.run(12 - restored)
            return tr2.state
        tr.run(12)
        return tr.state

    s_fail = run(True)
    s_clean = run(False)
    for a, b in zip(jax.tree.leaves(s_fail.params), jax.tree.leaves(s_clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cluster_callback_runs_and_training_continues():
    cfg, step, state, static, data = _setup(emb="cce")

    def cluster_fn(key, params, buffers):
        return dlrm.cluster_tables(key, params, buffers, cfg)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 cluster_fn=cluster_fn, cluster_every=10, cluster_max=2)
    hist = tr.run(25)
    assert tr.clusters_done == 2
    assert np.isfinite(hist[-1]["loss"])
    # training still improves after clustering
    assert np.mean([h["loss"] for h in hist[-5:]]) < np.mean(
        [h["loss"] for h in hist[:5]]) + 0.05


def test_train_state_donated_no_copy():
    """The jitted step donates the whole TrainState (params, moments,
    embedding buffers, step counter): every state leaf must carry an
    input-output alias in the lowered program — the in-place-update
    contract behind the single-launch hot path."""
    cfg, _, state, static, data = _setup()
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(
        loss_fn, opt, lambda s: jnp.float32(0.05), static, donate=True
    )
    batch = {
        k: np.asarray(v)[None] for k, v in next(data).items() if k != "step"
    }
    # every donated state buffer aliases an output in the lowering; the
    # DonationCoverage audit rule owns the aliasing-count check
    from repro.analysis import AuditProgram, DonationCoverage

    prog = AuditProgram.capture(
        step, state, batch, name="train_step", donate_argnums=(0,)
    )
    assert DonationCoverage().check(prog) == []
    # and the donated step still runs + matches the undonated math (up to
    # compilation-level float reassociation — donation changes the
    # program XLA sees, not the math)
    ref_step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    s_ref, m_ref = ref_step(state, batch)
    s_don, m_don = step(state, batch)
    np.testing.assert_allclose(float(m_don["loss"]), float(m_ref["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s_don.params), jax.tree.leaves(s_ref.params)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-7
        )


def _setup_sketch(in_step: bool, seed=0, accum=1, window=4):
    from repro.configs import dlrm_criteo as dc
    from repro.stream import make_step_cell_counter

    cfg = dc.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    tracker = dlrm.make_id_tracker(
        cfg, dc.reduced_stream(window=window, async_fold=True)
    )
    sketch_fn = make_step_cell_counter(tracker) if in_step else None
    step = make_train_step(
        loss_fn, opt, lambda s: jnp.float32(0.05), static,
        accum=accum, sketch_fn=sketch_fn, donate=True,
    )
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 32 * accum
    )
    return cfg, step, state, static, data, tracker


def test_in_step_sketch_delta_matches_dispatch_path():
    """The cell delta produced INSIDE the donated train step must leave
    the tracker in bit-identical state to the PR-4 standalone-dispatch
    path — and the tracker's own counter must never be dispatched."""
    _, step_a, state_a, static_a, data_a, tk_a = _setup_sketch(False)
    tr_a = Trainer(step_a, state_a, static_a, data_a, id_tracker=tk_a)
    tr_a.run(9)
    tk_a.flush()

    _, step_b, state_b, static_b, data_b, tk_b = _setup_sketch(True)

    def boom(*a, **k):
        raise AssertionError("tracker dispatched its own cell counter")

    tk_b._cell_counter = boom  # the in-step delta must make this dead code
    tr_b = Trainer(step_b, state_b, static_b, data_b, id_tracker=tk_b)
    tr_b.run(9)
    tk_b.flush()

    for a, b in zip(tk_a.state_tree(), tk_b.state_tree()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the training math is untouched by carrying the delta
    for a, b in zip(
        jax.tree.leaves(tr_a.state.params), jax.tree.leaves(tr_b.state.params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_in_step_sketch_delta_accumulates_over_microbatches():
    """accum > 1: the per-microbatch deltas sum across the scan, so the
    tracker sees the WHOLE batch exactly once (window=0: no decay, so
    the folded mass is exactly the id count)."""
    _, step1, state1, static1, data1, tk1 = _setup_sketch(True, accum=1, window=0)
    _, step2, state2, static2, data2, tk2 = _setup_sketch(True, accum=2, window=0)
    tr1 = Trainer(step1, state1, static1, data1, id_tracker=tk1, accum=1)
    tr2 = Trainer(step2, state2, static2, data2, id_tracker=tk2, accum=2)
    tr1.run(4)
    tr2.run(4)
    tk1.flush()
    tk2.flush()
    # accum=2 consumed 64-id batches vs accum=1's 32-id batches: compare
    # total folded mass instead of bitwise state (different streams)
    m1 = sum(tk1.features[f].mass for f in tk1.tracked)
    m2 = sum(tk2.features[f].mass for f in tk2.tracked)
    assert m1 == 4 * 32 * len(tk1.tracked)
    assert m2 == 4 * 64 * len(tk2.tracked)


def test_in_step_sketch_restart_exact(tmp_path):
    """Checkpoint resume with the in-step delta path: kill at step 7,
    restore, replay — params AND tracker state bitwise equal to the
    uninterrupted run."""

    def run(fail: bool):
        cfg, step, state, static, data, tracker = _setup_sketch(True, seed=2)
        tr = Trainer(
            step, state, static, data,
            ckpt_dir=str(tmp_path / ("a" if fail else "b")), ckpt_every=5,
            id_tracker=tracker,
            failures=FailureInjector((7,)) if fail else None,
        )
        if fail:
            with pytest.raises(RuntimeError):
                tr.run(12)
            restored = tr.restore_latest()
            assert restored == 5
            _, step2, _, static2, _, tracker2 = _setup_sketch(True, seed=2)
            tracker2.load_state_tree(tracker.state_tree())
            data2 = clickstream_batches(
                ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=2),
                32, start_step=restored,
            )
            tr2 = Trainer(
                step2, tr.state, static2, data2,
                ckpt_dir=str(tmp_path / "a"), id_tracker=tracker2,
            )
            tr2.run(12 - restored)
            return tr2.state, tracker2
        tr.run(12)
        return tr.state, tracker

    (s_fail, tk_fail), (s_clean, tk_clean) = run(True), run(False)
    for a, b in zip(jax.tree.leaves(s_fail.params), jax.tree.leaves(s_clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tk_fail.flush()
    tk_clean.flush()
    for a, b in zip(tk_fail.state_tree(), tk_clean.state_tree()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(warmup=3, k=3.0)
    for i in range(20):
        mon.observe(i, 0.10 + 0.001 * (i % 3))
    assert not mon.flagged
    assert mon.observe(20, 1.0)  # 10x outlier
    assert mon.flagged[-1][0] == 20
    # EMA not poisoned: next normal step is not flagged
    assert not mon.observe(21, 0.101)


def test_failure_injector_fires_once():
    fi = FailureInjector((3,))
    fi.maybe_fail(2)
    with pytest.raises(RuntimeError):
        fi.maybe_fail(3)
    fi.maybe_fail(3)  # second pass: already fired
