"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + gradients.

Kernels run in interpret mode on CPU (the brief's validation contract);
on TPU the same pallas_call compiles to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("c", [1, 2, 4])
@pytest.mark.parametrize("T", [1, 2])
@pytest.mark.parametrize("B,k,dsub", [(8, 16, 8), (33, 70, 24), (128, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cce_lookup_matches_ref(c, T, B, k, dsub, dtype):
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub)).astype(dtype)
    got = ops.cce_lookup(idx, tables)
    want = ref.cce_lookup_ref(idx, tables)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2,
    )


def test_cce_lookup_sentinel_rows_are_noops():
    """The -1 sentinel (a T=1 method riding a T=2 supertable, DESIGN.md
    §6): sentinel lanes contribute EXACTLY zero forward and receive
    EXACTLY zero gradient — so a fused single-sub-table method equals its
    plain gather bit for bit and its zero-padded helper slab stays zero."""
    key = jax.random.PRNGKey(3)
    c, B, T, k, dsub = 3, 33, 2, 70, 8
    rows0 = jax.random.randint(key, (c, B), 0, k)
    idx = jnp.stack([rows0, jnp.full((c, B), -1, jnp.int32)], axis=-1)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)

    got = ops.cce_lookup(idx, tables)
    # == the single-table gather, bitwise (adding exact zeros is exact)
    want = jax.vmap(lambda t, r: t[r])(tables[:, 0], rows0)  # (c, B, dsub)
    want = jnp.transpose(want, (1, 0, 2)).reshape(B, c * dsub)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the masked ref agrees
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.cce_lookup_ref(idx, tables))
    )
    # gradient: the sentinel sub-table gets exactly zero everywhere
    g = jax.grad(lambda t: jnp.sum(ops.cce_lookup(idx, t) ** 2))(tables)
    assert float(jnp.abs(g[:, 1]).max()) == 0.0
    assert float(jnp.abs(g[:, 0]).max()) > 0.0


def test_cce_lookup_single_table_T1():
    """T=1 (hash/CE/full tables fused without sentinel padding): the
    kernel is table-count-generic and matches the plain gather."""
    key = jax.random.PRNGKey(4)
    c, B, k, dsub = 5, 17, 40, 16
    idx = jax.random.randint(key, (c, B, 1), 0, k)
    tables = jax.random.normal(key, (c, 1, k, dsub), jnp.float32)
    got = ops.cce_lookup(idx, tables)
    want = jax.vmap(lambda t, r: t[r])(tables[:, 0], idx[..., 0])
    want = jnp.transpose(want, (1, 0, 2)).reshape(B, c * dsub)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    b=st.integers(1, 40), k=st.integers(2, 90), dsub=st.sampled_from([4, 8, 16])
)
@settings(max_examples=10, deadline=None)
def test_cce_lookup_hypothesis_shapes(b, k, dsub):
    key = jax.random.PRNGKey(1)
    idx = jax.random.randint(key, (2, b, 2), 0, k)
    tables = jax.random.normal(key, (2, 2, k, dsub), jnp.float32)
    got = ops.cce_lookup(idx, tables)
    want = ref.cce_lookup_ref(idx, tables)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_cce_lookup_padding_edges_combined_with_grad():
    """B not a multiple of b_blk AND k < k_blk SIMULTANEOUSLY, gradient
    included — the two padding paths compose: padded batch rows must not
    scatter into the gradient, padded codebook rows must stay zero-grad.
    (The parametrized sweep hits each edge separately; this pins the
    combination, with an explicit small b_blk so B spans multiple blocks
    plus a ragged remainder.)"""
    key = jax.random.PRNGKey(7)
    c, B, T, k, dsub = 3, 33, 2, 70, 8  # B=33 -> blocks of 16 + remainder
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)

    def fused(t):
        return ops.cce_lookup(idx, t, b_blk=16, k_blk=128)  # k 70 -> pad 128

    out = fused(tables)
    want = ref.cce_lookup_ref(idx, tables)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)

    co = jax.random.normal(jax.random.fold_in(key, 1), (B, c * dsub))
    g1 = jax.grad(lambda t: jnp.sum(fused(t) * co))(tables)
    g2 = jax.grad(lambda t: jnp.sum(ref.cce_lookup_ref(idx, t) * co))(tables)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)
    # the rows the padded batch elements alias (row 0) got no phantom mass:
    # exact agreement with the ref grad above already proves it; also check
    # total mass conservation explicitly
    np.testing.assert_allclose(
        float(np.abs(np.asarray(g1)).sum()), float(np.abs(np.asarray(g2)).sum()),
        rtol=1e-5,
    )


def test_cce_lookup_grad_is_scatter_add():
    """Backward = one-hot^T @ dout: compare against jax autodiff of the ref."""
    key = jax.random.PRNGKey(2)
    c, B, T, k, dsub = 2, 16, 2, 24, 8
    idx = jax.random.randint(key, (c, B, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)

    def loss_kernel(t):
        return (ops.cce_lookup(idx, t) ** 2).sum()

    def loss_ref(t):
        return (ref.cce_lookup_ref(idx, t) ** 2).sum()

    g1 = jax.grad(loss_kernel)(tables)
    g2 = jax.grad(loss_ref)(tables)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,k,d", [(16, 8, 4), (100, 33, 7), (256, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_matches_ref(n, k, d, dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (n, d)).astype(dtype)
    cen = jax.random.normal(jax.random.fold_in(key, 1), (k, d)).astype(dtype)
    got = ops.kmeans_assign(x, cen)
    want = ref.kmeans_assign_ref(x, cen)
    # ties can differ between argmin orders at low precision; check distances
    xf = np.asarray(x, np.float32)
    cf = np.asarray(cen, np.float32)
    d_got = ((xf - cf[np.asarray(got)]) ** 2).sum(-1)
    d_want = ((xf - cf[np.asarray(want)]) ** 2).sum(-1)
    np.testing.assert_allclose(d_got, d_want, rtol=2e-2, atol=1e-3)


def test_kmeans_assign_exact_f32():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (200, 16), jnp.float32)
    cen = jax.random.normal(jax.random.fold_in(key, 1), (40, 16), jnp.float32)
    got = np.asarray(ops.kmeans_assign(x, cen))
    want = np.asarray(ref.kmeans_assign_ref(x, cen))
    assert (got == want).mean() > 0.99  # float assoc. order may flip rare ties


def test_cce_logits_ref_consistency():
    """Factored logits oracle == brute-force embedding materialization."""
    key = jax.random.PRNGKey(5)
    c, V, T, k, dsub, B = 2, 50, 2, 12, 4, 3
    idx = jax.random.randint(key, (c, V, T), 0, k)
    tables = jax.random.normal(key, (c, T, k, dsub), jnp.float32)
    h = jax.random.normal(jax.random.fold_in(key, 1), (B, c * dsub), jnp.float32)
    E = ref.cce_lookup_ref(jnp.moveaxis(idx, 1, 1), tables)  # (V, c*dsub)
    want = h @ E.T
    got = ref.cce_logits_ref(h, idx, tables)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
