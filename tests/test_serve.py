"""Serving engine: decode correctness + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      dtype=jnp.float32, remat="none")
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, buffers


def _naive_greedy(cfg, params, buffers, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        lg, _ = lm.forward(params, buffers, cfg, {"tokens": jnp.asarray(toks)[None]})
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_naive_decode(model):
    cfg, params, buffers = model
    eng = ServeEngine(cfg, params, buffers, max_batch=2, max_seq=32)
    prompt = np.asarray([5, 17, 3], np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_tokens=5))
    out = eng.run()[0].generated
    assert out == _naive_greedy(cfg, params, buffers, prompt.tolist(), 5)


def test_continuous_batching_is_isolated(model):
    """Requests sharing a batch must produce the same output as alone."""
    cfg, params, buffers = model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 97, size=4 + i).astype(np.int32) for i in range(5)]
    solo = []
    for i, p in enumerate(prompts):
        e = ServeEngine(cfg, params, buffers, max_batch=1, max_seq=32)
        e.submit(Request(uid=i, prompt=p, max_tokens=4))
        solo.append(e.run()[0].generated)
    eng = ServeEngine(cfg, params, buffers, max_batch=3, max_seq=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert [r.generated for r in done] == solo


def test_eos_stops_generation(model):
    cfg, params, buffers = model
    prompt = np.asarray([5, 17, 3], np.int32)
    free = ServeEngine(cfg, params, buffers, max_batch=1, max_seq=32)
    free.submit(Request(uid=0, prompt=prompt, max_tokens=8))
    full = free.run()[0].generated
    eos = full[2]
    eng = ServeEngine(cfg, params, buffers, max_batch=1, max_seq=32)
    eng.submit(Request(uid=0, prompt=prompt, max_tokens=8, eos=eos))
    out = eng.run()[0].generated
    assert out == full[:3]


def test_queue_longer_than_batch(model):
    cfg, params, buffers = model
    eng = ServeEngine(cfg, params, buffers, max_batch=2, max_seq=32)
    rng = np.random.default_rng(2)
    for i in range(7):
        eng.submit(Request(uid=i, prompt=rng.integers(0, 97, 3).astype(np.int32),
                           max_tokens=3))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.generated) == 3 for r in done)


def test_prefill_compile_count_is_bucketed(model):
    """Mixed-length traffic must not compile one prefill per distinct
    prompt length: prompts pad to power-of-two buckets, so at most
    log2(max_seq) prefill programs exist — and bucketing must not change
    the generated tokens."""
    cfg, params, buffers = model
    max_seq = 32
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, 97, size=s).astype(np.int32)
        for s in range(1, 18)  # 17 distinct lengths spanning 4 buckets
    ]
    eng = ServeEngine(cfg, params, buffers, max_batch=4, max_seq=max_seq)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_tokens=3))
    done = sorted(eng.run(), key=lambda r: r.uid)
    assert len(done) == len(prompts)
    n_compiles = eng._prefill._cache_size()
    assert n_compiles <= int(np.log2(max_seq)), n_compiles
    # bucketed prefill is semantics-preserving: same tokens as solo runs
    for i in (0, 7, 16):
        solo = ServeEngine(cfg, params, buffers, max_batch=1, max_seq=max_seq)
        solo.submit(Request(uid=0, prompt=prompts[i], max_tokens=3))
        assert solo.run()[0].generated == done[i].generated
