"""Sharded lowering on a small forced-device-count mesh — the in-repo
guard for the full dry-run (which needs 512 devices and its own process).

Runs in a subprocess so the XLA device-count flag never leaks into the
test session (conftest asserts that).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro import compat, configs
from repro.launch import steps, hlo_cost

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
with compat.set_mesh(mesh):
    cfg = configs.get("qwen2-1.5b", n_layers=2, d_model=512, n_heads=4,
                      n_kv_heads=2, head_dim=128, d_ff=1024, vocab=4096,
                      emb_budget=4096*512//8, train_microbatch=2)
    jitted, (state_shape, batch_sds), _ = steps.build_train_step(cfg, mesh, "train_4k")
    compiled = jitted.lower(state_shape, batch_sds).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    out["train"] = {"flops": cost.flops, "coll": cost.coll,
                    "ici": cost.ici_bytes}
    jitted, args = steps.build_serve_step(cfg, mesh, "decode_32k")
    compiled = jitted.lower(*args).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    out["decode"] = {"flops": cost.flops, "coll": cost.coll}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_lowering_end_to_end():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["train"]["flops"] > 1e9
    assert "all-reduce" in out["train"]["coll"] or "reduce-scatter" in out["train"]["coll"]
    assert out["decode"]["flops"] > 0
