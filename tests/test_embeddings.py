"""The unified sketch framework: every method satisfies the same contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import embeddings as E

D1, D2, BUDGET = 400, 16, 1600
METHODS = ["hash", "hemb", "ce", "robe", "dhe", "tt", "cce", "full"]


def make(method):
    return E.make_table(method, D1, D2, budget=BUDGET)


@pytest.mark.parametrize("method", METHODS)
def test_lookup_contract(method):
    t = make(method)
    params, buffers = t.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([0, 1, 5, D1 - 1])
    out = t.lookup(params, buffers, ids)
    assert out.shape == (4, D2)
    assert bool(jnp.isfinite(out).all())
    # deterministic
    out2 = t.lookup(params, buffers, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


@pytest.mark.parametrize("method", [m for m in METHODS if m != "full"])
def test_budget_respected(method):
    t = make(method)
    assert t.n_params <= 1.05 * BUDGET, (method, t.n_params)


@pytest.mark.parametrize("method", METHODS)
def test_logits_equal_materialized(method):
    t = make(method)
    params, buffers = t.init(jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (3, D2))
    ids = jnp.arange(D1)
    Emat = t.lookup(params, buffers, ids)  # (D1, D2)
    want = h @ Emat.T
    got = t.logits(params, buffers, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("method", ["hash", "hemb", "ce"])
def test_sketch_framework_T_equals_HM(method):
    """Section 2.1: lookup(i) == (e_i H) M for the linear-sketch methods."""
    t = make(method)
    params, buffers = t.init(jax.random.PRNGKey(0))
    H = t.sketch_matrix(buffers)  # (d1, k')
    if method in ("hash", "hemb"):
        M = np.asarray(params["M"])
    else:  # ce: block-diagonal M
        c, k, dsub = params["tables"].shape
        M = np.zeros((c * k, D2), np.float32)
        for i in range(c):
            M[i * k:(i + 1) * k, i * dsub:(i + 1) * dsub] = np.asarray(
                params["tables"][i]
            )
    T = H @ M
    got = np.asarray(t.lookup(params, buffers, jnp.arange(D1)))
    np.testing.assert_allclose(got, T, rtol=1e-4, atol=1e-5)


def test_cce_sketch_matrix_rows():
    t = make("cce")
    params, buffers = t.init(jax.random.PRNGKey(0))
    H = t.sketch_matrix(buffers)
    # one 1 in the main block and one in the helper block per (row, column)
    assert H.shape == (D1, t.c * 2 * t.k)
    assert np.allclose(H.sum(axis=1), 2 * t.c)


@pytest.mark.parametrize("method", METHODS)
def test_gradients_flow(method):
    t = make(method)
    params, buffers = t.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([1, 2, 3])

    def loss(p):
        return (t.lookup(p, buffers, ids) ** 2).sum()

    g = jax.grad(loss)(params)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
