import os

# smoke tests run on the single real CPU device — the 512-device forcing
# belongs ONLY to launch/dryrun.py (see the brief); make sure it never leaks
# into the test environment.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "tests must see 1 device; unset XLA_FLAGS"
)

import jax

jax.config.update("jax_default_matmul_precision", "highest")
