import os
import sys

# smoke tests run on the single real CPU device by default — the 512-device
# forcing belongs ONLY to launch/dryrun.py (see the brief); make sure it never
# leaks into the test environment by ACCIDENT.  The multi-device tier-1 CI job
# opts in explicitly (REPRO_MULTIDEVICE=1 + a small forced device count) so
# the shard_map paths run against real multi-device meshes on every PR.
if not os.environ.get("REPRO_MULTIDEVICE"):
    assert "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""
    ), "tests must see 1 device; unset XLA_FLAGS (or set REPRO_MULTIDEVICE=1)"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # property tests prefer real hypothesis (declared in pyproject [test])
    import hypothesis  # noqa: F401
except ImportError:  # hermetic container: deterministic fallback shim
    from repro.testing import hypothesis_fallback

    sys.modules["hypothesis"] = hypothesis_fallback
    sys.modules["hypothesis.strategies"] = hypothesis_fallback.strategies

import jax

jax.config.update("jax_default_matmul_precision", "highest")
