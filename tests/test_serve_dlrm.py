"""DLRM serve engine: cache-vs-supertable bit-exactness, launch counts,
staleness enforcement, churn refresh, micro-batching — DESIGN.md §11."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dlrm_criteo import reduced, reduced_stream
from repro.models import dlrm
from repro.obs.runlog import RunLog, read_runlog
from repro.serve.dlrm import (
    DLRMServeEngine,
    HotCache,
    MicroBatcher,
    ServeRequest,
    StaleCacheError,
)
from repro.stream.trigger import head_churn

B = 8


@pytest.fixture(scope="module")
def state():
    cfg = reduced()
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    tracker = dlrm.make_id_tracker(cfg, reduced_stream())
    rng = np.random.default_rng(0)
    # warm the sketches so the SpaceSaving heads are populated
    warm = np.stack(
        [rng.integers(0, v, 256) for v in cfg.vocab_sizes], axis=1
    )
    tracker.observe({"sparse": warm})
    # jit-compiled reference: the serve programs are jitted, and XLA
    # fusion may round differently from the eager path — jit vs jit is
    # the bit-exactness contract
    fwd = jax.jit(
        lambda p, b, batch: dlrm.forward(p, b, cfg, batch),
    )

    def ref(p, b, dense, sparse):
        return np.asarray(
            fwd(p, b, {"dense": jnp.asarray(dense), "sparse": jnp.asarray(sparse)})
        )

    return cfg, params, buffers, tracker, warm, ref


def _engine(state, **kw):
    cfg, params, buffers, tracker, _, _ = state
    kw.setdefault("tracker", tracker)
    kw.setdefault("max_batch", B)
    kw.setdefault("use_kernel", False)
    return DLRMServeEngine(params, buffers, cfg, **kw)


def _batch(state, rng, *, head=False):
    cfg = state[0]
    dense = rng.normal(size=(B, cfg.n_dense)).astype(np.float32)
    if head:
        return dense, None
    sparse = np.stack(
        [rng.integers(0, v, B) for v in cfg.vocab_sizes], axis=1
    )
    return dense, sparse


def _head_batch(cache, cfg, n):
    """ids drawn entirely from the cached head -> fully-hit batch."""
    cols = []
    for f in range(cfg.n_sparse):
        ids = cache.ids.get(f)
        assert ids is not None and ids.size, f"feature {f} not cached"
        cols.append(ids[np.arange(n) % ids.size])
    return np.stack(cols, axis=1)


def _miss_batch(cache, cfg, rng, n):
    """every id OUTSIDE the cached head -> fully-cold batch."""
    cols = []
    for f, v in enumerate(cfg.vocab_sizes):
        cand = np.setdiff1d(np.arange(v), cache.ids.get(f, np.empty(0)))
        cols.append(cand[rng.integers(0, cand.size, n)])
    return np.stack(cols, axis=1)


def test_hit_batch_is_exact_and_launch_free(state):
    cfg, params, buffers = state[0], state[1], state[2]
    eng = _engine(state)
    rng = np.random.default_rng(1)
    dense, _ = _batch(state, rng, head=True)
    sparse = _head_batch(eng.cache, cfg, B)
    got = eng.predict(dense, sparse)
    assert np.array_equal(got, state[5](params, buffers, dense, sparse))
    assert eng.counters["n_launches"] == 0
    assert eng.counters["n_hit_batches"] == 1
    assert eng.counters["n_id_hits"] == B * cfg.n_sparse


def test_mixed_batch_is_exact_with_one_launch(state):
    cfg, params, buffers = state[0], state[1], state[2]
    eng = _engine(state)
    rng = np.random.default_rng(2)
    dense, _ = _batch(state, rng, head=True)
    sparse = _head_batch(eng.cache, cfg, B)
    sparse[::2] = _miss_batch(eng.cache, cfg, rng, B)[::2]
    got = eng.predict(dense, sparse)
    assert np.array_equal(got, state[5](params, buffers, dense, sparse))
    assert eng.counters["n_launches"] == 1
    # half the requests answered purely from cache
    assert 0 < eng.counters["n_id_hits"] < B * cfg.n_sparse


def test_uncached_engine_matches_forward(state):
    cfg, params, buffers = state[0], state[1], state[2]
    eng = _engine(state, cache=False)
    rng = np.random.default_rng(3)
    dense, sparse = _batch(state, rng)
    got = eng.predict(dense, sparse)
    assert np.array_equal(got, state[5](params, buffers, dense, sparse))
    assert eng.counters["n_launches"] == 1
    assert eng.counters["n_id_hits"] == 0


def test_ragged_batch_pads_to_bucket(state):
    cfg, params, buffers = state[0], state[1], state[2]
    eng = _engine(state)
    rng = np.random.default_rng(4)
    dense, sparse = _batch(state, rng)
    n = 3  # < max_batch: engine pads to the bucket, answers stay exact
    got = eng.predict(dense[:n], sparse[:n])
    assert got.shape == (n,)
    assert np.array_equal(
        got, state[5](params, buffers, dense[:n], sparse[:n])
    )


def test_cache_exact_across_clustering_transition(state):
    cfg, params, buffers, tracker = state[:4]
    eng = _engine(state)
    p2, b2 = dlrm.cluster_tables(
        jax.random.PRNGKey(7), params, buffers, cfg,
        id_counts=tracker.counts, use_kernel=False,
    )
    eng.update_state(p2, b2)  # refreshes the cache at the transition
    rng = np.random.default_rng(5)
    dense, _ = _batch(state, rng, head=True)
    sparse = _head_batch(eng.cache, cfg, B)
    got = eng.predict(dense, sparse)
    assert np.array_equal(got, state[5](p2, b2, dense, sparse))
    assert eng.counters["n_refreshes"] == 2  # init + transition


def test_stale_cache_is_refused_not_served(state):
    cfg, params, buffers, tracker = state[:4]
    eng = _engine(state)
    sparse = _head_batch(eng.cache, cfg, B)
    dense = np.zeros((B, cfg.n_dense), np.float32)
    p2, b2 = dlrm.cluster_tables(
        jax.random.PRNGKey(8), params, buffers, cfg,
        id_counts=tracker.counts, use_kernel=False,
    )
    # serving across the transition WITHOUT a refresh must raise: the
    # cache still holds pre-transition decoded rows
    eng.update_state(p2, b2, refresh_cache=False)
    with pytest.raises(StaleCacheError):
        eng.predict(dense, sparse)
    # an explicit refresh clears the condition
    eng.refresh_cache()
    got = eng.predict(dense, _head_batch(eng.cache, cfg, B))
    assert got.shape == (B,)


def test_head_churn_triggers_refresh(state):
    cfg, params, buffers = state[0], state[1], state[2]
    # private tracker: this test mutates head state
    tracker = dlrm.make_id_tracker(cfg, reduced_stream())
    rng = np.random.default_rng(6)
    lo = np.stack([rng.integers(0, 50, 512) for _ in cfg.vocab_sizes], 1)
    tracker.observe({"sparse": lo})
    eng = _engine(state, tracker=tracker)
    old_ids = {f: ids.copy() for f, ids in eng.cache.ids.items()}
    assert eng.maybe_refresh() == pytest.approx(0.0)  # no churn yet
    assert eng.counters["n_refreshes"] == 1
    # hammer a disjoint id range until the SpaceSaving head turns over
    hi = np.stack(
        [50 + rng.integers(0, 50, 4096) for _ in cfg.vocab_sizes], 1
    )
    tracker.observe({"sparse": hi})
    churn = eng.maybe_refresh()
    assert churn is not None and churn >= eng.churn_threshold
    assert eng.counters["n_refreshes"] == 2
    assert any(
        not np.array_equal(eng.cache.ids[f], old_ids[f]) for f in old_ids
    )
    # post-refresh answers are exact on the NEW head
    dense = np.zeros((B, cfg.n_dense), np.float32)
    sparse = _head_batch(eng.cache, cfg, B)
    assert np.array_equal(
        eng.predict(dense, sparse),
        state[5](params, buffers, dense, sparse),
    )


def test_microbatcher_latency_budget():
    t = [0.0]
    mb = MicroBatcher(max_batch=4, latency_budget_s=0.010, clock=lambda: t[0])
    r = lambda i: ServeRequest(uid=i, dense=np.zeros(2), sparse=np.zeros(3))
    mb.submit(r(0))
    assert not mb.ready()  # under budget, under max_batch: hold
    t[0] = 0.005
    assert not mb.ready()
    t[0] = 0.011  # oldest request exceeded the budget: dispatch
    assert mb.ready()
    assert [q.uid for q in mb.take()] == [0]
    for i in range(1, 6):
        mb.submit(r(i))
    assert mb.ready()  # full batch dispatches immediately
    assert len(mb.take()) == 4
    assert len(mb) == 1


def test_request_path_events_and_histograms(state, tmp_path):
    cfg, params, buffers = state[0], state[1], state[2]
    log_path = tmp_path / "serve.jsonl"
    with RunLog(log_path, manifest={"config": "serve-test"}) as rl:
        eng = _engine(state, run_log=rl, latency_budget_s=0.0)
        rng = np.random.default_rng(9)
        dense, sparse = _batch(state, rng)
        hit_sparse = _head_batch(eng.cache, cfg, B)
        for i in range(B):
            eng.submit(ServeRequest(uid=i, dense=dense[i], sparse=hit_sparse[i]))
        results = eng.drain()
        for i in range(3):
            eng.submit(
                ServeRequest(uid=B + i, dense=dense[i], sparse=sparse[i])
            )
        results += eng.drain()
        stats = eng.flush_stats()
    assert len(results) == B + 3
    assert all(r.cache_hit for r in results[:B])
    assert stats["n_requests"] == B + 3
    assert 0 < stats["hit_rate_requests"] <= 1
    assert stats["launches_per_batch"] < 1.0  # hit batches skipped theirs
    recs = read_runlog(log_path)
    reqs = [r for r in recs if r["event"] == "request"]
    assert len(reqs) == B + 3
    assert all("cache_hit" in r and r["latency_s"] >= 0 for r in reqs)
    refreshes = [r for r in recs if r["event"] == "cache_refresh"]
    assert [r["reason"] for r in refreshes] == ["init"]
    hists = [r for r in recs if r["event"] == "latency_hist"]
    assert {h["label"] for h in hists} == {
        "serve-dlrm", "serve-dlrm-hit", "serve-dlrm-cold",
    }
    # the jax-free summarizer picks up the serve-cache sections
    from repro.obs.summary import format_summary, summarize_dict

    s = summarize_dict(recs)
    assert s["serve_cache"]["n_requests"] == B + 3
    assert s["cache_refreshes"][0]["reason"] == "init"
    assert "serve cache:" in format_summary(recs)


def test_logits_identical_with_and_without_cache(state):
    """The cache is a pure latency optimization: cached and uncached
    engines agree bitwise on identical traffic."""
    cfg = state[0]
    cached, uncached = _engine(state), _engine(state, cache=False)
    rng = np.random.default_rng(10)
    dense, sparse = _batch(state, rng)
    sparse[:4] = _head_batch(cached.cache, cfg, 4)
    assert np.array_equal(
        cached.predict(dense, sparse), uncached.predict(dense, sparse)
    )


def test_rows_masked_masks_exactly_the_hit_features(state):
    cfg = state[0]
    eng = _engine(state)
    rng = np.random.default_rng(11)
    _, sparse = _batch(state, rng)
    coll = cfg.collection
    skip = rng.random((B, cfg.n_sparse)) < 0.5
    rows = eng.translator.rows(sparse)
    masked = eng.translator.rows_masked(sparse, skip)
    col_owner = coll.rows_col_feature
    assert col_owner.shape == (coll.rows_n_cols,)
    for b in range(B):
        for c in range(coll.rows_n_cols):
            if skip[b, col_owner[c]]:
                assert (masked[b, c] == -1).all()
            else:
                assert np.array_equal(masked[b, c], rows[b, c])


def test_head_churn_metric():
    assert head_churn(np.array([1, 2, 3]), np.array([3, 2, 1])) == 0.0
    assert head_churn(np.array([1, 2]), np.array([3, 4])) == 1.0
    assert head_churn(np.array([1, 2, -1]), np.array([2, 3])) == pytest.approx(
        2 / 3
    )
    assert head_churn(np.array([]), np.array([])) == 0.0
    assert head_churn(np.array([]), np.array([1])) == 1.0


def test_export_heads_names_the_hot_ids(state):
    cfg, _, _, tracker = state[:4]
    heads = tracker.export_heads()
    assert set(heads) == set(tracker.tracked)
    capped = tracker.export_heads(4)
    for f, ids in heads.items():
        assert ids.size > 0
        assert capped[f].size <= 4
        assert np.array_equal(capped[f], ids[:4])


def test_hot_cache_build_drops_bad_ids(state):
    cfg, params, buffers = state[0], state[1], state[2]
    coll = cfg.collection
    cache = HotCache.build(
        coll, params["emb"], buffers["emb"],
        {0: np.array([5, 5, -3, 10**9, 2])},
    )
    assert np.array_equal(cache.ids[0], [2, 5])
    assert cache.n_slots == 2
    slots, hit = cache.slots(np.array([[5, 0, 0, 0, 0], [7, 0, 0, 0, 0]]))
    assert hit[0, 0] and not hit[1, 0]
    assert slots[0, 0] == 1 and slots[1, 0] == -1