"""Algorithm 3 (CCE) behavioural invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cce import CCE


@pytest.fixture(scope="module")
def cce_and_state():
    cce = CCE(d1=600, d2=16, k=16, c=4)
    params, buffers = cce.init(jax.random.PRNGKey(0))
    return cce, params, buffers


def test_lookup_is_sum_of_two_tables(cce_and_state):
    cce, params, buffers = cce_and_state
    ids = jnp.arange(20)
    out = cce.lookup(params, buffers, ids)
    rows = cce._rows(buffers, ids)
    for i, cid in enumerate([0, 7, 19]):
        for col in range(cce.c):
            main = params["tables"][col, 0, rows[col, cid, 0]]
            helper = params["tables"][col, 1, rows[col, cid, 1]]
            np.testing.assert_allclose(
                np.asarray(out[cid, col * cce.dsub:(col + 1) * cce.dsub]),
                np.asarray(main + helper), rtol=1e-6,
            )


def test_logits_match_materialized_table(cce_and_state):
    cce, params, buffers = cce_and_state
    h = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
    E = cce.lookup(params, buffers, jnp.arange(cce.d1))
    np.testing.assert_allclose(
        np.asarray(cce.logits(params, buffers, h)),
        np.asarray(h @ E.T), rtol=1e-4, atol=1e-4,
    )


def test_cluster_resets_helper_and_advances_epoch(cce_and_state):
    cce, params, buffers = cce_and_state
    p2, b2 = cce.cluster(jax.random.PRNGKey(2), params, buffers)
    assert b2["epoch"] == buffers["epoch"] + 1
    # Alg. 3 line 17: helper tables zeroed
    assert float(jnp.abs(p2["tables"][:, 1]).max()) == 0.0
    # fresh helper hash functions
    assert not np.array_equal(np.asarray(b2["hs"]), np.asarray(buffers["hs"]))
    # pointers in range
    ptr = np.asarray(b2["ptr"])
    assert ptr.min() >= 0 and ptr.max() < cce.k


def test_cluster_preserves_embeddings_approximately(cce_and_state):
    """Clustering replaces each embedding by its centroid: the new table
    should be close to the old one in mean squared error relative to
    variance (k-means quality), and embeddings of ids in the same cluster
    become identical per column."""
    cce, params, buffers = cce_and_state
    E_old = np.asarray(cce.lookup(params, buffers, jnp.arange(cce.d1)))
    p2, b2 = cce.cluster(jax.random.PRNGKey(3), params, buffers)
    E_new = np.asarray(cce.lookup(p2, b2, jnp.arange(cce.d1)))
    mse = ((E_old - E_new) ** 2).mean()
    var = E_old.var()
    assert mse < var  # better than collapsing to the mean
    # same-cluster ids share the main vector per column (helper is zero)
    ptr = np.asarray(b2["ptr"])
    col = 0
    same = np.where(ptr[col] == ptr[col][0])[0][:5]
    sub = E_new[same, :cce.dsub]
    assert np.allclose(sub, sub[0])


def test_collapse_entropies_detect_collapse():
    cce = CCE(d1=500, d2=8, k=8, c=2)
    params, buffers = cce.init(jax.random.PRNGKey(0))
    ent = cce.collapse_entropies(buffers)
    assert ent["H1"] > 0.8 * np.log(cce.k)  # random init: healthy
    # simulate column collapse
    bad = dict(buffers, ptr=jnp.zeros_like(buffers["ptr"]))
    ent_bad = cce.collapse_entropies(bad)
    assert ent_bad["H1"] == 0.0
    # simulate pairwise collapse (col 1 = col 0)
    pair = dict(buffers, ptr=jnp.stack([buffers["ptr"][0], buffers["ptr"][0]]))
    ent_pair = cce.collapse_entropies(pair)
    assert ent_pair["H2"] < ent["H2"] - 0.5


@given(st.integers(2, 64), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_from_budget_respects_budget(k_budget_mult, c):
    d1, d2 = 1000, 16
    budget = k_budget_mult * 2 * d2 * 4
    cce = CCE.from_budget(d1, d2, budget, c=min(c, 4) if d2 % min(c, 4) == 0 else 1)
    assert cce.n_params <= budget or cce.k == 1


def test_cluster_recovers_planted_structure():
    """Ids planted in G groups with identical 'true' embeddings: after one
    training-free cluster step on a table initialized AT the true values,
    same-group ids should map to the same pointer (per column, mostly)."""
    G, per, d2 = 8, 25, 8
    d1 = G * per
    rng = np.random.default_rng(0)
    true = rng.normal(size=(G, d2)).astype(np.float32)
    cce = CCE(d1=d1, d2=d2, k=8, c=2)
    params, buffers = cce.init(jax.random.PRNGKey(0))
    # force the current embeddings to the planted ones: main table rows are
    # the true group vectors, ptr maps id -> its group's row
    group_of = np.repeat(np.arange(G), per)
    tables = np.zeros((2, 2, 8, d2 // 2), np.float32)
    tables[0, 0] = true[:, : d2 // 2]
    tables[1, 0] = true[:, d2 // 2 :]
    params = {"tables": jnp.asarray(tables)}
    buffers = dict(buffers, ptr=jnp.asarray(np.stack([group_of, group_of])))
    p2, b2 = cce.cluster(jax.random.PRNGKey(1), params, buffers)
    ptr = np.asarray(b2["ptr"])
    for col in range(2):
        # same planted group -> same cluster (pointer purity)
        for g in range(G):
            vals = ptr[col][group_of == g]
            purity = (vals == np.bincount(vals).argmax()).mean()
            assert purity > 0.99
