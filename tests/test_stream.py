"""The streaming-statistics subsystem (repro/stream/, DESIGN.md §5):

* count-min sketch invariants — conservative-update overestimate, the
  count-mean unbiased tail estimator, device-cell/host-cell agreement,
* SpaceSaving head — top ids of a Zipf stream tracked with exact counts,
* decay/window semantics — estimates scale, recency wins,
* the k-means point provider — exact head + HT tail, float-count
  cleanliness (satellite: no silent int truncation on decayed counts),
  and the property test that the HT subsample stays unbiased under decay,
* tracker memory — O(sketch), independent of vocabulary, asserted at a
  10M-row config,
* trigger policy edge cases — empty stream, single-id stream, exactly
  one fire per collapse, drift firing, restart-exact trigger state,
* Trainer integration — adaptive transitions, restart-exact resume with
  sketch + trigger, and legacy DENSE id_counts checkpoints migrating
  into the sketch tracker bit-for-bit on the head ids.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.optim import sgd
from repro.stream import (
    ClusterTrigger,
    CountMinSketch,
    FeatureSketch,
    IdFrequencyTracker,
    SketchFrequencyTracker,
    StreamConfig,
    points_from_counts,
    sample_from_counts,
)
from repro.train.loop import (
    FailureInjector,
    Trainer,
    init_state,
    make_train_step,
    split_buffers,
)


def _zipf_stream(vocab=50_000, n=60_000, a=1.3, seed=0):
    return np.random.default_rng(seed).zipf(a, n) % vocab


# --- count-min sketch ---------------------------------------------------------


def test_cms_conservative_overestimate_invariant():
    ids = _zipf_stream()
    cms = CountMinSketch(width=1 << 10, depth=4, seed=3)
    for lo in range(0, ids.size, 4096):
        u, c = np.unique(ids[lo : lo + 4096], return_counts=True)
        cms.add(u, c)
    true = np.bincount(ids)
    probe = np.unique(ids)[:2000]
    est = cms.estimate(probe)
    assert (est >= true[probe] - 1e-9).all()  # never underestimates
    assert cms.total == pytest.approx(ids.size)


def test_cms_corrected_estimator_beats_min_on_tail():
    """At narrow width (heavy collision pressure) the collision-corrected
    estimate must carry LESS tail bias than the min-estimate, on both the
    conservative-update (host) and plain-add (device fold) paths — and
    never exceed the min upper bound."""
    ids = _zipf_stream()
    true = np.bincount(ids)
    u = np.unique(ids)
    tail = u[true[u] <= 3]

    def errs(cms):
        e_min = float(np.mean(cms.estimate(tail) - true[tail]))
        e_ub = float(np.mean(cms.estimate_unbiased(tail) - true[tail]))
        assert (cms.estimate_unbiased(tail) <= cms.estimate(tail) + 1e-9).all()
        return e_min, e_ub

    cu = CountMinSketch(width=1 << 10, depth=4, seed=3)
    for lo in range(0, ids.size, 2048):
        uu, cc = np.unique(ids[lo : lo + 2048], return_counts=True)
        cu.add(uu, cc)
    e_min, e_ub = errs(cu)
    assert abs(e_ub) < abs(e_min)

    plain = CountMinSketch(width=1 << 10, depth=4, seed=3)
    cells = plain.cells(ids)
    delta = np.zeros((4, 1 << 10))
    for r in range(4):
        np.add.at(delta[r], cells[r], 1)
    plain.add_cells(delta)
    e_min, e_ub = errs(plain)
    assert abs(e_ub) < abs(e_min)


def test_cms_device_cells_match_host_cells():
    from repro.stream.device import make_cell_counter

    cms = CountMinSketch(width=1 << 9, depth=3, seed=7)
    counter = make_cell_counter([cms])
    ids = np.random.default_rng(1).integers(0, 1_000_000, 4096)
    delta = np.asarray(counter(jnp.asarray(ids[:, None], jnp.int32)))[0]
    ref = np.zeros((3, 1 << 9), np.int64)
    cells = cms.cells(ids)
    for r in range(3):
        np.add.at(ref[r], cells[r], 1)
    np.testing.assert_array_equal(ref, delta)
    # and folding the delta gives the plain-CMS state: estimate still an
    # overestimate of every id's true count
    cms.add_cells(delta)
    true = np.bincount(ids)
    probe = np.unique(ids)
    assert (cms.estimate(probe) >= true[probe]).all()


# --- heavy hitters ------------------------------------------------------------


def test_spacesaving_head_is_exact_on_zipf_top():
    ids = _zipf_stream(seed=5)
    fs = FeatureSketch(width=1 << 11, depth=4, heavy=64, ring=2048, seed=0)
    for lo in range(0, ids.size, 2048):
        fs.observe(ids[lo : lo + 2048])
    true = np.bincount(ids)
    top = np.argsort(true)[::-1][:16]
    h_ids, h_cnt = fs.hh.head()
    assert np.isin(top, h_ids).all()  # the true top-16 are all resident
    lut = dict(zip(h_ids.tolist(), h_cnt.tolist()))
    for i in top.tolist():  # ...with their EXACT stream counts
        assert lut[i] == true[i]
    # estimates never underestimate, resident or not
    probe = np.unique(ids)[:1000]
    assert (fs.estimate(probe) >= true[probe] - 1e-9).all()


def test_decay_scales_and_recency_wins():
    fs = FeatureSketch(width=1 << 10, depth=4, heavy=8, ring=256, seed=0)
    old = np.repeat(np.arange(8), 50)  # old regime: ids 0..7, 50x each
    fs.observe(old)
    before = fs.estimate(np.arange(8)).copy()
    fs.decay(0.5)
    np.testing.assert_allclose(fs.estimate(np.arange(8)), before * 0.5)
    assert fs.mass == pytest.approx(old.size * 0.5)
    # new regime: ids 100..107 dominate after a few decayed windows
    for _ in range(6):
        fs.observe(np.repeat(np.arange(100, 108), 50))
        fs.decay(0.5)
    h_ids, _ = fs.hh.head()
    assert np.isin(np.arange(100, 108), h_ids).all()
    new_w = fs.estimate(np.arange(100, 108)).min()
    old_w = fs.estimate(np.arange(8)).max()
    assert new_w > old_w  # the histogram tracks the RECENT stream


# --- point sets (float counts, HT unbiasedness) -------------------------------


def test_float_counts_are_not_truncated():
    # decayed histogram summing to < 1: int() truncation used to turn
    # this into "nothing observed"
    counts = np.zeros(50)
    counts[[3, 30]] = [0.4, 0.3]
    s = sample_from_counts(counts, 100, seed=0)
    assert s is not None and set(np.unique(s)) <= {3, 30}
    ids, w = points_from_counts(counts, 10, seed=0)
    np.testing.assert_array_equal(ids, [3, 30])
    np.testing.assert_allclose(w, [0.4, 0.3], rtol=1e-6)
    assert sample_from_counts(np.zeros(4), 10, 0) is None
    assert points_from_counts(np.zeros(4), 10, 0) is None


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1.0, 0.9, 0.5, 0.25]))
def test_ht_tail_estimator_unbiased_under_decay(seed, gamma):
    """E_seed[total HT-subsampled weight] == the total decayed (float)
    mass — the stratified head + inverse-probability-inflated tail stays
    unbiased whatever the decay did to the counts."""
    rng = np.random.default_rng(seed)
    counts = rng.zipf(1.5, 400).astype(np.float64)
    counts *= gamma ** rng.integers(0, 12, 400)  # per-id decayed floats
    dense = np.zeros(4000)
    dense[rng.choice(4000, 400, replace=False)] = counts
    tots = [
        points_from_counts(dense, 40, seed=s)[1].sum()
        for s in range(60)
    ]
    np.testing.assert_allclose(np.mean(tots), dense.sum(), rtol=0.1)


def test_sketch_points_head_exact_tail_ht():
    ids = _zipf_stream(vocab=5000, n=40_000, seed=2)
    fs = FeatureSketch(width=1 << 11, depth=4, heavy=32, ring=4096, seed=0)
    for lo in range(0, ids.size, 4096):
        fs.observe(ids[lo : lo + 4096])
    true = np.bincount(ids, minlength=5000)
    pts, w = fs.points(64, seed=9)
    assert pts.size == 64 and np.unique(pts).size == 64
    # the n/2 head comes from the exact heavy-hitter counters
    top = np.argsort(true)[::-1][:16]
    assert np.isin(top, pts).all()
    lut = dict(zip(pts.tolist(), w.tolist()))
    for i in top.tolist():
        assert lut[i] == true[i]
    # deterministic by seed
    pts2, w2 = fs.points(64, seed=9)
    np.testing.assert_array_equal(pts, pts2)
    np.testing.assert_array_equal(w, w2)
    # under the cap: every head + ring candidate, no sampling
    few = FeatureSketch(width=1 << 8, depth=4, heavy=8, ring=64, seed=0)
    few.observe(np.asarray([5, 5, 9]))
    pts3, w3 = few.points(100, seed=0)
    np.testing.assert_array_equal(pts3, [5, 9])
    assert lut is not None and few.points(100, seed=1)[1][0] == 2.0


def test_sketch_id_weights_dense_view():
    fs = FeatureSketch(width=1 << 10, depth=4, heavy=16, ring=512, seed=0)
    fs.observe(np.repeat([3, 7, 11], [30, 20, 10]))
    w = fs.id_weights(100)
    assert w.shape == (100,) and w.dtype == np.float32
    assert w[3] == 30.0 and w[7] == 20.0 and w[11] == 10.0  # exact head


# --- tracker: memory, state, async --------------------------------------------


def test_tracker_memory_independent_of_vocab():
    """The acceptance criterion: O(width·depth + heavy + ring) state,
    asserted at a 10M-row config against a 1k-row config."""
    scfg = StreamConfig(width=1 << 12, depth=4, heavy=256, ring=4096)
    small = SketchFrequencyTracker((1000, 1000), scfg)
    big = SketchFrequencyTracker((10_000_000, 10_000_000), scfg)
    assert big.nbytes == small.nbytes
    per_feature = (
        scfg.width * scfg.depth * 8 + scfg.heavy * 16 + scfg.ring * 8
        + 2 * scfg.depth * 4  # hash coefficients
    )
    assert big.nbytes == 2 * per_feature
    # no state leaf scales with the vocabulary either
    assert all(leaf.size < 10_000_000 // 100 for leaf in big.state_tree())
    # ...and the full-Criteo factory config stays a few dozen MB
    tr = dlrm.make_id_tracker(dlrm_criteo.CONFIG, dlrm_criteo.STREAM)
    assert tr.nbytes < 64e6 < sum(dlrm_criteo.CONFIG.vocab_sizes) * 8


def test_tracker_state_roundtrip_and_windows():
    scfg = StreamConfig(width=1 << 9, depth=3, heavy=16, ring=128,
                        decay=0.5, window=2)
    tr = SketchFrequencyTracker((100, 200), scfg, tracked=(0, 1))
    rng = np.random.default_rng(0)
    for _ in range(4):
        tr.observe({"sparse": rng.integers(0, 100, (32, 2))})
    stats = tr.poll_window()
    assert stats is not None and stats["entropy"] > 0
    assert tr.poll_window() is None  # cleared on read
    tr2 = SketchFrequencyTracker((100, 200), scfg, tracked=(0, 1))
    tr2.load_state_tree(tr.state_tree())
    for a, b in zip(tr.state_tree(), tr2.state_tree()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tr2.batches_seen == 4


def test_async_fold_matches_sync_statistics():
    def mk(af):
        return SketchFrequencyTracker(
            (500, 9000), StreamConfig(width=1 << 10, depth=4, heavy=32,
                                      ring=512, async_fold=af), tracked=(0, 1),
        )

    sync, async_ = mk(False), mk(True)
    rng = np.random.default_rng(4)
    for _ in range(10):
        b = {"sparse": np.stack(
            [rng.zipf(1.3, 256) % 500, rng.zipf(1.3, 256) % 9000], axis=1
        )}
        sync.observe(b)
        async_.observe(b)
    async_.flush()
    for f in (0, 1):
        assert async_.features[f].mass == sync.features[f].mass
        # the two paths admit from different sketch estimates
        # (conservative vs plain), but the bulk of the head agrees
        hs = dict(zip(*[x.tolist() for x in sync.features[f].hh.head()]))
        ha = dict(zip(*[x.tolist() for x in async_.features[f].hh.head()]))
        assert len(set(hs) & set(ha)) >= len(hs) // 2
    # the providers the transition indexes ARE the feature sketches
    assert async_.counts[0] is async_.features[0]


# --- trigger policy -----------------------------------------------------------


def _stats(entropy, heads=None):
    return {"entropy": entropy, "mass": 1.0,
            "heads": heads if heads is not None else [None]}


def test_trigger_empty_and_single_id_never_fire():
    tg = ClusterTrigger(entropy_drop=0.1, warmup=0, min_windows_between=0)
    ev = tg.update(None, step=1)  # empty stream: nothing observed
    assert not ev.fire and np.isnan(ev.entropy)
    # single-id stream: entropy 0 from the first window — zero reference,
    # no collapse, never fires
    for s in range(2, 8):
        ev = tg.update(_stats(0.0), step=s)
        assert not ev.fire
    assert tg.fired == 0


def test_trigger_fires_exactly_once_per_collapse():
    tg = ClusterTrigger(entropy_drop=0.2, drift_threshold=2.0,  # drift off
                        warmup=1, min_windows_between=0)
    for s, h in enumerate([4.0, 4.1, 4.05]):  # healthy plateau
        assert not tg.update(_stats(h), step=s).fire
    ev = tg.update(_stats(3.0), step=3)  # collapse: 3.0 < 4.1 * 0.8
    assert ev.fire and ev.reason == "entropy-collapse"
    # stays low: NO re-fire (reference reset to the collapsed entropy)
    for s, h in enumerate([3.0, 2.9, 2.95], start=4):
        assert not tg.update(_stats(h), step=s).fire
    # a SECOND collapse from the new level fires again
    assert tg.update(_stats(2.2), step=8).fire
    assert tg.fired == 2


def test_trigger_fires_on_drift():
    heads_a = [(np.arange(8), np.full(8, 0.125))]
    heads_b = [(np.arange(100, 108), np.full(8, 0.125))]  # disjoint head
    tg = ClusterTrigger(entropy_drop=0.99, drift_threshold=0.5,
                        warmup=1, min_windows_between=0)
    assert not tg.update(_stats(3.0, heads_a), step=1).fire
    assert not tg.update(_stats(3.0, heads_a), step=2).fire  # no drift
    ev = tg.update(_stats(3.0, heads_b), step=3)
    assert ev.fire and ev.reason == "drift" and ev.drift == pytest.approx(1.0)


def test_trigger_state_roundtrip_is_exact():
    tg = ClusterTrigger(entropy_drop=0.2, warmup=1, min_windows_between=0)
    heads = [(np.arange(4), np.asarray([0.4, 0.3, 0.2, 0.1]))]
    seq = [4.0, 4.2, 3.1, 3.0, 2.2, 2.25]
    mid = len(seq) // 2
    for s, h in enumerate(seq[:mid]):
        tg.update(_stats(h, heads), step=s)
    tg2 = ClusterTrigger(entropy_drop=0.2, warmup=1, min_windows_between=0)
    tg2.load_state_tree(tg.state_tree())
    fires = []
    for s, h in enumerate(seq[mid:], start=mid):
        fires.append(
            (tg.update(_stats(h, heads), step=s).fire,
             tg2.update(_stats(h, heads), step=s).fire)
        )
    assert all(a == b for a, b in fires) and any(a for a, _ in fires)
    assert tg.fired == tg2.fired


# --- Trainer integration ------------------------------------------------------


def _setup(seed=0, cap=512):
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=cap)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 32
    )
    return cfg, step, state, static, data


def test_make_id_tracker_tracks_only_cce_features():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    tr = dlrm.make_id_tracker(cfg, dlrm_criteo.reduced_stream())
    from repro.core.cce import CCE

    cce_feats = {
        i for i, t in enumerate(cfg.collection.tables) if isinstance(t, CCE)
    }
    assert set(tr.tracked) == cce_feats
    for i in range(cfg.n_sparse):
        assert (tr.counts[i] is None) == (i not in cce_feats)
    assert isinstance(dlrm.make_id_tracker(cfg), IdFrequencyTracker)


def test_transition_receives_sketch_points(monkeypatch):
    """With a sketch provider the transition must hand cluster() the
    exact head ids/counts (plus HT tail) — not a dense array."""
    from repro.core.cce import CCE
    from repro.train.transition import transition_table

    cce = CCE(d1=3000, d2=16, k=8, c=4, seed_salt=3)
    params, buffers = cce.init(jax.random.PRNGKey(0))
    fs = FeatureSketch(width=1 << 10, depth=4, heavy=16, ring=256, seed=0)
    fs.observe(np.repeat([7, 13, 99], [5, 1, 2]))
    seen = {}
    orig = CCE.cluster

    def spy(self, key, p, b, **kw):
        seen.update(kw)
        return orig(self, key, p, b, **kw)

    monkeypatch.setattr(CCE, "cluster", spy)
    transition_table(cce, jax.random.PRNGKey(0), params, buffers, counts=fs)
    np.testing.assert_array_equal(np.asarray(seen["sample_ids"]), [7, 13, 99])
    np.testing.assert_array_equal(np.asarray(seen["sample_weights"]), [5.0, 1.0, 2.0])


def test_trainer_trigger_fires_transition_and_training_continues():
    cfg, step, state, static, data = _setup()
    tracker = dlrm.make_id_tracker(cfg, dlrm_criteo.reduced_stream(window=5))
    trigger = ClusterTrigger(entropy_drop=0.05, drift_threshold=0.05, warmup=1)

    def cluster_fn(key, p, b, opt):
        return dlrm.cluster_tables(key, p, b, cfg, opt, id_counts=tracker.counts)

    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 cluster_fn=cluster_fn, cluster_every=0, cluster_max=2,
                 id_tracker=tracker, trigger=trigger)
    hist = tr.run(25)
    assert tr.clusters_done == 2  # adaptive schedule fired (capped)
    assert trigger.fired >= 2 and len(trigger.events) == 5
    assert np.isfinite(hist[-1]["loss"])


def test_restart_exact_with_sketch_tracker_and_trigger(tmp_path):
    """Crash after a TRIGGERED transition, restore, replay: bitwise-equal
    final state — the sketch histograms, the trigger's reference/latch,
    and the fired schedule are all training state."""

    def make(cfg, tracker, trigger):
        def cluster_fn(key, p, b, opt):
            return dlrm.cluster_tables(key, p, b, cfg, opt,
                                       id_counts=tracker.counts)

        return dict(cluster_fn=cluster_fn, cluster_every=0, cluster_max=3,
                    id_tracker=tracker, trigger=trigger, seed=1)

    def mk_parts():
        cfg, step, state, static, data = _setup(seed=1)
        tracker = dlrm.make_id_tracker(
            cfg, dlrm_criteo.reduced_stream(window=3))
        trigger = ClusterTrigger(entropy_drop=0.05, drift_threshold=0.05,
                                 warmup=1)
        return cfg, step, state, static, data, tracker, trigger

    def run(fail: bool):
        cfg, step, state, static, data, tracker, trigger = mk_parts()
        tr = Trainer(
            jax.jit(step, donate_argnums=(0,)), state, static, data,
            ckpt_dir=str(tmp_path / ("a" if fail else "b")), ckpt_every=5,
            failures=FailureInjector((8,)) if fail else None,
            **make(cfg, tracker, trigger),
        )
        if fail:
            with pytest.raises(RuntimeError):
                tr.run(12)
            cfg2, step2, _, static2, _, tracker2, trigger2 = mk_parts()
            tr2 = Trainer(
                jax.jit(step2, donate_argnums=(0,)), tr.state, static2,
                clickstream_batches(
                    ClickstreamConfig(vocab_sizes=cfg2.vocab_sizes, seed=1),
                    32, start_step=5,
                ),
                ckpt_dir=str(tmp_path / "a"), **make(cfg2, tracker2, trigger2),
            )
            restored = tr2.restore_latest()
            assert restored == 5
            assert tracker2.batches_seen == 5  # sketch state resumed
            tr2.run(12 - restored)
            return tr2.state, trigger2
        tr.run(12)
        return tr.state, trigger

    (s_fail, tg_fail), (s_clean, tg_clean) = run(True), run(False)
    assert tg_fail.fired == tg_clean.fired  # the schedule replayed
    for a, b in zip(jax.tree.leaves(s_fail.params), jax.tree.leaves(s_clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_fail.opt), jax.tree.leaves(s_clean.opt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dense_checkpoint_migrates_into_sketch_tracker(tmp_path):
    """Satellite: a checkpoint written by a DENSE-tracker Trainer restores
    into a sketch-tracker Trainer through load_checkpoint(migrations=...)
    — head ids carry their exact (bit-for-bit) dense counts."""
    cfg, step, state, static, data = _setup(seed=2)
    dense = IdFrequencyTracker(cfg.vocab_sizes)
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 ckpt_dir=str(tmp_path), ckpt_every=4, id_tracker=dense)
    tr.run(4)
    tr.ckpt.wait()
    dense_counts = [c.copy() for c in dense.counts]

    cfg2, step2, state2, static2, _ = _setup(seed=2)
    sketch = dlrm.make_id_tracker(cfg2, dlrm_criteo.reduced_stream(window=0))
    tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), state2, static2,
                  iter(()), ckpt_dir=str(tmp_path), id_tracker=sketch)
    assert tr2.restore_latest() == 4
    heavy = sketch.config.heavy
    for f in sketch.tracked:
        c = dense_counts[f]
        nz = np.flatnonzero(c)
        top = nz[np.argsort(c[nz], kind="stable")[::-1]][:heavy]
        h_ids, h_cnt = sketch.features[f].hh.head()
        lut = dict(zip(h_ids.tolist(), h_cnt.tolist()))
        for i in top.tolist():
            assert lut[i] == float(c[i])  # bit-for-bit on the head
        assert sketch.features[f].mass == float(c.sum())
        # the sketch never underestimates the remaining tail
        tail = np.setdiff1d(nz, top)
        if tail.size:
            assert (sketch.features[f].cms.estimate(tail) >= c[tail]).all()


def test_sketch_checkpoint_roundtrip_via_trainer(tmp_path):
    """Sketch-tracker checkpoints restore exactly (sectioned manifest) —
    including when the reader adds a trigger the writer didn't have."""
    cfg, step, state, static, data = _setup(seed=3)
    tracker = dlrm.make_id_tracker(cfg, dlrm_criteo.reduced_stream(window=2))
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 ckpt_dir=str(tmp_path), ckpt_every=4, id_tracker=tracker)
    tr.run(4)
    tr.ckpt.wait()
    want = [np.asarray(leaf) for leaf in tracker.state_tree()]

    cfg2, step2, state2, static2, _ = _setup(seed=3)
    tracker2 = dlrm.make_id_tracker(cfg2, dlrm_criteo.reduced_stream(window=2))
    trigger2 = ClusterTrigger()
    tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), state2, static2,
                  iter(()), ckpt_dir=str(tmp_path), id_tracker=tracker2,
                  trigger=trigger2)
    assert tr2.restore_latest() == 4
    for a, b in zip(want, tracker2.state_tree()):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert trigger2.windows == 0  # fresh trigger state, not garbage


def test_combined_legacy_emb_and_dense_counts_checkpoint_migrates(tmp_path):
    """Migrations COMPOSE: a pre-collection-era checkpoint (per-feature
    emb layout, dense id_counts, no section index in the manifest) must
    restore into a grouped-layout Trainer with a SKETCH tracker — old
    along both axes at once."""
    import json
    import os

    from repro.checkpoint import save_checkpoint
    from repro.core.collection import legacy_layout_migration

    cfg, step, state, static, data = _setup(seed=4)
    dense = IdFrequencyTracker(cfg.vocab_sizes)
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 id_tracker=dense)
    tr.run(3)
    emb_to_old, _ = legacy_layout_migration(cfg.collection)
    legacy_tree = emb_to_old(tr._ckpt_tree())
    path = save_checkpoint(str(tmp_path), 3, legacy_tree)
    manifest = os.path.join(path, "manifest.json")
    with open(manifest) as f:
        m = json.load(f)
    del m["toplevel"]  # pre-PR4 writers had no section index
    with open(manifest, "w") as f:
        json.dump(m, f)

    cfg2, step2, state2, static2, _ = _setup(seed=4)
    sketch = dlrm.make_id_tracker(cfg2, dlrm_criteo.reduced_stream(window=0))
    tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), state2, static2,
                  iter(()), ckpt_dir=str(tmp_path), id_tracker=sketch,
                  migrations=dlrm.checkpoint_migrations(cfg2))
    assert tr2.restore_latest() == 3
    # params restored bit-exact through the re-stacking migration
    for a, b in zip(jax.tree.leaves(tr.state.params),
                    jax.tree.leaves(tr2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # histograms ingested: exact head counts per tracked feature
    f = sketch.tracked[0]
    c = dense.counts[f]
    top = np.argsort(c)[::-1][: min(8, int((c > 0).sum()))]
    lut = dict(zip(*[x.tolist() for x in sketch.features[f].hh.head()]))
    for i in top.tolist():
        assert lut[i] == float(c[i])


def test_trigger_restores_from_pre_first_window_checkpoint(tmp_path):
    """The checkpoint template must accept trigger state saved BEFORE the
    first closed window (empty prev-head snapshot) even when the LIVE
    trigger has closed windows since — in-process crash recovery must
    restore the stored state, not silently keep the stale live state."""
    cfg, step, state, static, data = _setup(seed=5)
    tracker = dlrm.make_id_tracker(cfg, dlrm_criteo.reduced_stream(window=8))
    trigger = ClusterTrigger(entropy_drop=0.05, drift_threshold=0.05, warmup=0)
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 ckpt_dir=str(tmp_path), ckpt_every=5,
                 failures=FailureInjector((9,)),
                 id_tracker=tracker, trigger=trigger, seed=5)
    with pytest.raises(RuntimeError):
        tr.run(12)  # ckpt at 5 (no window closed yet), window at 8, crash at 9
    assert trigger.windows == 1  # the live trigger HAS closed a window
    assert tr.restore_latest() == 5
    # stored pre-window state restored: reference re-armed, events dropped
    assert trigger.windows == 0 and trigger._prev_ids is None
    assert trigger.events == []


def test_trackerless_writer_restores_fresh_tracker_state(tmp_path):
    """A sectioned checkpoint from a tracker-less writer restored into a
    tracker-enabled Trainer must reset the tracker to DETERMINISTIC fresh
    state — not silently keep the live tracker's post-checkpoint
    observations (in-process crash recovery would diverge)."""
    cfg, step, state, static, data = _setup(seed=6)
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data,
                 ckpt_dir=str(tmp_path), ckpt_every=3)  # NO tracker
    tr.run(3)
    tr.ckpt.wait()

    cfg2, step2, state2, static2, data2 = _setup(seed=6)
    sketch = dlrm.make_id_tracker(cfg2, dlrm_criteo.reduced_stream(window=0))
    tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), state2, static2,
                  data2, ckpt_dir=str(tmp_path), id_tracker=sketch)
    tr2.run(2)  # live tracker accumulates PRE-restore observations
    assert sketch.features[sketch.tracked[0]].mass > 0
    assert tr2.restore_latest() == 3
    for f in sketch.tracked:
        assert sketch.features[f].mass == 0.0  # fresh, not stale live state
    assert sketch.batches_seen == 0
    # dense reader: same fresh semantics
    cfg3, step3, state3, static3, data3 = _setup(seed=6)
    dense = dlrm.make_id_tracker(cfg3)
    tr3 = Trainer(jax.jit(step3, donate_argnums=(0,)), state3, static3,
                  data3, ckpt_dir=str(tmp_path), id_tracker=dense)
    tr3.run(2)
    assert tr3.restore_latest() == 3
    assert all(int(c.sum()) == 0 for c in dense.counts)


def test_trigger_survives_tracked_feature_count_change():
    """A restored prev-head snapshot with a different feature count (the
    wildcard restore template accepts any stored row count) must reset
    the drift baseline, not crash or pair mismatched features."""
    tg = ClusterTrigger(entropy_drop=0.99, drift_threshold=0.5,
                        warmup=0, min_windows_between=0)
    one = [(np.arange(4), np.full(4, 0.25))]
    two = one + [(np.arange(10, 14), np.full(4, 0.25))]
    tg.update(_stats(3.0, one), step=1)
    ev = tg.update(_stats(3.0, two), step=2)  # feature count 1 -> 2
    assert not ev.fire and ev.drift == 0.0  # baseline reset, no IndexError
    ev = tg.update(_stats(3.0, two), step=3)
    assert ev.drift == pytest.approx(0.0)  # baseline re-established
