"""Theorem 3.1 and Algorithms 1 & 2 — the paper's provable core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import least_squares as ls


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (300, 60))
    Y = jax.random.normal(jax.random.fold_in(key, 1), (300, 6))
    return X, Y


def test_dense_cce_beats_theorem_bound(problem):
    """Theorem 3.1 is an UPPER bound in expectation; a single run should
    track or beat it (loose factor for randomness)."""
    X, Y = problem
    k, iters = 20, 25
    tr = ls.dense_cce(jax.random.PRNGKey(2), X, Y, k, iters)
    bound = ls.theorem_bound(X, Y, k, iters)
    opt, _ = ls.optimal_loss(X, Y)
    # excess loss vs the bound's excess, iteration-wise
    excess = np.asarray(tr.losses) - float(opt)
    bexcess = np.asarray(bound) - float(opt)
    # allow 3x slack at each of a few checkpoints (expectation vs sample)
    for i in (5, 10, 20, 25):
        assert excess[i] <= 3 * bexcess[i] + 1e-3, (i, excess[i], bexcess[i])


def test_dense_cce_converges_to_opt(problem):
    X, Y = problem
    tr = ls.dense_cce(jax.random.PRNGKey(3), X, Y, k=20, iters=60)
    opt, _ = ls.optimal_loss(X, Y)
    assert float(tr.losses[-1]) < 1.02 * float(opt)


def test_smart_noise_converges_faster(problem):
    """Appendix B: SVD-aligned noise has the better rate (1-1/d)^ik."""
    X, Y = problem
    k, iters = 20, 30
    plain = ls.dense_cce(jax.random.PRNGKey(4), X, Y, k, iters)
    smart = ls.dense_cce(jax.random.PRNGKey(4), X, Y, k, iters, smart_noise=True)
    opt, _ = ls.optimal_loss(X, Y)
    assert float(smart.losses[-1]) - float(opt) <= float(plain.losses[-1]) - float(opt) + 1e-3


def test_sparse_cce_decreases(problem):
    X, Y = problem
    tr = ls.sparse_cce(jax.random.PRNGKey(5), X, Y, k=24, iters=8)
    losses = np.asarray(tr.losses)
    assert losses[-1] < losses[0]
    # monotone non-increasing up to small noise
    assert (np.diff(losses) < 1e-3).mean() > 0.7


def test_sparse_cce_beats_pure_sketch(problem):
    """One iteration == random count-sketch (A empty-ish); more iterations
    must improve on it — the paper's 'learned beats random sketching'."""
    X, Y = problem
    one = ls.sparse_cce(jax.random.PRNGKey(6), X, Y, k=24, iters=1)
    many = ls.sparse_cce(jax.random.PRNGKey(6), X, Y, k=24, iters=8)
    assert float(many.losses[-1]) < float(one.losses[-1])


def test_kmeans_factorize_quality():
    """Figure 1b's comparison lines: K-means factorization of the exact
    solution; 2 ones per row (residual step) beats 1."""
    key = jax.random.PRNGKey(7)
    # low-rank-ish T so clustering its rows is meaningful
    U = jax.random.normal(key, (80, 3))
    V = jax.random.normal(jax.random.fold_in(key, 1), (3, 8))
    T = U @ V + 0.05 * jax.random.normal(jax.random.fold_in(key, 2), (80, 8))
    t1 = ls.kmeans_factorize(key, T, k=16, ones_per_row=1)
    t2 = ls.kmeans_factorize(key, T, k=16, ones_per_row=2)
    e1 = float(jnp.sum((t1 - T) ** 2))
    e2 = float(jnp.sum((t2 - T) ** 2))
    assert e2 <= e1 * 1.05
    assert e1 < float(jnp.sum(T**2))


def test_bound_is_monotone_decreasing(problem):
    X, Y = problem
    bound = np.asarray(ls.theorem_bound(X, Y, k=20, iters=10))
    assert (np.diff(bound) <= 1e-6).all()
