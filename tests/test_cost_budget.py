"""Cost-budget suite: the peak-live estimator, the quantitative rules,
budget-file roundtrips/tolerances, and the CLI regression gate.

Same philosophy as test_analysis.py: the budgets are a CI gate, so every
rule gets a planted regression it MUST flag and a clean case it MUST
pass.  Handcrafted HLO modules pin the liveness estimator's contract
(DESIGN.md §8) line by line; the planted fp64 upcast doubles real HBM
bytes through the real AOT-compile path; the CLI test doctors a budget
file and demands a non-zero exit.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.budget import (
    DEFAULT_TOLERANCES,
    BudgetFile,
    allowed_max,
    diff_profiles,
)
from repro.analysis.cost_rules import (
    BytesBudget,
    CollectiveBudget,
    CostProfile,
    FlopBudget,
    NoReplicatedParam,
    PeakMemoryBudget,
    cost_profile,
)
from repro.analysis.program import AuditProgram
from repro.launch import hlo_cost

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --- peak-live-buffer estimator on handcrafted HLO --------------------------

_STRAIGHT_LINE = """\
HloModule toy

ENTRY %main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  %a = f32[256]{0} add(f32[256]{0} %p0, f32[256]{0} %p0)
  %b = f32[256]{0} multiply(f32[256]{0} %a, f32[256]{0} %a)
  ROOT %c = f32[256]{0} add(f32[256]{0} %b, f32[256]{0} %b)
}
"""

# same dataflow with a tuple/get-tuple-element detour: aliases must add
# no storage, so the peak is identical to the straight-line module
_ALIASED = """\
HloModule toy

ENTRY %main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  %a = f32[256]{0} add(f32[256]{0} %p0, f32[256]{0} %p0)
  %b = f32[256]{0} multiply(f32[256]{0} %a, f32[256]{0} %a)
  %t = (f32[256]{0}) tuple(f32[256]{0} %b)
  %g = f32[256]{0} get-tuple-element((f32[256]{0}) %t), index=0
  ROOT %c = f32[256]{0} add(f32[256]{0} %g, f32[256]{0} %g)
}
"""

_WHILE = """\
HloModule loop

%cond (x: (s32[], f32[1024])) -> pred[] {
  %x = (s32[], f32[1024]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[1024]) %x), index=0
  %k = s32[] constant(10)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %k), direction=LT
}

%body (y: (s32[], f32[1024])) -> (s32[], f32[1024]) {
  %y = (s32[], f32[1024]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[1024]) %y), index=0
  %v = f32[1024]{0} get-tuple-element((s32[], f32[1024]) %y), index=1
  %one = s32[] constant(1)
  %j2 = s32[] add(s32[] %j, s32[] %one)
  %tmp = f32[1024]{0} add(f32[1024]{0} %v, f32[1024]{0} %v)
  %tmp2 = f32[1024]{0} multiply(f32[1024]{0} %tmp, f32[1024]{0} %tmp)
  ROOT %r = (s32[], f32[1024]) tuple(s32[] %j2, f32[1024]{0} %tmp2)
}

ENTRY %main (p0: (s32[], f32[1024])) -> (s32[], f32[1024]) {
  %p0 = (s32[], f32[1024]) parameter(0)
  ROOT %w = (s32[], f32[1024]) while((s32[], f32[1024]) %p0), condition=%cond, body=%body
}
"""


def test_liveness_straight_line_counts_two_live_buffers():
    est = hlo_cost.liveness(_STRAIGHT_LINE)
    # at every step exactly two 1 KiB buffers overlap (producer+consumer)
    assert est.peak_bytes == 2 * 256 * 4
    assert est.param_bytes == 256 * 4


def test_liveness_tuple_gte_alias_adds_no_storage():
    assert (
        hlo_cost.liveness(_ALIASED).peak_bytes
        == hlo_cost.liveness(_STRAIGHT_LINE).peak_bytes
    )


def test_liveness_while_adds_body_peak_minus_params():
    est = hlo_cost.liveness(_WHILE)
    carry = 4 + 1024 * 4  # (s32[], f32[1024])
    # body peak: carry (live until its last gte-aliased use at %tmp)
    # + %j2 + %tmp all overlap; minus the carry param, which aliases the
    # caller's buffer, the body contributes j2 + tmp on top of the entry
    body_extra = 4 + 1024 * 4
    # entry: carry param + while result live together at the call site
    assert est.peak_bytes == 2 * carry + body_extra
    assert est.param_bytes == carry


def test_liveness_runs_on_a_real_compiled_module():
    def f(x):
        return jnp.tanh(x @ x.T).sum()

    text = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ).compile().as_text()
    est = hlo_cost.liveness(text)
    # at least the input buffer must be live, and params are counted
    assert est.peak_bytes >= 64 * 64 * 4
    assert est.param_bytes == 64 * 64 * 4


# --- CostProfile via the abstract AOT-compile path --------------------------


def _profile_of(fn, *args, **kw):
    return cost_profile(AuditProgram.capture(fn, *args, name="toy", **kw))


def test_planted_fp64_upcast_blows_the_bytes_and_peak_budgets():
    n = 1 << 16
    f32 = _profile_of(lambda x: x * 2.0, jax.ShapeDtypeStruct((n,), jnp.float32))
    with jax.experimental.enable_x64():  # audit: allow-raw-experimental
        f64 = _profile_of(
            lambda x: x * 2.0, jax.ShapeDtypeStruct((n,), jnp.float64)
        )
    # the planted regression: fp64 doubles every byte metric
    assert f64.hbm_bytes == 2 * f32.hbm_bytes
    assert f64.peak_bytes == 2 * f32.peak_bytes
    with jax.experimental.enable_x64():  # audit: allow-raw-experimental
        prog = AuditProgram.capture(
            lambda x: x * 2.0, jax.ShapeDtypeStruct((n,), jnp.float64),
            name="toy",
        )
        found = BytesBudget(max_bytes=f32.hbm_bytes, baseline=f32.hbm_bytes).check(prog)
        assert len(found) == 1 and found[0].rule == "bytes-budget"
        assert "committed baseline" in found[0].message
        found = PeakMemoryBudget(max_bytes=f32.peak_bytes).check(prog)
        assert len(found) == 1 and found[0].rule == "peak-memory-budget"


def test_flop_budget_flags_doubled_matmul_work():
    m = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    one = _profile_of(lambda a, b: a @ b, m, m)
    assert one.flops == 2 * 64 * 64 * 64
    prog = AuditProgram.capture(lambda a, b: (a @ b) @ b, m, m, name="toy")
    found = FlopBudget(max_flops=one.flops).check(prog)
    assert len(found) == 1 and found[0].rule == "flop-budget"
    assert FlopBudget(max_flops=2 * one.flops).check(prog) == []


def _stub_program(profile: CostProfile) -> AuditProgram:
    prog = AuditProgram(name="stub", closed=None, invar_labels=())
    prog._cost_profile = profile
    return prog


def test_collective_budget_default_allows_nothing():
    clean = _stub_program(CostProfile())
    assert CollectiveBudget().check(clean) == []

    chatty = _stub_program(CostProfile(
        ici_bytes=1000.0, collectives={"all-reduce": 2.0}
    ))
    found = CollectiveBudget().check(chatty)
    assert {f.rule for f in found} == {"collective-budget"}
    assert len(found) == 2  # disallowed kind + ici bytes over the 0 cap
    assert CollectiveBudget(
        allow=("all-reduce",), max_ici_bytes=1000.0
    ).check(chatty) == []
    # bytes cap binds even when the kind is allowed
    found = CollectiveBudget(allow=("all-reduce",), max_ici_bytes=999.0).check(chatty)
    assert len(found) == 1 and "ici_bytes" in found[0].message


_REPLICATED_HLO = """\
HloModule jit_f, num_partitions=4

ENTRY %main (p0: f32[524288]) -> f32[524288] {
  %p0 = f32[524288]{0} parameter(0)
  ROOT %m = f32[524288]{0} multiply(f32[524288]{0} %p0, f32[524288]{0} %p0)
}
"""

_SHARDED_HLO = """\
HloModule jit_f, num_partitions=4

ENTRY %main (p0: f32[131072]) -> f32[131072] {
  %p0 = f32[131072]{0} parameter(0)
  ROOT %m = f32[131072]{0} multiply(f32[131072]{0} %p0, f32[131072]{0} %p0)
}
"""


def _captured_big_input():
    big = jax.ShapeDtypeStruct((1 << 19,), jnp.float32)  # 2 MiB
    return AuditProgram.capture(lambda d: d["w"] * 2.0, {"w": big}, name="toy")


def test_no_replicated_param_flags_full_size_leaf_under_partitions():
    prog = _captured_big_input()
    prog._compiled_text = _REPLICATED_HLO
    found = NoReplicatedParam().check(prog)
    assert len(found) == 1 and "'w'" in found[0].where
    assert "replicated on every device" in found[0].message
    # the allowlist names the leaf replicated by contract
    prog2 = _captured_big_input()
    prog2._compiled_text = _REPLICATED_HLO
    assert NoReplicatedParam(allow=("w",)).check(prog2) == []
    # instance-level severity downgrades documentation-only findings
    prog3 = _captured_big_input()
    prog3._compiled_text = _REPLICATED_HLO
    assert NoReplicatedParam(severity="warning").check(prog3)[0].severity == "warning"


def test_no_replicated_param_passes_on_sharded_leaf():
    prog = _captured_big_input()
    prog._compiled_text = _SHARDED_HLO
    assert NoReplicatedParam().check(prog) == []


def test_no_replicated_param_refuses_single_partition():
    prog = _captured_big_input()
    found = NoReplicatedParam().check(prog)  # real compile: 1 partition
    assert len(found) == 1 and "single partition" in found[0].message


# --- budget files: roundtrip, tolerances, diffs -----------------------------


def _profiles():
    return {
        "fwd": CostProfile(flops=1e9, hbm_bytes=2e9, peak_bytes=5e8),
        "step": CostProfile(
            flops=4e9, hbm_bytes=8e9, peak_bytes=1e9,
            ici_bytes=1e6, collectives={"all-reduce": 4.0}, num_partitions=4,
        ),
    }


def test_budget_file_roundtrip(tmp_path):
    bf = BudgetFile.from_profiles("toy", _profiles())
    path = str(tmp_path / "toy.json")
    bf.save(path)
    loaded = BudgetFile.load(path)
    assert loaded.to_dict() == bf.to_dict()
    assert loaded.tolerances == DEFAULT_TOLERANCES
    # committed collectives become the allowed kinds
    coll_rule = next(
        r for r in loaded.rules_for("step") if isinstance(r, CollectiveBudget)
    )
    assert coll_rule.allow == ("all-reduce",)
    assert loaded.rules_for("nope") is None


def test_budget_tolerance_boundary_is_inclusive():
    bf = BudgetFile.from_profiles("toy", _profiles())
    cap = allowed_max(1e9, "flops", bf.tolerances)
    assert cap == 1e9 * 1.1  # relative tolerance dominates the slack floor
    flop_rule = next(
        r for r in bf.rules_for("fwd") if isinstance(r, FlopBudget)
    )
    at_cap = _stub_program(CostProfile(flops=cap))
    assert flop_rule.check(at_cap) == []
    over = _stub_program(CostProfile(flops=cap * 1.001))
    assert len(flop_rule.check(over)) == 1


def test_budget_slack_floor_covers_near_zero_baselines():
    # 10% of 1 kFLOP is noise-level; the absolute floor absorbs it
    assert allowed_max(1e3, "flops", DEFAULT_TOLERANCES) == 1e3 + 1e6
    # ici/dcn get NO slack: committed zero collectives stay exactly zero
    assert allowed_max(0.0, "ici_bytes", DEFAULT_TOLERANCES) == 0.0


def test_diff_profiles_statuses():
    bf = BudgetFile.from_profiles("toy", _profiles())
    current = {
        "fwd": CostProfile(flops=3e9, hbm_bytes=2e9, peak_bytes=1e8),
        "step": _profiles()["step"],
    }
    by_key = {
        (d.program, d.metric): d.status for d in diff_profiles(bf, current)
    }
    assert by_key[("fwd", "flops")] == "regression"
    assert by_key[("fwd", "hbm_bytes")] == "ok"
    assert by_key[("fwd", "peak_bytes")] == "improvement"
    assert all(
        v == "ok" for (p, _), v in by_key.items() if p == "step"
    )


def test_budget_structural_findings():
    bf = BudgetFile.from_profiles("toy", _profiles())
    mismatched = {
        "fwd": _profiles()["fwd"],
        # committed at 4 partitions, now compiled for 1
        "step": CostProfile(flops=4e9, num_partitions=1),
        "brand_new": CostProfile(),
    }
    found = bf.structural_findings(mismatched)
    msgs = {f.program: f.message for f in found}
    assert "brand_new" in msgs and "no committed budget" in msgs["brand_new"]
    assert "step" in msgs and "num_partitions" in msgs["step"]
    assert all(f.severity == "error" and f.rule == "budget-file" for f in found)

    del bf.programs["fwd"]
    bf.programs["ghost"] = bf.programs["step"]
    stale = bf.structural_findings({"step": _profiles()["step"]})
    assert any("ghost" in f.message and "stale" in f.message for f in stale)


# --- the CLI gate ------------------------------------------------------------


def _run_cli(args, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--jaxpr-only",
         "--config", "dlrm_criteo_reduced", *args],
        capture_output=True, text=True, env=env, timeout=900,
    )


@pytest.mark.slow
def test_cli_budget_gate_roundtrip_and_doctored_regression(tmp_path):
    path = str(tmp_path / "reduced.json")
    report = str(tmp_path / "cost.json")

    # 1. regenerate: writes the file, exits 0
    res = _run_cli(["--update-budgets", "--budgets", path], tmp_path)
    assert res.returncode == 0, res.stderr[-3000:]
    committed = json.load(open(path))
    assert set(committed["programs"]) == {
        "fwd", "grad", "train_step", "train_step_telemetry", "serve_lookup",
        "serve_dlrm_cold", "serve_dlrm_hit",
    }

    # 2. clean gate: current == committed, exits 0, diff all-ok
    res = _run_cli(["--budgets", path, "--cost-report", report], tmp_path)
    assert res.returncode == 0, res.stderr[-3000:]
    diffs = json.load(open(report))["diffs"]
    assert diffs and all(d["status"] == "ok" for d in diffs)

    # 3. doctored budget: halve the committed bytes -> current is a 2x
    #    regression -> structured diff + non-zero exit
    committed["programs"]["fwd"]["hbm_bytes"] /= 2.0
    with open(path, "w") as fh:
        json.dump(committed, fh)
    res = _run_cli(["--budgets", path, "--cost-report", report], tmp_path)
    assert res.returncode == 1, res.stderr[-3000:]
    assert "[bytes-budget] fwd" in res.stderr
    bad = [d for d in json.load(open(report))["diffs"] if d["status"] != "ok"]
    assert len(bad) == 1
    assert bad[0]["program"] == "fwd"
    assert bad[0]["metric"] == "hbm_bytes"
    assert bad[0]["status"] == "regression"
    assert bad[0]["committed"] == committed["programs"]["fwd"]["hbm_bytes"]
    assert bad[0]["rel_change"] == pytest.approx(1.0)

    # 4. missing budget file is its own exit code (2): the gate cannot
    #    silently pass when there is nothing to gate against
    res = _run_cli(["--budgets", str(tmp_path / "missing.json")], tmp_path)
    assert res.returncode == 2


# --- the sharded bundle under a forced 4-device mesh ------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
from repro.analysis.audit import run_audit

report = run_audit("dlrm_criteo_reduced_sharded", with_cost=True)
out = {
    "ok": report.ok,
    "profiles": {n: p.to_dict() for n, p in report.profiles.items()},
    "findings": [f.to_dict() for f in report.findings],
}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_transition_audit_on_forced_mesh():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"], out["findings"]
    profs = out["profiles"]
    assert set(profs) == {
        "cluster_sharded", "assign_all_sharded", "train_step_sharded",
        "train_step_sharded_telemetry",
    }
    for prof in profs.values():
        assert prof["num_partitions"] == 4
        assert prof["dcn_bytes"] == 0.0
        assert set(prof["collectives"]) <= {
            "all-to-all", "all-reduce", "all-gather", "collective-permute",
        }
    # the distributed k-means really does psum
    assert profs["cluster_sharded"]["collectives"].get("all-reduce", 0) > 0
    # the model-parallel step really does route ids shard-to-shard
    assert profs["train_step_sharded"]["collectives"].get("all-to-all", 0) > 0
