"""The trip-count-aware HLO cost parser (the dry-run's measurement tool)."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def test_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == 2 * 128 * 256 * 256 * 10


def test_nested_scan_flops_exact():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(g).lower(x, w).compile()
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == 2 * 64 * 128 * 128 * 20


def test_grad_of_scan_counts_both_passes():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(jax.grad(f, argnums=1)).lower(x, w).compile()
    cost = hlo_cost.analyze(c.as_text())
    # fwd (1 dot) + bwd (2 dots) per iteration
    assert cost.flops == 3 * 2 * 128 * 256 * 256 * 10


def test_bytes_nonzero_and_reasonable():
    def f(x):
        return (x @ x.T).sum()

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    cost = hlo_cost.analyze(c.as_text())
    lo = 2 * 256 * 256 * 4  # at least read x twice-ish
    hi = 30 * 256 * 256 * 4
    assert lo <= cost.bytes <= hi


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY this module exists: XLA counts while bodies once."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    # dict (newer jax) vs list[dict] (older) — normalized by the helper
    xla_flops = hlo_cost.xla_cost_analysis(c).get("flops", 0)
    ours = hlo_cost.analyze(c.as_text()).flops
    assert ours >= 9 * xla_flops  # XLA reports ~1/10
