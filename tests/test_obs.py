"""Observability (DESIGN.md §10): in-step telemetry is launch-free, the
run log is restart-exact, the pump never loses records, the serve engine
records latencies, and the CLI summarizer stays jax-free."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.data import ClickstreamConfig, clickstream_batches
from repro.models import dlrm
from repro.obs import LatencyHistogram, RunLog, TelemetryConfig
from repro.obs.pump import MetricsPump
from repro.obs.runlog import read_runlog
from repro.obs.summary import format_summary, summarize_dict
from repro.obs.telemetry import telemetry_labels, telemetry_metrics
from repro.obs.trace import ProfileWindow
from repro.optim import sgd
from repro.stream import ClusterTrigger
from repro.train.loop import (
    FailureInjector,
    Trainer,
    init_state,
    make_train_step,
    split_buffers,
)


def _setup(emb="cce", seed=0, telemetry=None):
    cfg = dlrm_criteo.reduced(emb_method=emb, cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static,
                           telemetry=telemetry)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 32
    )
    return cfg, step, state, static, data


def _one_batch(data):
    return {k: np.asarray(v)[None] for k, v in next(data).items() if k != "step"}


# --- in-step telemetry --------------------------------------------------------


def test_telemetry_adds_zero_launches_and_leaves_math_untouched():
    """The tentpole contract: telemetry-on lowers to the SAME launch
    count as telemetry-off (pure jnp reductions fused into the one
    program), and the training math is bit-identical."""
    from repro.analysis import count_primitive

    _, step_off, state, _, data = _setup()
    _, step_on, state_on, _, _ = _setup(telemetry=TelemetryConfig())
    batch = _one_batch(data)

    jx_off = jax.make_jaxpr(step_off)(state, batch)
    jx_on = jax.make_jaxpr(step_on)(state, batch)
    assert count_primitive(jx_on, "pallas_call") == count_primitive(
        jx_off, "pallas_call"
    )
    # no host round-trips smuggled in either
    for prim in ("pure_callback", "io_callback", "debug_callback"):
        assert count_primitive(jx_on, prim) == 0

    s_off, m_off = step_off(state, batch)
    s_on, m_on = step_on(state_on, batch)
    np.testing.assert_array_equal(float(m_off["loss"]), float(m_on["loss"]))
    for a, b in zip(jax.tree.leaves(s_off.params), jax.tree.leaves(s_on.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    health = m_on["telemetry"]
    labels = telemetry_labels(state_on.params)
    assert health["emb_grad_norm"].shape == (labels["emb_groups"],)
    assert health["emb_param_norm"].shape == (labels["emb_groups"],)
    assert health["grad_nonfinite"].shape == (len(labels["leaves"]),)
    assert int(health["param_nonfinite"].sum()) == 0
    assert np.all(np.isfinite(np.asarray(health["emb_grad_norm"])))


def test_nonfinite_attribution_names_the_planted_leaf():
    """A NaN planted in ONE emb group's params must light up exactly that
    leaf of ``param_nonfinite`` — grads go NaN everywhere through
    backprop, which is why attribution reads the param side."""
    _, step, state, _, data = _setup(telemetry=TelemetryConfig())
    labels = telemetry_labels(state.params)
    # pick the supertable leaf of emb group 0 (the reduced CCE config has
    # one universal collection group)
    target = next(
        i for i, name in enumerate(labels["leaves"]) if "['emb'][0]" in name
    )
    paths, treedef = jax.tree_util.tree_flatten_with_path(state.params)
    leaves = [leaf for _, leaf in paths]
    poisoned = leaves[target].at[(0,) * leaves[target].ndim].set(jnp.nan)
    params = jax.tree_util.tree_unflatten(treedef, leaves[:target] + [poisoned] + leaves[target + 1:])
    state = state._replace(params=params)

    _, metrics = step(state, _one_batch(data))
    pn = np.asarray(metrics["telemetry"]["param_nonfinite"])
    assert pn[target] == 1
    assert pn.sum() == 1  # no other leaf implicated
    # the group's slab norm is poisoned too — the operator's first glance
    assert not np.isfinite(float(metrics["telemetry"]["emb_param_norm"][0]))


def test_occupancy_metrics_match_numpy():
    tcfg = TelemetryConfig(emb_norms=False, nonfinite=False)
    rng = np.random.default_rng(0)
    rows4 = rng.integers(-1, 5, size=(1, 8, 3, 4)).astype(np.int32)
    out = telemetry_metrics(tcfg, {}, {}, {"rows": jnp.asarray(rows4)})
    assert float(out["rows_occupancy"]) == pytest.approx(
        (rows4 >= 0).mean()
    )
    assert "shard_occupancy" not in out  # unbucketed rows: no shard axis

    rows5 = rng.integers(-1, 5, size=(2, 4, 3, 2, 5)).astype(np.int32)
    out = telemetry_metrics(tcfg, {}, {}, {"rows": jnp.asarray(rows5)})
    np.testing.assert_allclose(
        np.asarray(out["shard_occupancy"]),
        (rows5 >= 0).mean(axis=(0, 1, 3, 4)),
        rtol=1e-6,
    )


# --- the async pump -----------------------------------------------------------


def test_pump_lag_and_flush():
    drained = []
    pump = MetricsPump(lag=3, sink=drained.append)
    for s in range(5):
        pump.push(s, {"loss": jnp.float32(s)})
    # 5 pushed, lag 3 -> exactly 2 drained so far
    assert len(drained) == 2 and len(pump) == 3
    pump.flush()
    assert [r["step"] for r in drained] == [0, 1, 2, 3, 4]
    assert [r["loss"] for r in drained] == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert all(isinstance(r["loss"], float) for r in drained)


def test_trainer_history_exact_bounded_and_sync_every():
    """The pumped history equals the old always-synced history (same
    seed, same stream), sync_every=1 drains eagerly, and history_max
    bounds host memory."""
    _, step, state, static, data = _setup(seed=3)
    tr = Trainer(jax.jit(step, donate_argnums=(0,)), state, static, data)
    hist = tr.run(12)
    assert [h["step"] for h in hist] == list(range(12))

    _, step2, state2, static2, data2 = _setup(seed=3)
    tr2 = Trainer(jax.jit(step2, donate_argnums=(0,)), state2, static2, data2,
                  sync_every=1)
    tr2.run(12)
    assert len(tr2.pump) == 0  # eager drain: nothing left in flight
    np.testing.assert_array_equal(
        [h["loss"] for h in hist], [h["loss"] for h in tr2.history]
    )

    _, step3, state3, static3, data3 = _setup(seed=3)
    tr3 = Trainer(jax.jit(step3, donate_argnums=(0,)), state3, static3, data3,
                  history_max=5)
    hist3 = tr3.run(12)
    assert len(hist3) == 5 and hist3[-1]["step"] == 11  # newest kept


# --- run log ------------------------------------------------------------------


def test_runlog_roundtrip_dedupe_and_resume(tmp_path):
    p = tmp_path / "run.jsonl"
    with RunLog(p, manifest={"config": "t"}) as rl:
        assert rl.append("step", step=0, loss=1.0)
        assert not rl.append("step", step=0, loss=1.0)  # replay drops
        assert rl.append("fault", step=0, dedupe=False, error="x")
        assert rl.append("fault", step=0, dedupe=False, error="x")

    recs = read_runlog(p)
    assert recs[0]["event"] == "manifest" and recs[0]["config"] == "t"
    assert [r["event"] for r in recs[1:]] == ["step", "fault", "fault"]

    # re-open: appends (no second manifest), replays still dedupe
    with RunLog(p) as rl2:
        assert not rl2.append("step", step=0, loss=1.0)
        assert rl2.append("step", step=1, loss=0.9)
    recs = read_runlog(p)
    assert sum(r["event"] == "manifest" for r in recs) == 1
    assert [r["step"] for r in recs if r["event"] == "step"] == [0, 1]


def test_runlog_tolerates_truncated_tail(tmp_path):
    p = tmp_path / "run.jsonl"
    with RunLog(p) as rl:
        rl.append("step", step=0, loss=1.0)
    with open(p, "a") as f:
        f.write('{"event": "step", "st')  # writer died mid-record
    assert [r["event"] for r in read_runlog(p)] == ["manifest", "step"]
    with RunLog(p) as rl:  # and resume still works
        assert rl.append("step", step=1)


def test_runlog_restart_exact_through_triggered_transition(tmp_path):
    """Crash at step 8 (after a ckpt at 5 and a triggered transition),
    restore, replay with the SAME log file: one contiguous set of step
    records, one record per trigger window, and the step/trigger/
    transition records equal an uninterrupted run's."""

    def mk_parts(seed):
        cfg, step, state, static, data = _setup(seed=seed)
        tracker = dlrm.make_id_tracker(
            cfg, dlrm_criteo.reduced_stream(window=3))
        trigger = ClusterTrigger(entropy_drop=0.05, drift_threshold=0.05,
                                 warmup=1)

        def cluster_fn(key, p, b, opt):
            return dlrm.cluster_tables(key, p, b, cfg, opt,
                                       id_counts=tracker.counts)

        return cfg, step, state, static, data, tracker, trigger, cluster_fn

    def run(fail: bool):
        log = tmp_path / ("a.jsonl" if fail else "b.jsonl")
        cfg, step, state, static, data, tracker, trigger, cf = mk_parts(1)
        rl = RunLog(log)
        tr = Trainer(
            jax.jit(step, donate_argnums=(0,)), state, static, data,
            ckpt_dir=str(tmp_path / ("ca" if fail else "cb")), ckpt_every=5,
            cluster_fn=cf, cluster_max=3, id_tracker=tracker, trigger=trigger,
            failures=FailureInjector((8,)) if fail else None,
            runlog=rl, seed=1,
        )
        if fail:
            with pytest.raises(RuntimeError):
                tr.run(12)
            restored = tr.restore_latest()  # logs checkpoint_restore
            assert restored == 5
            rl.close()
            cfg2, step2, _, static2, _, tracker2, trigger2, cf2 = mk_parts(1)
            tracker2.load_state_tree(tracker.state_tree())
            trigger2.load_state_tree(trigger.state_tree())
            rl2 = RunLog(log)  # REOPEN: replayed events must dedupe
            tr2 = Trainer(
                jax.jit(step2, donate_argnums=(0,)), tr.state, static2,
                clickstream_batches(
                    ClickstreamConfig(vocab_sizes=cfg2.vocab_sizes, seed=1),
                    32, start_step=restored,
                ),
                ckpt_dir=str(tmp_path / "ca"), cluster_fn=cf2, cluster_max=3,
                id_tracker=tracker2, trigger=trigger2, runlog=rl2, seed=1,
            )
            tr2.run(12 - restored)
            rl2.close()
        else:
            tr.run(12)
            rl.close()
        return read_runlog(log)

    crashed, clean = run(True), run(False)

    steps = [r for r in crashed if r["event"] == "step"]
    assert sorted(r["step"] for r in steps) == list(range(12))  # contiguous
    assert len(steps) == 12  # ... and deduped (no replays)
    clean_steps = [r for r in clean if r["event"] == "step"]
    by_step = {r["step"]: r for r in steps}
    for r in clean_steps:  # restart-exact losses, window by window
        assert by_step[r["step"]]["loss"] == r["loss"]

    for ev in ("trigger", "transition"):
        a = [(r["step"], r.get("fire"), r.get("reason")) for r in crashed
             if r["event"] == ev]
        b = [(r["step"], r.get("fire"), r.get("reason")) for r in clean
             if r["event"] == ev]
        assert a == b and len(set(a)) == len(a), ev
    assert any(r["event"] == "transition" for r in crashed)

    # the crash run's extra lifecycle events are real, not noise
    assert sum(r["event"] == "fault" for r in crashed) == 1
    assert sum(r["event"] == "checkpoint_restore" for r in crashed) == 1
    assert any(r["event"] == "checkpoint_save" for r in crashed)
    assert not any(r["event"] in ("fault", "checkpoint_restore")
                   for r in clean)


# --- latency histogram / serve ------------------------------------------------


def test_latency_histogram_percentiles_and_clamping():
    h = LatencyHistogram(lo=1e-3, hi=1.0, n_buckets=20)
    for v in [0.01] * 98 + [0.5] * 2:
        h.observe(v)
    assert h.n == 100
    # upper-edge estimate: true quantile <= reported, within one bucket
    assert 0.01 <= h.percentile(50) <= 0.02
    assert 0.5 <= h.percentile(99) <= 1.0
    h.observe(1e-9)  # clamps into the tail buckets, never dropped
    h.observe(1e9)
    assert h.n == 102
    d = h.to_dict()
    assert d["n"] == 102 and len(d["counts"]) == 20
    assert sum(d["counts"]) == 102


def test_serve_engine_records_latency(tmp_path):
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.serve.engine import Request, ServeEngine

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      dtype=jnp.float32, remat="none")
    params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
    rl = RunLog(tmp_path / "serve.jsonl")
    eng = ServeEngine(cfg, params, buffers, max_batch=2, max_seq=32, runlog=rl)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=np.asarray([5, 17, 3], np.int32),
                           max_tokens=4))
    done = eng.run()
    assert len(done) == 3
    assert all(r.latency_s is not None and r.latency_s > 0 for r in done)
    stats = eng.flush_stats()
    assert stats["n"] == 3 and stats["p99"] >= stats["p50"] > 0
    rl.close()

    recs = read_runlog(tmp_path / "serve.jsonl")
    reqs = [r for r in recs if r["event"] == "request"]
    assert sorted(r["uid"] for r in reqs) == [0, 1, 2]
    assert all(r["n_generated"] == 4 for r in reqs)
    hist = [r for r in recs if r["event"] == "latency_hist"]
    assert len(hist) == 1 and hist[0]["n"] == 3


# --- trace / profiler ---------------------------------------------------------


def test_profile_window_state_machine(tmp_path):
    pw = ProfileWindow(1, 3, log_dir=str(tmp_path / "prof"))
    pw.observe(0)
    assert not pw.active
    pw.observe(1)
    assert pw.active
    jnp.square(jnp.arange(8)).block_until_ready()  # give the trace content
    pw.observe(2)
    assert pw.active
    pw.observe(3)
    assert not pw.active and pw.done
    pw.observe(1)  # one window per process: never re-arms
    assert not pw.active
    pw.close()  # idempotent after done
    assert os.path.isdir(tmp_path / "prof")


# --- summarizer CLI (jax-free) ------------------------------------------------


def _write_synthetic_log(path):
    with RunLog(path, manifest={"config": "t", "backend": "cpu"}) as rl:
        for s in range(10):
            rl.append("step", step=s, loss=1.0 - 0.05 * s, dt=0.01,
                      telemetry={"shard_occupancy": [0.5, 0.4]})
        rl.append("trigger", step=3, entropy=2.0, drift=0.1, fire=False,
                  reason="hold")
        rl.append("trigger", step=6, entropy=1.0, drift=0.9, fire=True,
                  reason="entropy-drop")
        rl.append("transition", step=6, reason="trigger", clusters_done=1)
        rl.append("checkpoint_save", step=5)


def test_summarize_report(tmp_path):
    p = tmp_path / "run.jsonl"
    _write_synthetic_log(p)
    recs = read_runlog(p)
    d = summarize_dict(recs)
    assert d["steps"]["n"] == 10 and d["steps"]["contiguous"]
    assert d["steps"]["loss_last"] == pytest.approx(0.55)
    assert d["steps"]["dt_p50_ms"] == pytest.approx(10.0, rel=0.2)
    assert d["triggers"]["n"] == 2 and d["triggers"]["fired"] == 1
    assert d["transitions"] == [{"step": 6, "reason": "trigger"}]
    assert d["shard_balance"]["skew"] == pytest.approx(0.5 / 0.4)
    text = format_summary(recs)
    assert "steps" in text and "trigger" in text


def test_cli_summarize_and_jax_free_import(tmp_path):
    p = tmp_path / "run.jsonl"
    _write_synthetic_log(p)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env = {**os.environ, "PYTHONPATH": src}
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summarize", str(p),
         "--json", str(tmp_path / "s.json")],
        capture_output=True, text=True, env=env,
    )
    assert out.returncode == 0, out.stderr
    assert "steps" in out.stdout
    assert json.load(open(tmp_path / "s.json"))["steps"]["n"] == 10

    # the CLI path must never pull in jax: run logs are read on hosts
    # without the accelerator stack
    probe = subprocess.run(
        [sys.executable, "-c",
         "import sys, repro.obs, repro.obs.summary, repro.obs.__main__; "
         "assert 'jax' not in sys.modules, 'obs CLI imported jax'"],
        capture_output=True, text=True, env=env,
    )
    assert probe.returncode == 0, probe.stderr

    missing = subprocess.run(
        [sys.executable, "-m", "repro.obs", "summarize",
         str(tmp_path / "nope.jsonl")],
        capture_output=True, text=True, env=env,
    )
    assert missing.returncode == 2
