"""Flash-attention Pallas kernel vs the dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("Sq,S,H,KVH,D", [
    (128, 128, 4, 2, 16),
    (256, 256, 2, 1, 32),
    (64, 64, 8, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_dense(Sq, S, H, KVH, D, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (2, Sq, H, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (2, S, KVH, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv, (2, S, KVH, D)) * 0.5).astype(dtype)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


def test_flash_blocks_smaller_than_seq():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 256, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 16), jnp.float32)
    for bq, bk in ((32, 64), (64, 32), (128, 128)):
        got = ops.flash_attention(q, k, v, bq=bq, bk=bk)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_flash_first_row_attends_self_only():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 64, 1, 8), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 1, 8), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 1, 8), jnp.float32)
    out = ops.flash_attention(q, k, v, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]),
                               rtol=1e-5)
