"""Pod-scale model parallelism (ROADMAP item 1): the sharded supertable.

Four contracts, each pinned by construction rather than tolerance:

  * routing — ``bucket_rows`` partitions global row indices exactly once
    across shards (host/device twins bit-identical), and the
    ``HostTranslator``'s pre-bucketed emission reconstructs the unsharded
    rows tensor exactly;
  * bit-exactness — the all-to-all sharded lookup/forward equals the
    1-device program BIT-exactly (one-hot semantics: each column picks
    one row, so partial sums have at most one nonzero term);
  * memory — no replica holds the full slab, full moments, or full
    pointer table (asserted on live shards AND on the compiled step's
    per-device entry parameters via ``hlo_cost.liveness``);
  * portability — checkpoints cross ``emb_k_multiple`` layouts (sharded
    writer -> 1-device reader and back) bit-exactly through
    ``dlrm.checkpoint_migrations``.

Multi-device cases run in subprocesses that force 4 host devices before
jax initializes, so they exercise real 4-way meshes under the plain
tier-1 lane too.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import dlrm_criteo
from repro.core.collection import bucket_rows
from repro.data import ClickstreamConfig, clickstream_batches
from repro.data.translate import HostTranslator
from repro.launch.mesh import MODEL_AXIS, ptr_partition_spec
from repro.models import dlrm
from repro.optim import sgd
from repro.train.loop import Trainer, init_state, make_train_step, split_buffers


# --- routing: the one greppable at-rest ptr layout policy --------------------


def test_ptr_partition_spec_policy():
    # 1 shard: nothing to split
    assert ptr_partition_spec(4, 100, 1) == P()
    # vocab divides: id-sharded (the transition kernels' compute layout)
    assert ptr_partition_spec(4, 100, 4) == P(None, MODEL_AXIS)
    assert ptr_partition_spec(4, 8, 2, "data") == P(None, "data")
    # ragged vocab (Criteo's 10_131_227 is odd), columns divide: c-sharded
    assert ptr_partition_spec(4, 101, 4) == P(MODEL_AXIS, None)
    # nothing divides: replicated is the only legal layout
    assert ptr_partition_spec(3, 101, 4) == P()


def test_bucket_rows_partitions_exactly_once_and_twins_match():
    rng = np.random.default_rng(0)
    k_pad, n_shards = 16, 4
    k_loc = k_pad // n_shards
    rows = rng.integers(-1, k_pad, size=(5, 3, 7)).astype(np.int32)
    b_np = bucket_rows(rows, k_loc, n_shards, np)
    b_jnp = np.asarray(bucket_rows(jnp.asarray(rows), k_loc, n_shards, jnp))
    np.testing.assert_array_equal(b_np, b_jnp)  # host/device twins

    assert b_np.shape == (n_shards,) + rows.shape
    hit = b_np >= 0
    # every valid global row lands in exactly ONE bucket, sentinel in none
    np.testing.assert_array_equal(hit.sum(axis=0), (rows >= 0).astype(int))
    # and the owning bucket holds the shard-LOCAL index
    recon = np.full_like(rows, -1)
    for s in range(n_shards):
        recon = np.where(hit[s], b_np[s] + s * k_loc, recon)
    np.testing.assert_array_equal(recon, rows)


def test_host_translator_sharded_rows_reconstruct_unsharded():
    M = 4
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512, k_multiple=M)
    coll = cfg.collection
    _, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    tr_flat = HostTranslator(coll, buffers["emb"])
    tr_shard = HostTranslator(coll, buffers["emb"], n_shards=M)

    batch = next(clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0), 32
    ))
    flat = tr_flat.rows(batch["sparse"])          # (B, n_cols, T)
    shard = tr_shard.rows(batch["sparse"])        # (B, M, n_cols, T)
    assert shard.shape == (flat.shape[0], M) + flat.shape[1:]

    # reconstruct global indices: each group buckets by its own k_pad/M
    recon = np.full_like(flat, -1)
    col = 0
    for g in coll.univ_groups:
        grp = coll.groups[g]
        k_loc = grp.k_pad // M
        sl = slice(col, col + grp.n_cols)
        for s in range(M):
            loc = shard[:, s, sl]
            recon[:, sl] = np.where(loc >= 0, loc + s * k_loc, recon[:, sl])
        col += grp.n_cols
    np.testing.assert_array_equal(recon, flat)


# --- checkpoint portability across k_multiple layouts ------------------------


def _unsharded_trainer(cfg, tmp_path, seed=0, ckpt_every=0):
    params, buffers = dlrm.init(jax.random.PRNGKey(seed), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=seed), 16
    )
    return Trainer(
        jax.jit(step, donate_argnums=(0,)), init_state(params, opt, dyn),
        static, data, ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
        migrations=dlrm.checkpoint_migrations(cfg),
    )


def _assert_same_per_feature(cfg_a, state_a, cfg_b, state_b):
    """Bit-equality of two states that differ only in emb k_multiple
    padding, compared through the lossless per-feature view."""
    ca, cb = cfg_a.collection, cfg_b.collection
    for tree_a, tree_b, unstack in (
        (state_a.params["emb"], state_b.params["emb"], "unstack_params"),
        (state_a.opt["m"]["emb"], state_b.opt["m"]["emb"], "unstack_params"),
        (state_a.ebuf["emb"], state_b.ebuf["emb"], "unstack_buffers"),
    ):
        per_a = getattr(ca, unstack)(jax.device_get(tree_a))
        per_b = getattr(cb, unstack)(jax.device_get(tree_b))
        for la, lb in zip(jax.tree.leaves(per_a), jax.tree.leaves(per_b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for k in ("bottom", "top"):
        for la, lb in zip(
            jax.tree.leaves(state_a.params[k]),
            jax.tree.leaves(state_b.params[k]),
        ):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_k_multiple_checkpoint_migration_bitexact(tmp_path):
    """A checkpoint written under the sharded padding (k_multiple=4,
    k_pad 12) restores BIT-exact into a 1-device trainer (k_multiple=1,
    k_pad 9) through the KNOWN_K_MULTIPLES migrations — the pad rows are
    unreachable and provably zero, so the per-feature view loses
    nothing."""
    from repro.checkpoint import save_checkpoint

    cfg4 = dlrm_criteo.reduced(emb_method="cce", cap=300, k_multiple=4)
    cfg1 = dlrm_criteo.reduced(emb_method="cce", cap=300, k_multiple=1)
    pads = lambda c: [c.collection.groups[g].k_pad
                      for g in c.collection.univ_groups]
    assert pads(cfg4) != pads(cfg1)  # the migration genuinely fires

    tr4 = _unsharded_trainer(cfg4, tmp_path)
    tr4.run(3)
    save_checkpoint(
        str(tmp_path), 3, {"state": tr4.state, "clusters_done": np.int32(0)}
    )

    tr1 = _unsharded_trainer(cfg1, tmp_path, seed=1)
    assert tr1.restore_latest() == 3
    _assert_same_per_feature(cfg4, tr4.state, cfg1, tr1.state)
    tr1.run(2)  # and training continues from the migrated state
    assert np.isfinite(tr1.history[-1]["loss"])


# --- forced-4-device system tests --------------------------------------------


_PRELUDE = """
import os
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
assert jax.device_count() == 4, jax.devices()
"""


def _run_forced(code: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [os.path.join(os.path.dirname(__file__), "..", "src"),
                      env.get("PYTHONPATH")])
    )
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(code)], env=env,
        capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "MULTIDEVICE-OK" in r.stdout, r.stdout[-2000:]


@pytest.mark.slow
def test_sharded_step_bitexact_and_per_device_bytes():
    """The sharded lookup/forward is BIT-identical to the 1-device jitted
    program, and neither the live state nor the compiled step's
    per-device entry parameters hold the full slab/moments/ptr."""
    _run_forced("""
    from repro.configs import dlrm_criteo
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.data.translate import HostTranslator
    from repro.launch import hlo_cost
    from repro.launch.mesh import MODEL_AXIS, all_batch_axes, make_host_mesh
    from repro.launch.steps import build_dlrm_train_step
    from repro.models import dlrm
    from repro.optim import sgd
    from repro.train.loop import init_state, split_buffers

    M = 4
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512, k_multiple=M)
    coll = cfg.collection
    mesh = make_host_mesh(data=1, model=M)
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    dyn, static = split_buffers(buffers)

    raw = next(clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0), 32))
    b1 = HostTranslator(coll, buffers["emb"])(raw)
    b4 = HostTranslator(coll, buffers["emb"], n_shards=M)(raw)

    # jitted-vs-jitted (eager MLP fusion differs; the contract is the
    # compiled programs agree): lookup AND full forward, bit-exact
    emb_ref = jax.jit(lambda p, b, r: coll.lookup_all(
        p, b, None, use_kernel=True, rows=r))(
        params["emb"], buffers["emb"], b1["rows"])
    emb_sh = jax.jit(lambda p, b, r: coll.lookup_all(
        p, b, None, use_kernel=True, rows=r, mesh=mesh,
        model_axis=MODEL_AXIS, batch_axes=all_batch_axes(mesh)))(
        params["emb"], buffers["emb"], b4["rows"])
    assert float(jnp.abs(emb_ref - emb_sh).max()) == 0.0
    strip = lambda b: {k: v for k, v in b.items()
                       if k not in ("sparse", "step")}
    out_ref = jax.jit(lambda p, b, bt: dlrm.forward(p, b, cfg, bt))(
        params, buffers, strip(b1))
    out_sh = jax.jit(lambda p, b, bt: dlrm.forward(
        p, b, cfg, bt, mesh=mesh, model_axis=MODEL_AXIS,
        batch_axes=all_batch_axes(mesh)))(params, buffers, strip(b4))
    assert float(jnp.abs(out_ref - out_sh).max()) == 0.0

    # the donated sharded step runs, and its state stays sharded
    optimizer = sgd(momentum=0.9)
    step, (state_shape, batch_struct), (state_sh, _) = build_dlrm_train_step(
        cfg, mesh, batch_size=32, accum=1, optimizer=optimizer,
        static_buffers=static, with_sparse=True)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                         init_state(params, optimizer, dyn), state_sh)
    batch = {k: np.asarray(v)[None] for k, v in b4.items() if k != "step"}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    g = coll.univ_groups[0]
    for arr in (state.params["emb"][g]["tables"],
                state.opt["m"]["emb"][g]["tables"]):
        assert max(s.data.nbytes for s in arr.addressable_shards) * M \\
            == arr.nbytes

    # compiled-step entry params per device: sharded leaves at 1/M
    nbytes = lambda t: sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(t))
    sharded = sum(
        nbytes(state_shape.params["emb"][g]["tables"])
        + nbytes(state_shape.opt["m"]["emb"][g]["tables"])
        + nbytes([fb.get("ptr") for fb in state_shape.ebuf["emb"][g]
                  if isinstance(fb, dict)])
        for g in coll.univ_groups)
    total = nbytes(state_shape) + nbytes(batch_struct)
    est = hlo_cost.liveness(
        step.lower(state_shape, batch_struct).compile().as_text())
    assert est.param_bytes <= (total - sharded) + sharded / M + (1 << 20), (
        est.param_bytes, total, sharded)
    print("MULTIDEVICE-OK")
    """)


@pytest.mark.slow
def test_sharded_trainer_clustering_beats_through_transitions(tmp_path):
    """The paper's central claim holds on the model-parallel trainer:
    interleaved clustering (>= 2 sharded transitions end to end) helps,
    and the state is still sharded afterwards."""
    _run_forced(f"""
    import argparse
    from repro.configs import dlrm_criteo
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.launch.train import build_dlrm_sharded_trainer
    from repro.models import dlrm
    from repro.train.loop import merge_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512, k_multiple=4)

    def train(cluster_every):
        args = argparse.Namespace(
            emb="cce", emb_cap=512, seed=0, batch=64, accum=1, lr=5e-2,
            momentum=0.9, ckpt_dir={str(tmp_path)!r}, ckpt_every=0,
            cluster_every=cluster_every, fail_at=[])
        tr = build_dlrm_sharded_trainer(cfg, args, model=4)
        tr.run(90)
        data_cfg = ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=0)
        batch = next(clickstream_batches(data_cfg, 512, host_id=1,
                                         n_hosts=2))
        buffers = merge_buffers(jax.device_get(tr.state.ebuf),
                                tr.static_buffers)
        bce = float(dlrm.bce_loss(
            jax.device_get(tr.state.params), buffers, cfg, batch))
        return tr, bce

    tr_c, with_c = train(30)
    assert tr_c.clusters_done >= 2, tr_c.clusters_done
    # still sharded after the transitions
    g = cfg.collection.univ_groups[0]
    for arr in (tr_c.state.params["emb"][g]["tables"],
                tr_c.state.opt["m"]["emb"][g]["tables"]):
        assert max(s.data.nbytes for s in arr.addressable_shards) * 4 \\
            == arr.nbytes
    _, without = train(0)
    assert with_c <= without + 0.01, (with_c, without)
    print("MULTIDEVICE-OK")
    """)


@pytest.mark.slow
def test_sharded_checkpoint_roundtrips_with_1device_trainer(tmp_path):
    """A model-sharded trainer's checkpoint restores BIT-exact into a
    1-device trainer (different k_multiple layout) and back, through the
    existing migration machinery — checkpoints store gathered arrays, so
    portability is a pure layout question."""
    dir_a = str(tmp_path / "a")
    dir_b = str(tmp_path / "b")
    _run_forced(f"""
    import argparse
    from repro.configs import dlrm_criteo
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.launch.train import build_dlrm_sharded_trainer
    from repro.models import dlrm
    from repro.optim import sgd
    from repro.train.loop import (
        Trainer, init_state, make_train_step, split_buffers)

    cfg4 = dlrm_criteo.reduced(emb_method="cce", cap=300, k_multiple=4)
    cfg1 = dlrm_criteo.reduced(emb_method="cce", cap=300, k_multiple=1)

    def sharded(ckpt_dir):
        args = argparse.Namespace(
            emb="cce", emb_cap=300, seed=0, batch=32, accum=1, lr=1e-2,
            momentum=0.9, ckpt_dir=ckpt_dir, ckpt_every=4,
            cluster_every=0, fail_at=[])
        return build_dlrm_sharded_trainer(cfg4, args, model=4)

    def onedev(ckpt_dir, ckpt_every=0):
        params, buffers = dlrm.init(jax.random.PRNGKey(1), cfg1)
        dyn, static = split_buffers(buffers)
        opt = sgd(momentum=0.9)
        step = make_train_step(
            lambda p, b, mb: (dlrm.bce_loss(p, b, cfg1, mb), {{}}),
            opt, lambda s: jnp.float32(1e-2), static)
        data = clickstream_batches(ClickstreamConfig(
            vocab_sizes=cfg1.vocab_sizes, seed=0), 32)
        return Trainer(
            jax.jit(step, donate_argnums=(0,)),
            init_state(params, opt, dyn), static, data,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            migrations=dlrm.checkpoint_migrations(cfg1))

    def same_per_feature(cfg_a, sa, cfg_b, sb):
        ca, cb = cfg_a.collection, cfg_b.collection
        pairs = [
            (sa.params["emb"], sb.params["emb"], "unstack_params"),
            (sa.opt["m"]["emb"], sb.opt["m"]["emb"], "unstack_params"),
            (sa.ebuf["emb"], sb.ebuf["emb"], "unstack_buffers"),
        ]
        for ta, tb, un in pairs:
            pa = getattr(ca, un)(jax.device_get(ta))
            pb = getattr(cb, un)(jax.device_get(tb))
            for la, lb in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        for k in ("bottom", "top"):
            for la, lb in zip(jax.tree.leaves(sa.params[k]),
                              jax.tree.leaves(sb.params[k])):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    # sharded writer -> 1-device reader
    tr4 = sharded({dir_a!r})
    tr4.run(4)           # auto-saves at step 4
    tr4.ckpt.wait()
    tr1 = onedev({dir_a!r})
    assert tr1.restore_latest() == 4
    same_per_feature(cfg4, tr4.state, cfg1, tr1.state)

    # 1-device writer -> sharded reader
    tr1b = onedev({dir_b!r}, ckpt_every=2)
    tr1b.run(2)
    tr1b.ckpt.wait()
    tr4b = sharded({dir_b!r})
    assert tr4b.restore_latest() == 2
    same_per_feature(cfg1, tr1b.state, cfg4, tr4b.state)
    # and the restored state landed on the sharded layout
    g4 = cfg4.collection.univ_groups[0]
    slab = tr4b.state.params["emb"][g4]["tables"]
    assert max(s.data.nbytes for s in slab.addressable_shards) * 4 \\
        == slab.nbytes
    tr4b.run(2)  # trains on from the restored sharded state
    print("MULTIDEVICE-OK")
    """)
