"""Planted-violation suite for repro.analysis.

Every shipped rule gets (a) a deliberately broken toy program it MUST
flag and (b) a clean program it MUST pass — the rules are the CI gate,
so the gate itself is what's under test here.  Plus: walker traversal
through scan/cond sub-jaxprs, the AST source rules on tmp files, the
CLI exit-code contract, and an integration run of the real reduced
DLRM audit bundle.
"""
import json
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    RULES,
    AuditProgram,
    ConstantCapture,
    DeadInput,
    DonationCoverage,
    DtypeHygiene,
    LaunchBudget,
    NoDeviceGatherOf,
    NoHostCallback,
    NoTransfers,
    count_primitive,
    register,
    used_var_ids,
    walk,
)
from repro.analysis.rules import _is_real_transfer
from repro.analysis.source_rules import check_source_file, run_source_rules
from repro.compat import pallas as pl


def _launch(x):
    """One tiny pallas launch (interpret mode — jaxpr structure is what
    the rules audit, not the backend)."""

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + 1.0

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def _capture(fn, *args, **kw):
    return AuditProgram.capture(fn, *args, name="toy", **kw)


X = jnp.ones((8,), jnp.float32)


# --- registry ---------------------------------------------------------------


def test_registry_has_every_shipped_rule():
    import repro.analysis.cost_rules  # noqa: F401 — registers the cost rules

    assert set(RULES) == {
        "launch-budget", "no-device-gather", "donation-coverage",
        "dtype-hygiene", "no-host-callback", "no-transfers",
        "constant-capture", "dead-input",
        "flop-budget", "bytes-budget", "peak-memory-budget",
        "collective-budget", "no-replicated-param",
    }


def test_registry_rejects_duplicates_and_missing_ids():
    with pytest.raises(ValueError, match="duplicate"):
        register(type("Fake", (), {"id": "launch-budget"}))
    with pytest.raises(ValueError, match="no id"):
        register(type("Anon", (), {"id": ""}))


# --- walker -----------------------------------------------------------------


def test_walker_recurses_into_scan_and_cond():
    def scanned(x):
        def body(c, _):
            return _launch(c), None

        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    closed = jax.make_jaxpr(scanned)(X)
    assert count_primitive(closed, "pallas_call") == 1  # ONE eqn, 3 trips
    paths = [s.path for s in walk(closed) if s.primitive == "pallas_call"]
    assert len(paths) == 1 and "scan" in paths[0]  # found INSIDE the body

    def conded(x):
        return jax.lax.cond(x[0] > 0, _launch, lambda v: v, x)

    assert count_primitive(jax.make_jaxpr(conded)(X), "pallas_call") == 1


def test_used_var_ids_exact_for_top_level_invars():
    closed = jax.make_jaxpr(lambda a, b: a * 2.0)(X, X)
    used = used_var_ids(closed, include_outputs=False)
    a_var, b_var = closed.jaxpr.invars
    assert id(a_var) in used and id(b_var) not in used


# --- LaunchBudget -----------------------------------------------------------


def test_launch_budget_flags_extra_launch():
    assert LaunchBudget(1).check(_capture(_launch, X)) == []
    found = LaunchBudget(1).check(_capture(lambda x: _launch(_launch(x)), X))
    assert len(found) == 1 and found[0].rule == "launch-budget"
    assert "2 pallas_call" in found[0].message
    assert "pallas_call" in found[0].where  # points at the extra site


def test_launch_budget_exact_flags_missing_launch():
    # exact=True also catches the launch DISAPPEARING (fusion regressed
    # to a pure-XLA gather without anyone noticing)
    found = LaunchBudget(1).check(_capture(lambda x: x + 1.0, X))
    assert len(found) == 1 and "0 pallas_call" in found[0].message
    assert LaunchBudget(1, exact=False).check(_capture(lambda x: x + 1.0, X)) == []


# --- NoDeviceGatherOf -------------------------------------------------------


def test_no_device_gather_flags_consumed_pointer_input():
    tree = {"ptr": jnp.zeros((4,), jnp.int32), "w": X}
    rule = NoDeviceGatherOf(("ptr",))
    assert rule.check(_capture(lambda d: d["w"] * 2.0, tree)) == []
    found = rule.check(
        _capture(lambda d: d["w"] + d["ptr"].astype(jnp.float32).sum(), tree)
    )
    assert len(found) == 1 and "'ptr'" in found[0].where


def test_no_device_gather_refuses_vacuous_pass():
    # no input named ptr at all -> the spec is mislabeled, not "clean"
    found = NoDeviceGatherOf(("ptr",)).check(_capture(lambda d: d["w"], {"w": X}))
    assert len(found) == 1 and "vacuous" in found[0].message


# --- DonationCoverage -------------------------------------------------------


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donation_coverage_passes_aliased_and_flags_unaliased():
    state = {"a": X, "b": jnp.zeros((3,), jnp.float32)}
    good = _capture(
        lambda s: {k: v + 1.0 for k, v in s.items()},
        state, donate_argnums=(0,),
    )
    assert DonationCoverage().check(good) == []

    # output shapes match nothing -> XLA can alias no donated buffer
    bad = _capture(lambda s: s["a"].sum(), state, donate_argnums=(0,))
    found = DonationCoverage().check(bad)
    assert len(found) == 1 and "2 leaves donated" in found[0].message


def test_donation_coverage_refuses_undonated_program():
    found = DonationCoverage().check(_capture(lambda s: s, {"a": X}))
    assert len(found) == 1 and "donates nothing" in found[0].message


# --- DtypeHygiene -----------------------------------------------------------


def test_dtype_hygiene_flags_f64():
    assert DtypeHygiene().check(_capture(lambda x: x * 2.0, X)) == []
    with jax.experimental.enable_x64():  # audit: allow-raw-experimental
        bad = _capture(
            lambda x: x * 2.0, jax.ShapeDtypeStruct((4,), jnp.float64)
        )
    found = DtypeHygiene().check(bad)
    assert found and all(f.rule == "dtype-hygiene" for f in found)
    assert "float64" in found[0].message


# --- NoHostCallback ---------------------------------------------------------


def test_no_host_callback_flags_pure_callback():
    def with_cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )

    found = NoHostCallback().check(_capture(with_cb, X))
    assert len(found) == 1 and "pure_callback" in found[0].message
    assert NoHostCallback().check(_capture(lambda x: x * 2.0, X)) == []


# --- NoTransfers ------------------------------------------------------------


def test_no_transfers_flags_concrete_placement():
    cpu0 = jax.devices("cpu")[0]
    found = NoTransfers().check(
        _capture(lambda x: jax.device_put(x, cpu0) + 1.0, X)
    )
    assert len(found) == 1 and found[0].rule == "no-transfers"


def test_no_transfers_ignores_alias_noop_and_fails_closed():
    class Sem:
        def __str__(self):
            return "CopySemantics.ALIAS"

    benign = types.SimpleNamespace(
        params={"devices": [None], "srcs": [None], "copy_semantics": [Sem()]}
    )
    assert not _is_real_transfer(benign)
    placed = types.SimpleNamespace(
        params={"devices": ["cpu:0"], "srcs": [None], "copy_semantics": [Sem()]}
    )
    assert _is_real_transfer(placed)
    # unknown param shape (jax drift) must flag, not silently pass
    assert _is_real_transfer(types.SimpleNamespace(params={}))


# --- ConstantCapture --------------------------------------------------------


def test_constant_capture_flags_large_baked_const():
    big = jnp.arange(1 << 15, dtype=jnp.float32)  # 128 KiB, closed over
    found = ConstantCapture(max_bytes=1 << 16).check(
        _capture(lambda x: x + big.sum(), X)
    )
    assert len(found) == 1 and "pass it as an argument" in found[0].message

    small = jnp.arange(8, dtype=jnp.float32)
    assert ConstantCapture(max_bytes=1 << 16).check(
        _capture(lambda x: x + small.sum(), X)
    ) == []


# --- DeadInput --------------------------------------------------------------


def test_dead_input_flags_unconsumed_leaf_unless_allowed():
    tree = {"a": X, "b": jnp.zeros((3,), jnp.float32)}
    found = DeadInput().check(_capture(lambda d: d["a"] * 2.0, tree))
    assert len(found) == 1 and "'b'" in found[0].where
    assert DeadInput(allow=("b",)).check(
        _capture(lambda d: d["a"] * 2.0, tree)
    ) == []
    # passing an input through to the output counts as consumption
    assert DeadInput().check(_capture(lambda d: d, tree)) == []


# --- AST source rules -------------------------------------------------------


def _write(tmp_path, name, body):
    p = tmp_path / name
    p.write_text(textwrap.dedent(body))
    return str(p)


def test_fuse_rows_twin_rule(tmp_path):
    bad = _write(tmp_path, "bad.py", """
        class T:
            def fuse_rows(self, ids):
                return ids
    """)
    assert [f.rule for f in check_source_file(bad)] == ["fuse-rows-twin"]
    good = _write(tmp_path, "good.py", """
        class T:
            def fuse_rows(self, ids):
                return ids

            def fuse_rows_np(self, ids):
                return ids
    """)
    assert check_source_file(good) == []


def test_int_cast_rule_scoped_to_jax_modules(tmp_path):
    bad = _write(tmp_path, "bad.py", """
        import jax.numpy as jnp

        def f(x):
            return int(x.sum()), x.max().item()
    """)
    assert [f.rule for f in check_source_file(bad)] == [
        "no-int-cast", "no-int-cast",
    ]
    # identical code in a pure-numpy module holds no traced values
    pure = _write(tmp_path, "pure.py", """
        import numpy as np

        def f(x):
            return int(x.sum()), x.max().item()
    """)
    assert check_source_file(pure) == []
    waived = _write(tmp_path, "waived.py", """
        import jax

        def f(x):
            return int(x.sum())  # audit: allow-int-cast
    """)
    assert check_source_file(waived) == []


def test_stale_waiver_is_itself_a_finding(tmp_path):
    # the excused int() was removed but the waiver stayed behind
    stale = _write(tmp_path, "stale.py", """
        import jax

        def f(x):
            return x.sum()  # audit: allow-int-cast
    """)
    found = check_source_file(stale)
    assert [f.rule for f in found] == ["stale-waiver"]
    assert "allow-int-cast" in found[0].message
    # a misspelled tag suppresses nothing AND is called out as unknown
    typo = _write(tmp_path, "typo.py", """
        import jax

        def f(x):
            return int(x.sum())  # audit: allow-int-casts
    """)
    rules = sorted(f.rule for f in check_source_file(typo))
    assert rules == ["no-int-cast", "stale-waiver"]
    assert any("unknown tag" in f.message for f in check_source_file(typo))


def test_waiver_text_inside_strings_is_inert(tmp_path):
    # prose about waivers (docstrings, messages) is neither a suppression
    # nor stale — only COMMENT tokens count
    doc = _write(tmp_path, "doc.py", '''
        import jax

        def f(x):
            """Host-side casts need `# audit: allow-int-cast` waivers."""
            return x.sum()
    ''')
    assert check_source_file(doc) == []
    # ...and a string does NOT suppress a real finding on its line
    inline = _write(tmp_path, "inline.py", """
        import jax

        def f(x):
            return int(x.sum()), "audit: allow-int-cast"
    """)
    assert [f.rule for f in check_source_file(inline)] == ["no-int-cast"]


def test_raw_experimental_rule_excepts_compat(tmp_path):
    bad = _write(tmp_path, "bad.py", """
        from jax.experimental import pallas as pl
    """)
    assert [f.rule for f in check_source_file(bad)] == ["no-raw-experimental"]
    compat = _write(tmp_path, "compat.py", """
        from jax.experimental import pallas as pl
    """)
    assert check_source_file(compat) == []
    shimmed = _write(tmp_path, "shimmed.py", """
        from repro.compat import pallas as pl
    """)
    assert check_source_file(shimmed) == []


def test_source_rules_walk_and_syntax_finding(tmp_path):
    _write(tmp_path, "broken.py", "def f(:\n")
    _write(tmp_path, "ok.py", "x = 1\n")
    found = run_source_rules(str(tmp_path))
    assert [f.rule for f in found] == ["syntax"]


def test_repo_source_tree_is_clean():
    assert run_source_rules("src/repro") == []


# --- integration: the real audit bundle + CLI -------------------------------


def test_reduced_dlrm_audit_is_green():
    from repro.analysis import run_audit

    report = run_audit("dlrm_criteo_reduced")
    assert report.ok, report.to_json()
    assert [p["name"] for p in report.programs] == [
        "fwd", "grad", "train_step", "train_step_telemetry", "serve_lookup",
        "serve_dlrm_cold", "serve_dlrm_hit",
    ]
    # the report records the launch counts the budgets pinned
    by_name = {p["name"]: p for p in report.programs}
    assert by_name["fwd"]["n_eqns_by_primitive"]["pallas_call"] == 1
    assert by_name["train_step"]["n_eqns_by_primitive"]["pallas_call"] == 2
    # telemetry is free: same launch count as the bare step
    assert (
        by_name["train_step_telemetry"]["n_eqns_by_primitive"]["pallas_call"]
        == 2
    )
    # serve: ONE fused launch on the cold path, ZERO on a fully-hit batch
    assert (
        by_name["serve_dlrm_cold"]["n_eqns_by_primitive"]["pallas_call"] == 1
    )
    assert (
        by_name["serve_dlrm_hit"]["n_eqns_by_primitive"].get("pallas_call", 0)
        == 0
    )


def test_cli_source_only_exit_codes(tmp_path):
    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "m.py").write_text("x = 1\n")
    dirty = tmp_path / "dirty"
    dirty.mkdir()
    (dirty / "m.py").write_text("from jax.experimental import pallas\n")

    import os

    import repro.analysis as _mod

    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(_mod.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")

    def run(root):
        out = tmp_path / "report.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--source-only",
             "--source-root", str(root), "--json", str(out)],
            capture_output=True, text=True, env=env,
        )
        return proc, json.loads(out.read_text())

    proc, rep = run(clean)
    assert proc.returncode == 0 and rep["ok"] is True
    proc, rep = run(dirty)
    assert proc.returncode == 1 and rep["ok"] is False
    assert rep["source_findings"][0]["rule"] == "no-raw-experimental"
