"""EmbeddingCollection: grouped supertables == the per-table loop.

The refactor's contract, asserted here:
  * universal fusion drops heavy lookups from O(n_features) to ONE launch
    on a compressed config (``n_lookup_launches`` AND a jaxpr-level
    pallas_call count, so a refactor can't silently reintroduce the
    per-feature loop),
  * the fused path (Pallas kernel AND jnp oracle) is numerically
    equivalent to the legacy per-feature loop — forward and gradients —
    for every fusable method (CCE, hash, CE-concat, small full tables),
  * ragged codebooks (different k in one group), mixed methods in one
    supertable, and the padded full-table gather are exact,
  * host-side pointer translation (``data.translate``) is BIT-exact with
    the device row path and leaves the pointer buffers untouched,
  * pre-collection (per-feature layout) checkpoints restore BIT-EXACT
    through ``Trainer.restore_latest`` + ``dlrm.checkpoint_migrations``,
  * the collection-backed transition keeps the Trainer protocol intact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.core.cce import CCE
from repro.core.collection import EmbeddingCollection
from repro.core.embeddings import CEConcat, FullTable, HashingTrick
from repro.models import dlrm
from repro.models.dlrm import DLRMConfig
from repro.optim import sgd


MIXED = DLRMConfig(
    vocab_sizes=(8, 1000, 20, 5000, 16, 300),
    n_dense=13, emb_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
    emb_method="cce", emb_param_cap=512,
)


def _batch(cfg, B=9, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(
            np.stack([rng.integers(0, v, B) for v in cfg.vocab_sizes], axis=1),
            jnp.int32,
        ),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32),
    }


def _per_feature_lookup(coll, emb_params, emb_buffers, sparse):
    """The legacy hot loop: one lookup per feature."""
    per_p = coll.unstack_params(emb_params)
    per_b = coll.unstack_buffers(emb_buffers)
    return jnp.stack(
        [
            coll.tables[i].lookup(per_p[i], per_b[i], sparse[:, i])
            for i in range(coll.n_features)
        ],
        axis=1,
    )


# --- grouping ------------------------------------------------------------


def test_grouping_collapses_launches():
    # all-compressed reduced config: every table fuses into ONE launch
    coll = dlrm_criteo.reduced(emb_method="cce", cap=512).collection
    assert coll.n_features == 5 and coll.n_groups == 1
    assert coll.n_lookup_launches == 1
    assert coll.groups[0].kind == "univ"
    # mixed cce/full config: the small full tables JOIN the supertable
    # (identity rows, T-sentinel padding) — still ONE launch
    coll = MIXED.collection
    assert [g.kind for g in coll.groups] == ["univ"]
    assert coll.n_lookup_launches == 1
    # every feature appears in exactly one group
    feats = sorted(i for g in coll.groups for i in g.features)
    assert feats == list(range(coll.n_features))


def test_criteo_config_is_one_launch():
    """The acceptance criterion: the full Criteo DLRM config (capped
    CCE + small full tables) issues ONE heavy embedding launch."""
    coll = dlrm_criteo.CONFIG.collection
    assert coll.n_features == 26
    assert coll.n_lookup_launches == 1
    assert [g.kind for g in coll.groups] == ["univ"]


def test_hash_and_ce_groups_fuse():
    """The QREmbeddingBag lesson applies to the hashed methods too: one
    launch, not a per-feature loop (the PR-3 fallback)."""
    for method in ("hash", "ce"):
        coll = dlrm_criteo.reduced(emb_method=method, cap=512).collection
        assert coll.n_lookup_launches == 1, method
        assert [g.kind for g in coll.groups] == ["univ"], method


def test_full_groups_split_on_pathological_padding():
    """A (tiny, huge) full-table mix must NOT pad the tiny table to the
    huge vocab (full-only buckets keep the padded batched gather — a
    one-hot matmul over d1 rows has nothing to amortize against)."""
    tables = tuple(FullTable(d1, 16) for d1 in (8, 16, 100_000))
    coll = EmbeddingCollection.build(tables)
    full_groups = [g for g in coll.groups if g.kind == "full"]
    assert len(full_groups) == 2  # {8, 16} together, 100k alone
    assert not [g for g in coll.groups if g.kind == "univ"]
    sizes = sorted(tuple(t.d1 for t in g.tables) for g in full_groups)
    assert sizes == [(8, 16), (100_000,)]


def test_big_full_tables_stay_out_of_the_supertable():
    """A full table whose d1 dwarfs the compressed codebooks must not
    join the one-hot supertable (k_pad would explode); it keeps the
    gather path."""
    tables = (CCE(d1=10_000, d2=16, k=16, c=4), FullTable(100_000, 16))
    coll = EmbeddingCollection.build(tables)
    assert sorted(g.kind for g in coll.groups) == ["full", "univ"]
    assert coll.n_lookup_launches == 2


def test_univ_groups_split_on_k_spread():
    """One huge-k member must not inflate every other member's codebook
    axis (params, moments and one-hot work all scale with k_pad): the
    waste bound splits the bucket instead."""
    tables = (
        CCE(d1=10_000, d2=16, k=16, c=4, seed_salt=0),
        HashingTrick(d1=500_000, d2=16, k=100_000, seed_salt=1),
    )
    coll = EmbeddingCollection.build(tables)
    assert [g.kind for g in coll.groups] == ["univ", "univ"]
    assert coll.n_lookup_launches == 2
    # the CCE slab keeps its natural codebook size, not the hash table's
    params, _ = coll.init(jax.random.PRNGKey(0))
    g_cce = coll._locate[0][0]
    assert params[g_cce]["tables"].shape[2] == 16


def test_univ_waste_bound_is_per_member_too():
    """A dominant huge-k member must not carry a tiny member to
    megabytes of dead padding even when the AGGREGATE ratio looks fine
    (the 8-row table would be padded to a 100k-row codebook while
    barely moving the bucket total)."""
    tables = (
        HashingTrick(d1=500_000, d2=16, k=100_000, seed_salt=0),
        FullTable(8, 16),
    )
    coll = EmbeddingCollection.build(tables)
    # the tiny full table splits off; alone it reverts to the gather
    assert sorted(g.kind for g in coll.groups) == ["full", "univ"]
    # ...while Criteo's tiny full tables still fuse (absolute slack:
    # kilobytes of padding buys the single launch)
    assert dlrm_criteo.CONFIG.collection.n_lookup_launches == 1


def test_loop_fallback_for_unfusable_methods():
    coll = dlrm_criteo.reduced(emb_method="robe", cap=512).collection
    assert all(g.kind == "loop" for g in coll.groups)
    assert coll.n_lookup_launches == coll.n_features


def test_collection_modes_are_benchmark_baselines():
    """mode="group"/"loop" reproduce the pre-universal groupings (for
    bench_kernels --fuse) and agree numerically with the default."""
    coll = MIXED.collection
    key = jax.random.PRNGKey(3)
    p1, b1 = coll.init(key)
    sparse = _batch(MIXED, B=19, seed=5)["sparse"]
    want = coll.lookup_all(p1, b1, sparse, use_kernel=False)
    legacy = EmbeddingCollection.build(coll.tables, mode="group")
    assert sorted(g.kind for g in legacy.groups) == ["full", "univ"]
    loop = EmbeddingCollection.build(coll.tables, mode="loop")
    assert loop.n_lookup_launches == loop.n_features
    for c2 in (legacy, loop):
        p2, b2 = c2.init(key)
        got = c2.lookup_all(p2, b2, sparse, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cached_collection_is_not_reconstructed():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    assert cfg.collection is cfg.collection  # cached_property, one build
    assert cfg.table(0) is cfg.collection.tables[0]


# --- numerics: fused == looped --------------------------------------------


@pytest.mark.parametrize("use_kernel", [True, False])
def test_lookup_all_matches_per_feature_loop(use_kernel):
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    sparse = _batch(cfg, B=33)["sparse"]  # B not a block multiple
    got = coll.lookup_all(
        params["emb"], buffers["emb"], sparse, use_kernel=use_kernel
    )
    want = _per_feature_lookup(coll, params["emb"], buffers["emb"], sparse)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("use_kernel", [True, False])
def test_lookup_all_grads_match_per_feature_loop(use_kernel):
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(1), cfg)
    sparse = _batch(cfg, B=17, seed=1)["sparse"]
    co = jax.random.normal(jax.random.PRNGKey(2), (17, cfg.n_sparse, cfg.emb_dim))

    def loss_fused(emb_p):
        out = coll.lookup_all(emb_p, buffers["emb"], sparse, use_kernel=use_kernel)
        return jnp.sum(out * co)

    def loss_looped(emb_p):
        out = _per_feature_lookup(coll, emb_p, buffers["emb"], sparse)
        return jnp.sum(out * co)

    g1 = jax.grad(loss_fused)(params["emb"])
    g2 = jax.grad(loss_looped)(params["emb"])
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
        )


def test_dlrm_forward_kernel_path_matches_jnp_path():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=21)
    out_k = dlrm.forward(params, buffers, cfg, batch)
    cfg_j = dataclasses.replace(cfg, emb_use_kernel=False)
    out_j = dlrm.forward(params, buffers, cfg_j, batch)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_j), rtol=1e-5, atol=1e-5
    )
    g_k = jax.grad(lambda p: dlrm.bce_loss(p, buffers, cfg, batch))(params)
    g_j = jax.grad(lambda p: dlrm.bce_loss(p, buffers, cfg_j, batch))(params)
    for a, b in zip(jax.tree.leaves(g_k), jax.tree.leaves(g_j)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6
        )


def test_ragged_codebooks_fuse_exactly():
    """Two CCE tables with DIFFERENT k in one group: the supertable pads
    the codebook axis and lookups stay exact, grads land only in real rows."""
    t1 = CCE(d1=100, d2=16, k=5, c=4, seed_salt=0)
    t2 = CCE(d1=200, d2=16, k=12, c=4, seed_salt=1)
    coll = EmbeddingCollection.build((t1, t2))
    assert coll.n_groups == 1 and coll.groups[0].kind == "univ"
    params, buffers = coll.init(jax.random.PRNGKey(0))
    assert params[0]["tables"].shape == (8, 2, 12, 4)  # padded to max k
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 100, (13, 2)), jnp.int32)
    got = coll.lookup_all(params, buffers, ids, use_kernel=True)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    # gradient never touches the padding rows of the small-k table
    g = jax.grad(
        lambda p: jnp.sum(coll.lookup_all(p, buffers, ids, use_kernel=True) ** 2)
    )(params)
    assert float(np.abs(np.asarray(g[0]["tables"][:4, :, 5:, :])).max()) == 0.0


def test_full_group_clamps_out_of_range_ids_like_per_table():
    """An id >= a small table's vocab must clamp to ITS last row (the
    per-table XLA gather semantics), not read — or train — the padding
    rows of the stacked (F, max d1, d2) table."""
    tables = (FullTable(4, 8), FullTable(16, 8))
    coll = EmbeddingCollection.build(tables)
    params, buffers = coll.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([[4, 0], [99, 15]], jnp.int32)  # 4, 99 out of range for d1=4
    got = coll.lookup_all(params, buffers, ids)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # gradient lands in the clamped real row, never in the padding
    g = jax.grad(lambda p: jnp.sum(coll.lookup_all(p, buffers, ids) ** 2))(params)
    assert float(np.abs(np.asarray(g[0]["table"][0, 4:])).max()) == 0.0


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("method", ["hash", "ce"])
def test_fused_hash_ce_matches_loop_fallback(method, use_kernel):
    """Fused hash/CEConcat groups vs the per-feature loop: forward AND
    gradient, ragged k within the group, B not a block multiple."""
    if method == "hash":
        tables = (
            HashingTrick(d1=1000, d2=16, k=24, seed_salt=0),
            HashingTrick(d1=5000, d2=16, k=64, seed_salt=1),  # ragged k
            HashingTrick(d1=77, d2=16, k=8, seed_salt=2),
        )
    else:
        tables = (
            CEConcat(d1=1000, d2=16, k=24, c=4, seed_salt=0),
            CEConcat(d1=5000, d2=16, k=64, c=4, seed_salt=1),
            CEConcat(d1=77, d2=16, k=8, c=4, seed_salt=2),
        )
    coll = EmbeddingCollection.build(tables)
    assert coll.n_lookup_launches == 1 and coll.groups[0].kind == "univ"
    params, buffers = coll.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B = 33  # not a multiple of b_blk
    ids = jnp.asarray(
        np.stack([rng.integers(0, t.d1, B) for t in tables], axis=1), jnp.int32
    )
    got = coll.lookup_all(params, buffers, ids, use_kernel=use_kernel)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )
    co = jax.random.normal(jax.random.PRNGKey(1), got.shape)
    g1 = jax.grad(
        lambda p: jnp.sum(
            coll.lookup_all(p, buffers, ids, use_kernel=use_kernel) * co
        )
    )(params)
    g2 = jax.grad(
        lambda p: jnp.sum(_per_feature_lookup(coll, p, buffers, ids) * co)
    )(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
        )


@pytest.mark.parametrize("use_kernel", [True, False])
def test_mixed_method_supertable_matches_loop(use_kernel):
    """CCE + hash + CE + full tables in ONE supertable launch: sub-column
    splitting (hash dsub 16 -> group gcd 4) and sentinel T-padding
    compose, forward and gradient."""
    tables = (
        CCE(d1=2000, d2=16, k=16, c=4, seed_salt=0),
        HashingTrick(d1=900, d2=16, k=32, seed_salt=1),
        CEConcat(d1=700, d2=16, k=12, c=4, seed_salt=2),
        FullTable(40, 16),
    )
    coll = EmbeddingCollection.build(tables)
    assert coll.n_lookup_launches == 1
    grp = coll.groups[0]
    assert grp.kind == "univ" and grp.dsub == 4 and grp.n_tables == 2
    assert grp.col_counts == (4, 4, 4, 4)  # hash/full split 16 -> 4x4
    params, buffers = coll.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    ids = jnp.asarray(
        np.stack([rng.integers(0, t.d1, 21) for t in tables], axis=1), jnp.int32
    )
    got = coll.lookup_all(params, buffers, ids, use_kernel=use_kernel)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )
    co = jax.random.normal(jax.random.PRNGKey(3), got.shape)
    g1 = jax.grad(
        lambda p: jnp.sum(
            coll.lookup_all(p, buffers, ids, use_kernel=use_kernel) * co
        )
    )(params)
    g2 = jax.grad(
        lambda p: jnp.sum(_per_feature_lookup(coll, p, buffers, ids) * co)
    )(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
        )
    # the single-sub-table members' sentinel T slots get EXACTLY zero
    # gradient (they must stay zero so stacking stays value-preserving)
    slab_g = g1[0]["tables"]  # (16, 2, k_pad, 4)
    assert float(jnp.abs(slab_g[4:, 1]).max()) == 0.0  # hash/ce/full helpers


# --- launch counting at the jaxpr level ------------------------------------


def test_jaxpr_launch_count_matches_n_lookup_launches():
    """The regression guard behind ``n_lookup_launches``: the lowered
    program really contains exactly ONE pallas launch for the forward
    (and one more for the backward scatter-add)."""
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    sparse = _batch(cfg, B=16)["sparse"]

    from repro.analysis import count_primitive

    fwd = jax.make_jaxpr(
        lambda p: coll.lookup_all(p, buffers["emb"], sparse, use_kernel=True)
    )(params["emb"])
    assert count_primitive(fwd, "pallas_call") == coll.n_lookup_launches == 1

    grad = jax.make_jaxpr(
        jax.grad(
            lambda p: jnp.sum(
                coll.lookup_all(p, buffers["emb"], sparse, use_kernel=True)
            )
        )
    )(params["emb"])
    assert count_primitive(grad, "pallas_call") == 2  # fwd + bwd, nothing else

    # whole-model check: the full DLRM loss step still lowers to exactly
    # one forward launch
    batch = _batch(cfg, B=16)
    cfg_k = dataclasses.replace(cfg, emb_use_kernel=True)
    loss_jaxpr = jax.make_jaxpr(
        lambda p: dlrm.bce_loss(p, buffers, cfg_k, batch)
    )(params)
    assert count_primitive(loss_jaxpr, "pallas_call") == 1


# --- host-side pointer translation (DESIGN.md §4/§6) -----------------------


def test_host_translated_rows_match_device_bitexact():
    from repro.data import HostTranslator

    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(4)
    sparse = np.stack(
        [rng.integers(0, v, 33) for v in cfg.vocab_sizes], axis=1
    ).astype(np.int32)
    tr = HostTranslator(coll, buffers["emb"])
    rows = tr.rows(sparse)
    assert rows.shape == (33, coll.rows_n_cols, coll.rows_n_tables)
    # host rows == device rows, bit for bit
    dev = coll.group_rows(coll.groups[0], buffers["emb"][0], jnp.asarray(sparse))
    np.testing.assert_array_equal(np.moveaxis(rows, 0, 1), np.asarray(dev))
    # lookup through host rows == device-translated lookup, bit for bit
    for uk in (True, False):
        a = coll.lookup_all(
            params["emb"], buffers["emb"], jnp.asarray(sparse), use_kernel=uk
        )
        b = coll.lookup_all(
            params["emb"], buffers["emb"], None, use_kernel=uk,
            rows=jnp.asarray(rows),
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_host_translation_clamps_out_of_range_ids_like_device():
    """Dirty ids must not crash (or diverge from) the host translator:
    the jitted device gather clamps, so the numpy twin clamps too —
    bit-exact rows either way."""
    from repro.data import HostTranslator

    cfg = MIXED
    coll = cfg.collection
    _, buffers = dlrm.init(jax.random.PRNGKey(9), cfg)
    tr = HostTranslator(coll, buffers["emb"])
    # ids at and past every feature's vocab edge
    sparse = np.stack(
        [np.array([0, v - 1, v, v + 99]) for v in cfg.vocab_sizes], axis=1
    ).astype(np.int32)
    rows = tr.rows(sparse)
    dev = jax.jit(
        lambda ids: coll.group_rows(coll.groups[0], buffers["emb"][0], ids)
    )(jnp.asarray(sparse))
    np.testing.assert_array_equal(np.moveaxis(rows, 0, 1), np.asarray(dev))


def test_host_translation_tracks_transitions():
    """The mirrors are snapshots: after a clustering transition rewrites
    ptr/hs, ``update`` re-syncs and parity holds again."""
    from repro.data import HostTranslator
    from repro.train.transition import transition_collection

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(5), cfg)
    tr = HostTranslator(coll, buffers["emb"])
    new_p, new_b, _ = transition_collection(
        coll, jax.random.PRNGKey(6), params["emb"], buffers["emb"]
    )
    tr.update(new_b)
    rng = np.random.default_rng(6)
    sparse = np.stack(
        [rng.integers(0, v, 17) for v in cfg.vocab_sizes], axis=1
    ).astype(np.int32)
    rows = tr.rows(sparse)
    dev = coll.group_rows(coll.groups[0], new_b[0], jnp.asarray(sparse))
    np.testing.assert_array_equal(np.moveaxis(rows, 0, 1), np.asarray(dev))
    a = coll.lookup_all(new_p, new_b, jnp.asarray(sparse), use_kernel=True)
    b = coll.lookup_all(new_p, new_b, None, use_kernel=True, rows=jnp.asarray(rows))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rows_path_never_reads_pointer_buffers():
    """DESIGN.md §4's pod contract: with host-translated rows the device
    program must not consume the (c, d1) pointer tables — asserted by the
    NoDeviceGatherOf audit rule (ptr/hs invars appear in no equation; the
    rule also refuses vacuously if no input matches the names)."""
    from repro.analysis import AuditProgram, NoDeviceGatherOf

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(7), cfg)
    from repro.data import HostTranslator

    tr = HostTranslator(coll, buffers["emb"])
    rng = np.random.default_rng(7)
    sparse = np.stack(
        [rng.integers(0, v, 9) for v in cfg.vocab_sizes], axis=1
    ).astype(np.int32)
    rows = jnp.asarray(tr.rows(sparse))

    prog = AuditProgram.capture(
        lambda p, b, r: coll.lookup_all(p, b, None, use_kernel=True, rows=r),
        params["emb"], buffers["emb"], rows, name="rows_lookup",
    )
    assert NoDeviceGatherOf(("ptr", "hs")).check(prog) == []


def test_drop_sparse_rejected_when_tables_are_not_all_fused():
    """drop_sparse=True on a collection with non-universal groups would
    crash the lookup far from the cause — the translator refuses up
    front."""
    from repro.data import HostTranslator

    tables = (CCE(d1=10_000, d2=16, k=16, c=4), FullTable(100_000, 16))
    coll = EmbeddingCollection.build(tables)
    assert any(g.kind != "univ" for g in coll.groups)
    params, buffers = coll.init(jax.random.PRNGKey(0))
    tr = HostTranslator(coll, buffers)
    batch = {"sparse": np.zeros((4, 2), np.int32)}
    with pytest.raises(ValueError, match="universally fused"):
        tr(batch, drop_sparse=True)
    assert "rows" in tr(batch)  # keeping raw ids stays fine


def test_trainer_refreshes_translator_across_transitions():
    """A Trainer fed host-translated batches must produce BIT-identical
    training to the raw-ids path across a clustering transition — the
    Trainer(translator=) hook re-syncs the ptr/hs mirrors the moment the
    transition rewrites them."""
    from repro.data import ClickstreamConfig, HostTranslator, clickstream_batches
    from repro.data import translate_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)

    def run(host_rows: bool):
        params, buffers = dlrm.init(jax.random.PRNGKey(21), cfg)
        dyn, static = split_buffers(buffers)
        opt = sgd(momentum=0.9)

        def loss_fn(p, b, mb):
            return dlrm.bce_loss(p, b, cfg, mb), {}

        step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
        state = init_state(params, opt, dyn)
        data = clickstream_batches(
            ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=21), 16
        )

        def cluster_fn(key, p, b):
            return dlrm.cluster_tables(key, p, b, cfg)

        translator = None
        if host_rows:
            translator = HostTranslator(cfg.collection, buffers["emb"])
            data = translate_batches(data, translator, drop_sparse=True)
        tr = Trainer(
            jax.jit(step, donate_argnums=(0,)), state, static, data,
            cluster_fn=cluster_fn, cluster_every=4, cluster_max=2,
            translator=translator, seed=21,
        )
        tr.run(10)
        assert tr.clusters_done == 2
        return tr.state

    s_rows, s_ids = run(True), run(False)
    for a, b in zip(jax.tree.leaves(s_rows.params), jax.tree.leaves(s_ids.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_translate_batches_ships_rows_only():
    """The translated batch is the only sparse input shipped: the wrapper
    drops raw ids and the model consumes rows."""
    from repro.data import ClickstreamConfig, HostTranslator, clickstream_batches
    from repro.data import translate_batches

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(8), cfg)
    tr = HostTranslator(cfg.collection, buffers["emb"])
    raw_it = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=8), 16
    )
    raw = next(
        clickstream_batches(
            ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=8), 16
        )
    )
    batch = next(translate_batches(raw_it, tr, drop_sparse=True))
    assert "sparse" not in batch and batch["rows"].dtype == np.int32
    out_rows = dlrm.forward(params, buffers, cfg, batch)
    out_ids = dlrm.forward(params, buffers, cfg, raw)
    np.testing.assert_array_equal(np.asarray(out_rows), np.asarray(out_ids))


def test_stack_unstack_roundtrip_bitexact():
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(2), cfg)
    rt = coll.stack_params(coll.unstack_params(params["emb"]))
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(params["emb"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rt_b = coll.stack_buffers(coll.unstack_buffers(buffers["emb"]))
    assert jax.tree.structure(rt_b) == jax.tree.structure(buffers["emb"])
    # per-feature views agree with unstack
    per = coll.unstack_params(params["emb"])
    for i in range(coll.n_features):
        for a, b in zip(
            jax.tree.leaves(coll.feature_params(params["emb"], i)),
            jax.tree.leaves(per[i]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- legacy checkpoint migration ------------------------------------------


def test_legacy_per_feature_checkpoint_restores_bitexact(tmp_path):
    """A checkpoint written under the pre-collection layout (params/moments/
    ebuf per feature) restores bit-exact into the grouped state through
    Trainer.restore_latest + dlrm.checkpoint_migrations."""
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(3), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=3), 16
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(3)

    # hand-write what a PR-2-era writer produced: per-feature emb trees
    to_old, _ = dlrm.checkpoint_migrations(cfg)[0]
    new_tree = {"state": tr.state, "clusters_done": np.int32(0)}
    old_tree = to_old(new_tree)
    # sanity: the legacy layout really is per-feature (one leaf per table)
    assert len(old_tree["state"].params["emb"]) == cfg.n_sparse
    save_checkpoint(str(tmp_path), 3, old_tree)

    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 3
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and training continues from the migrated state
    tr.run(2)
    assert np.isfinite(tr.history[-1]["loss"])


def test_legacy_checkpoint_with_id_counts_and_trackerless_reader(tmp_path):
    """Hardest migration case: the legacy writer ALSO checkpointed id
    histograms, and the restoring Trainer has no tracker — the id_counts
    wildcard placeholder must be sized against the CONVERTED (per-feature)
    layout, not the grouped one."""
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.freq import IdFrequencyTracker
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(7), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=7), 16
    )
    tr = Trainer(  # NO id_tracker
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(2)
    to_old, _ = dlrm.checkpoint_migrations(cfg)[0]
    old_tree = to_old({"state": tr.state, "clusters_done": np.int32(1)})
    # the legacy writer's tracker state rides along
    tracker = IdFrequencyTracker(cfg.vocab_sizes)
    old_tree["id_counts"] = tracker.state_tree()
    save_checkpoint(str(tmp_path), 2, old_tree)

    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 2
    assert tr.clusters_done == 1
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pr3_grouped_checkpoint_restores_bitexact(tmp_path):
    """A checkpoint written under the PRE-UNIVERSAL grouped layout
    (mode="group": per-signature CCE slab + full buckets) restores
    bit-exact into today's universal layout through Trainer.restore_latest
    + dlrm.checkpoint_migrations."""
    from repro.checkpoint import save_checkpoint
    from repro.core.collection import grouped_layout_migration
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = MIXED  # cce + full mix: grouped and universal layouts differ
    params, buffers = dlrm.init(jax.random.PRNGKey(11), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=11), 16
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(3)

    # hand-write what a PR-3/PR-4-era writer produced: the mode="group"
    # grouped layout (CCE supertable + padded full stack)
    grouped = EmbeddingCollection.build(cfg.collection.tables, mode="group")
    assert len(grouped.groups) > 1  # really a different layout
    to_old, _ = grouped_layout_migration(cfg.collection, grouped)
    old_tree = to_old({"state": tr.state, "clusters_done": np.int32(0)})
    save_checkpoint(str(tmp_path), 3, old_tree)

    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 3
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tr.run(2)
    assert np.isfinite(tr.history[-1]["loss"])


def test_group_mode_reproduces_pr3_order():
    """mode="group" must emit groups in the HISTORICAL order (signature
    insertion + d1-sorted full buckets) — NOT first-feature order — or
    PR-3 grouped checkpoints restore into the wrong list positions.
    Pinned against the actual PR-3 build output."""
    # full spread with the largest table FIRST: PR-3 put the d1-sorted
    # small bucket before the big one
    tables = (FullTable(100_000, 16), FullTable(8, 16), FullTable(16, 16))
    grouped = EmbeddingCollection.build(tables, mode="group")
    assert [g.features for g in grouped.groups] == [(1, 2), (0,)]
    # ...and the universal (current) layout orders by first feature, so
    # the layouts differ and checkpoint_migrations must bridge them
    univ = EmbeddingCollection.build(tables)
    assert [g.features for g in univ.groups] == [(0,), (1, 2)]
    # within a full bucket PR-3 kept d1 order, not feature order
    grouped = EmbeddingCollection.build(MIXED.collection.tables, mode="group")
    full = [g for g in grouped.groups if g.kind == "full"][0]
    assert full.features == (0, 4, 2)  # d1s 8, 16, 20


def test_pr3_grouped_checkpoint_restores_bitexact_order_sensitive(tmp_path):
    """Ordering-sensitive variant: a pure-full config whose PR-3 group
    order differs from first-feature order still restores bit-exact."""
    from repro.checkpoint import save_checkpoint
    from repro.core.collection import grouped_layout_migration
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = DLRMConfig(
        vocab_sizes=(100_000, 8, 16), n_dense=13, emb_dim=16,
        bottom_mlp=(32, 16), top_mlp=(32, 1), emb_method="full",
    )
    params, buffers = dlrm.init(jax.random.PRNGKey(13), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=13), 8
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(2)
    grouped = EmbeddingCollection.build(cfg.collection.tables, mode="group")
    assert [g.features for g in grouped.groups] != [
        g.features for g in cfg.collection.groups
    ]
    to_old, _ = grouped_layout_migration(cfg.collection, grouped)
    save_checkpoint(
        str(tmp_path), 2, to_old({"state": tr.state, "clusters_done": np.int32(0)})
    )
    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 2
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_new_layout_checkpoint_still_restores(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(4), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=4), 16
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(2)
    save_checkpoint(str(tmp_path), 2, {"state": tr.state, "clusters_done": np.int32(0)})
    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 2
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- transition through the collection -------------------------------------


def test_collection_transition_equals_per_table_transition():
    """cluster_tables through the grouped layout produces EXACTLY the
    tables/pointers the per-table loop would: slice per feature and
    compare against transition_table run standalone."""
    from repro.train.transition import transition_table

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(5), cfg)
    key = jax.random.PRNGKey(6)
    p2, b2 = dlrm.cluster_tables(key, params, buffers, cfg)
    per_p = coll.unstack_params(params["emb"])
    per_b = coll.unstack_buffers(buffers["emb"])
    for i in range(cfg.n_sparse):
        t = cfg.table(i)
        if not isinstance(t, CCE):
            continue
        want_p, want_b, _ = transition_table(
            t, jax.random.fold_in(key, i), per_p[i], per_b[i],
            chunk_size=cfg.emb_cluster_chunk,
        )
        got_p = coll.feature_params(p2["emb"], i)
        got_b = coll.feature_buffers(b2["emb"], i)
        np.testing.assert_array_equal(
            np.asarray(got_p["tables"]), np.asarray(want_p["tables"])
        )
        np.testing.assert_array_equal(
            np.asarray(got_b["ptr"]), np.asarray(want_b["ptr"])
        )
        np.testing.assert_array_equal(
            np.asarray(got_b["hs"]), np.asarray(want_b["hs"])
        )
