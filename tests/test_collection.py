"""EmbeddingCollection: grouped supertables == the per-table loop.

The refactor's contract, asserted here:
  * grouping drops heavy lookups from O(n_features) to O(n_groups),
  * the fused path (Pallas kernel AND jnp oracle) is numerically
    equivalent to the legacy per-feature loop — forward and gradients,
  * ragged codebooks (different k in one group) and the padded full-table
    gather are exact,
  * pre-collection (per-feature layout) checkpoints restore BIT-EXACT
    through ``Trainer.restore_latest`` + ``dlrm.checkpoint_migrations``,
  * the collection-backed transition keeps the Trainer protocol intact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_criteo
from repro.core.cce import CCE
from repro.core.collection import EmbeddingCollection
from repro.core.embeddings import FullTable
from repro.models import dlrm
from repro.models.dlrm import DLRMConfig
from repro.optim import sgd


MIXED = DLRMConfig(
    vocab_sizes=(8, 1000, 20, 5000, 16, 300),
    n_dense=13, emb_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
    emb_method="cce", emb_param_cap=512,
)


def _batch(cfg, B=9, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(
            np.stack([rng.integers(0, v, B) for v in cfg.vocab_sizes], axis=1),
            jnp.int32,
        ),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32),
    }


def _per_feature_lookup(coll, emb_params, emb_buffers, sparse):
    """The legacy hot loop: one lookup per feature."""
    per_p = coll.unstack_params(emb_params)
    per_b = coll.unstack_buffers(emb_buffers)
    return jnp.stack(
        [
            coll.tables[i].lookup(per_p[i], per_b[i], sparse[:, i])
            for i in range(coll.n_features)
        ],
        axis=1,
    )


# --- grouping ------------------------------------------------------------


def test_grouping_collapses_launches():
    # all-compressed reduced config: every table fuses into ONE launch
    coll = dlrm_criteo.reduced(emb_method="cce", cap=512).collection
    assert coll.n_features == 5 and coll.n_groups == 1
    assert coll.n_lookup_launches == 1
    assert coll.groups[0].kind == "cce"
    # mixed config: one cce group + one full group
    coll = MIXED.collection
    kinds = sorted(g.kind for g in coll.groups)
    assert kinds == ["cce", "full"]
    assert coll.n_lookup_launches == 2
    # every feature appears in exactly one group
    feats = sorted(i for g in coll.groups for i in g.features)
    assert feats == list(range(coll.n_features))


def test_full_groups_split_on_pathological_padding():
    """A (tiny, huge) full-table mix must NOT pad the tiny table to the
    huge vocab."""
    tables = tuple(FullTable(d1, 16) for d1 in (8, 16, 100_000))
    coll = EmbeddingCollection.build(tables)
    full_groups = [g for g in coll.groups if g.kind == "full"]
    assert len(full_groups) == 2  # {8, 16} together, 100k alone
    sizes = sorted(tuple(t.d1 for t in g.tables) for g in full_groups)
    assert sizes == [(8, 16), (100_000,)]


def test_loop_fallback_for_unfusable_methods():
    coll = dlrm_criteo.reduced(emb_method="ce", cap=512).collection
    assert all(g.kind == "loop" for g in coll.groups)
    assert coll.n_lookup_launches == coll.n_features


def test_cached_collection_is_not_reconstructed():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    assert cfg.collection is cfg.collection  # cached_property, one build
    assert cfg.table(0) is cfg.collection.tables[0]


# --- numerics: fused == looped --------------------------------------------


@pytest.mark.parametrize("use_kernel", [True, False])
def test_lookup_all_matches_per_feature_loop(use_kernel):
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    sparse = _batch(cfg, B=33)["sparse"]  # B not a block multiple
    got = coll.lookup_all(
        params["emb"], buffers["emb"], sparse, use_kernel=use_kernel
    )
    want = _per_feature_lookup(coll, params["emb"], buffers["emb"], sparse)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("use_kernel", [True, False])
def test_lookup_all_grads_match_per_feature_loop(use_kernel):
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(1), cfg)
    sparse = _batch(cfg, B=17, seed=1)["sparse"]
    co = jax.random.normal(jax.random.PRNGKey(2), (17, cfg.n_sparse, cfg.emb_dim))

    def loss_fused(emb_p):
        out = coll.lookup_all(emb_p, buffers["emb"], sparse, use_kernel=use_kernel)
        return jnp.sum(out * co)

    def loss_looped(emb_p):
        out = _per_feature_lookup(coll, emb_p, buffers["emb"], sparse)
        return jnp.sum(out * co)

    g1 = jax.grad(loss_fused)(params["emb"])
    g2 = jax.grad(loss_looped)(params["emb"])
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
        )


def test_dlrm_forward_kernel_path_matches_jnp_path():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=21)
    out_k = dlrm.forward(params, buffers, cfg, batch)
    cfg_j = dataclasses.replace(cfg, emb_use_kernel=False)
    out_j = dlrm.forward(params, buffers, cfg_j, batch)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_j), rtol=1e-5, atol=1e-5
    )
    g_k = jax.grad(lambda p: dlrm.bce_loss(p, buffers, cfg, batch))(params)
    g_j = jax.grad(lambda p: dlrm.bce_loss(p, buffers, cfg_j, batch))(params)
    for a, b in zip(jax.tree.leaves(g_k), jax.tree.leaves(g_j)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6
        )


def test_ragged_codebooks_fuse_exactly():
    """Two CCE tables with DIFFERENT k in one group: the supertable pads
    the codebook axis and lookups stay exact, grads land only in real rows."""
    t1 = CCE(d1=100, d2=16, k=5, c=4, seed_salt=0)
    t2 = CCE(d1=200, d2=16, k=12, c=4, seed_salt=1)
    coll = EmbeddingCollection.build((t1, t2))
    assert coll.n_groups == 1 and coll.groups[0].kind == "cce"
    params, buffers = coll.init(jax.random.PRNGKey(0))
    assert params[0]["tables"].shape == (8, 2, 12, 4)  # padded to max k
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 100, (13, 2)), jnp.int32)
    got = coll.lookup_all(params, buffers, ids, use_kernel=True)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    # gradient never touches the padding rows of the small-k table
    g = jax.grad(
        lambda p: jnp.sum(coll.lookup_all(p, buffers, ids, use_kernel=True) ** 2)
    )(params)
    assert float(np.abs(np.asarray(g[0]["tables"][:4, :, 5:, :])).max()) == 0.0


def test_full_group_clamps_out_of_range_ids_like_per_table():
    """An id >= a small table's vocab must clamp to ITS last row (the
    per-table XLA gather semantics), not read — or train — the padding
    rows of the stacked (F, max d1, d2) table."""
    tables = (FullTable(4, 8), FullTable(16, 8))
    coll = EmbeddingCollection.build(tables)
    params, buffers = coll.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([[4, 0], [99, 15]], jnp.int32)  # 4, 99 out of range for d1=4
    got = coll.lookup_all(params, buffers, ids)
    want = _per_feature_lookup(coll, params, buffers, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # gradient lands in the clamped real row, never in the padding
    g = jax.grad(lambda p: jnp.sum(coll.lookup_all(p, buffers, ids) ** 2))(params)
    assert float(np.abs(np.asarray(g[0]["table"][0, 4:])).max()) == 0.0


def test_stack_unstack_roundtrip_bitexact():
    cfg = MIXED
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(2), cfg)
    rt = coll.stack_params(coll.unstack_params(params["emb"]))
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(params["emb"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rt_b = coll.stack_buffers(coll.unstack_buffers(buffers["emb"]))
    assert jax.tree.structure(rt_b) == jax.tree.structure(buffers["emb"])
    # per-feature views agree with unstack
    per = coll.unstack_params(params["emb"])
    for i in range(coll.n_features):
        for a, b in zip(
            jax.tree.leaves(coll.feature_params(params["emb"], i)),
            jax.tree.leaves(per[i]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- legacy checkpoint migration ------------------------------------------


def test_legacy_per_feature_checkpoint_restores_bitexact(tmp_path):
    """A checkpoint written under the pre-collection layout (params/moments/
    ebuf per feature) restores bit-exact into the grouped state through
    Trainer.restore_latest + dlrm.checkpoint_migrations."""
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(3), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=3), 16
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(3)

    # hand-write what a PR-2-era writer produced: per-feature emb trees
    to_old, _ = dlrm.checkpoint_migrations(cfg)[0]
    new_tree = {"state": tr.state, "clusters_done": np.int32(0)}
    old_tree = to_old(new_tree)
    # sanity: the legacy layout really is per-feature (one leaf per table)
    assert len(old_tree["state"].params["emb"]) == cfg.n_sparse
    save_checkpoint(str(tmp_path), 3, old_tree)

    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 3
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and training continues from the migrated state
    tr.run(2)
    assert np.isfinite(tr.history[-1]["loss"])


def test_legacy_checkpoint_with_id_counts_and_trackerless_reader(tmp_path):
    """Hardest migration case: the legacy writer ALSO checkpointed id
    histograms, and the restoring Trainer has no tracker — the id_counts
    wildcard placeholder must be sized against the CONVERTED (per-feature)
    layout, not the grouped one."""
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.freq import IdFrequencyTracker
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(7), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=7), 16
    )
    tr = Trainer(  # NO id_tracker
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(2)
    to_old, _ = dlrm.checkpoint_migrations(cfg)[0]
    old_tree = to_old({"state": tr.state, "clusters_done": np.int32(1)})
    # the legacy writer's tracker state rides along
    tracker = IdFrequencyTracker(cfg.vocab_sizes)
    old_tree["id_counts"] = tracker.state_tree()
    save_checkpoint(str(tmp_path), 2, old_tree)

    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 2
    assert tr.clusters_done == 1
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_new_layout_checkpoint_still_restores(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.data import ClickstreamConfig, clickstream_batches
    from repro.train.loop import Trainer, init_state, make_train_step, split_buffers

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    params, buffers = dlrm.init(jax.random.PRNGKey(4), cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    step = make_train_step(loss_fn, opt, lambda s: jnp.float32(0.05), static)
    state = init_state(params, opt, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=4), 16
    )
    tr = Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=str(tmp_path), migrations=dlrm.checkpoint_migrations(cfg),
    )
    tr.run(2)
    save_checkpoint(str(tmp_path), 2, {"state": tr.state, "clusters_done": np.int32(0)})
    want = jax.tree.leaves(tr.state)
    assert tr.restore_latest() == 2
    for a, b in zip(jax.tree.leaves(tr.state), want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- transition through the collection -------------------------------------


def test_collection_transition_equals_per_table_transition():
    """cluster_tables through the grouped layout produces EXACTLY the
    tables/pointers the per-table loop would: slice per feature and
    compare against transition_table run standalone."""
    from repro.train.transition import transition_table

    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    coll = cfg.collection
    params, buffers = dlrm.init(jax.random.PRNGKey(5), cfg)
    key = jax.random.PRNGKey(6)
    p2, b2 = dlrm.cluster_tables(key, params, buffers, cfg)
    per_p = coll.unstack_params(params["emb"])
    per_b = coll.unstack_buffers(buffers["emb"])
    for i in range(cfg.n_sparse):
        t = cfg.table(i)
        if not isinstance(t, CCE):
            continue
        want_p, want_b, _ = transition_table(
            t, jax.random.fold_in(key, i), per_p[i], per_b[i],
            chunk_size=cfg.emb_cluster_chunk,
        )
        got_p = coll.feature_params(p2["emb"], i)
        got_b = coll.feature_buffers(b2["emb"], i)
        np.testing.assert_array_equal(
            np.asarray(got_p["tables"]), np.asarray(want_p["tables"])
        )
        np.testing.assert_array_equal(
            np.asarray(got_b["ptr"]), np.asarray(want_b["ptr"])
        )
        np.testing.assert_array_equal(
            np.asarray(got_b["hs"]), np.asarray(want_b["hs"])
        )
