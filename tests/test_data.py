"""Data pipeline: determinism, restart-exactness, planted structure."""
import numpy as np

from repro.data import ClickstreamConfig, clickstream_batches, lm_token_batches
from repro.data.synthetic import planted_embedding_model, _zipf_probs


def test_restart_exactness():
    cfg = ClickstreamConfig(vocab_sizes=(100, 500), seed=7)
    a = clickstream_batches(cfg, 16)
    first = [next(a) for _ in range(6)]
    b = clickstream_batches(cfg, 16, start_step=3)
    for i in range(3):
        got = next(b)
        for k in ("dense", "sparse", "label"):
            np.testing.assert_array_equal(got[k], first[3 + i][k])


def test_host_sharding_differs():
    cfg = ClickstreamConfig(vocab_sizes=(100,), seed=7)
    h0 = next(clickstream_batches(cfg, 16, host_id=0, n_hosts=2))
    h1 = next(clickstream_batches(cfg, 16, host_id=1, n_hosts=2))
    assert not np.array_equal(h0["sparse"], h1["sparse"])


def test_zipf_skew():
    cfg = ClickstreamConfig(vocab_sizes=(1000,), seed=0, zipf_a=1.1)
    it = clickstream_batches(cfg, 512)
    ids = np.concatenate([next(it)["sparse"][:, 0] for _ in range(20)])
    counts = np.bincount(ids, minlength=1000)
    # head ids dominate (power law)
    assert counts[:10].sum() > 5 * counts[500:510].sum()


def test_planted_structure_is_learnable():
    """A logistic model on the TRUE latent concepts must beat one on random
    concept assignments — i.e. the labels actually depend on the planted
    clusters (what CCE is supposed to discover)."""
    cfg = ClickstreamConfig(vocab_sizes=(500,), seed=1, noise=0.2)
    concept_of, concept_w, dense_w = planted_embedding_model(cfg)
    it = clickstream_batches(cfg, 2048)
    batch = next(it)
    logit_true = batch["dense"] @ dense_w + concept_w[0][concept_of[0][batch["sparse"][:, 0]]]
    acc_true = ((logit_true > 0) == batch["label"].astype(bool)).mean()
    rng = np.random.default_rng(0)
    rand_concepts = rng.integers(0, cfg.n_latent, 500)
    logit_rand = batch["dense"] @ dense_w + concept_w[0][rand_concepts[batch["sparse"][:, 0]]]
    acc_rand = ((logit_rand > 0) == batch["label"].astype(bool)).mean()
    assert acc_true > acc_rand + 0.05


def test_lm_tokens_shapes_and_determinism():
    a = next(lm_token_batches(97, 4, 16, seed=3))
    b = next(lm_token_batches(97, 4, 16, seed=3))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 97
    c = next(lm_token_batches(33, 2, 8, seed=3, n_codebooks=4))
    assert c["tokens"].shape == (2, 8, 4)


def test_lm_tokens_have_markov_structure():
    it = lm_token_batches(200, 8, 128, seed=5)
    toks = next(it)["tokens"]
    from repro.data.synthetic import _zipf_probs  # noqa

    # successor-following 70% of the time -> adjacent-pair mutual info > 0:
    # check repeats of the most common bigram far above independence
    pairs = toks[:, :-1] * 200 + toks[:, 1:]
    _, counts = np.unique(pairs, return_counts=True)
    assert counts.max() > 5
