"""Checkpoint store: atomic commit, async, retention, cross-mesh restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    reshard_restore,
    save_checkpoint,
)
from repro.checkpoint.store import list_checkpoints


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "opt": {"m": jnp.zeros((8, 4)), "t": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, extra={"note": "x"})
    step, back, extra = load_checkpoint(str(tmp_path), template=t)
    assert step == 3 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    p = save_checkpoint(str(tmp_path), 2, t)
    os.remove(os.path.join(p, "_COMMITTED"))  # simulate crash mid-save
    step, _, _ = load_checkpoint(str(tmp_path), template=t)
    assert step == 1


def test_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in range(5):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = [s for s, _ in list_checkpoints(str(tmp_path))]
    assert steps == [3, 4]
    step, back, _ = mgr.restore_latest(_tree())
    assert step == 4
    want = _tree(4)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(want["w"]))


def test_async_error_surfaces(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "sub"), keep_last=1)
    mgr.save_async(0, _tree())
    mgr.wait()
    # replace the checkpoint dir with a FILE: the background writer must
    # fail, and the failure must surface on the next wait() (tests run as
    # root, so permission bits alone wouldn't fail)
    shutil.rmtree(mgr.directory)
    with open(mgr.directory, "w") as f:
        f.write("not a directory")
    try:
        mgr.save_async(1, _tree())
        with pytest.raises(BaseException):
            mgr.wait()
    finally:
        os.remove(mgr.directory)


def test_reshard_restore_other_sharding(tmp_path):
    """Save unsharded, restore onto an explicit (1-device) mesh sharding —
    the elastic-rescale path in miniature."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_checkpoint(str(tmp_path), 0, t)
    _, host, _ = load_checkpoint(str(tmp_path), template=t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {
        "w": NamedSharding(mesh, P("data", None)),
        "opt": {"m": NamedSharding(mesh, P()), "t": NamedSharding(mesh, P())},
    }
    placed = reshard_restore(host, sh)
    assert placed["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))
