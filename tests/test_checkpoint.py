"""Checkpoint store: atomic commit, async, retention, cross-mesh restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    reshard_restore,
    save_checkpoint,
)
from repro.checkpoint.store import list_checkpoints


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "opt": {"m": jnp.zeros((8, 4)), "t": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, extra={"note": "x"})
    step, back, extra = load_checkpoint(str(tmp_path), template=t)
    assert step == 3 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    p = save_checkpoint(str(tmp_path), 2, t)
    os.remove(os.path.join(p, "_COMMITTED"))  # simulate crash mid-save
    step, _, _ = load_checkpoint(str(tmp_path), template=t)
    assert step == 1


def test_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in range(5):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = [s for s, _ in list_checkpoints(str(tmp_path))]
    assert steps == [3, 4]
    step, back, _ = mgr.restore_latest(_tree())
    assert step == 4
    want = _tree(4)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(want["w"]))


def test_async_error_surfaces(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "sub"), keep_last=1)
    mgr.save_async(0, _tree())
    mgr.wait()
    # replace the checkpoint dir with a FILE: the background writer must
    # fail, and the failure must surface on the next wait() (tests run as
    # root, so permission bits alone wouldn't fail)
    shutil.rmtree(mgr.directory)
    with open(mgr.directory, "w") as f:
        f.write("not a directory")
    try:
        mgr.save_async(1, _tree())
        with pytest.raises(BaseException):
            mgr.wait()
    finally:
        os.remove(mgr.directory)


def test_reshard_restore_other_sharding(tmp_path):
    """Save unsharded, restore onto an explicit (1-device) mesh sharding —
    the elastic-rescale path in miniature."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save_checkpoint(str(tmp_path), 0, t)
    _, host, _ = load_checkpoint(str(tmp_path), template=t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {
        "w": NamedSharding(mesh, P("data", None)),
        "opt": {"m": NamedSharding(mesh, P()), "t": NamedSharding(mesh, P())},
    }
    placed = reshard_restore(host, sh)
    assert placed["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))


def test_sectioned_restore_drops_and_defaults_toplevel_keys(tmp_path):
    """New-format checkpoints carry a top-level section index: a reader
    missing a stored section drops it by NAME, a reader with a NEW
    section keeps its template default — no leaf-count arithmetic."""
    t = _tree()
    stored = dict(t, aux=[np.arange(5), np.float64(2.5)])
    save_checkpoint(str(tmp_path), 1, stored)
    # reader without "aux": section dropped
    _, back, _ = load_checkpoint(str(tmp_path), template=t)
    assert "aux" not in back
    np.testing.assert_array_equal(
        np.asarray(back["w"]), np.asarray(t["w"]))
    # reader with an extra section the writer lacked: template default kept
    t2 = dict(t, trigger=[np.int64(0), np.zeros(3)])
    _, back2, _ = load_checkpoint(str(tmp_path), template=t2)
    np.testing.assert_array_equal(np.asarray(back2["trigger"][1]), np.zeros(3))
    np.testing.assert_array_equal(np.asarray(back2["w"]), np.asarray(t["w"]))
    # shared sections still shape-check: a wrong-shape template fails
    bad = dict(t, w=np.zeros((2, 2)))
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), template=bad)


def test_sectioned_restore_prefers_consuming_over_dropping(tmp_path):
    """A candidate that MIGRATES a stored section must win over an
    earlier candidate that would merely drop it."""
    t = _tree()
    stored = dict(t, counts=[np.arange(4, dtype=np.int64)])
    save_checkpoint(str(tmp_path), 1, stored)
    dropper = dict(t)  # would match by dropping "counts"
    migrator = dict(t, counts=[np.zeros(4, np.int64)])

    def convert(tree):
        return dict(tree, counts=[tree["counts"][0] * 10])

    _, back, _ = load_checkpoint(
        str(tmp_path), migrations=[(dropper, None), (migrator, convert)]
    )
    np.testing.assert_array_equal(
        np.asarray(back["counts"][0]), np.arange(4) * 10
    )
