"""Product quantization baseline + DLRM integration + the paper's ordering
claim (CCE > CE > hashing at equal budget on clusterable data)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import dlrm_criteo
from repro.core.pq import pq_lookup, pq_table, product_quantize
from repro.models import dlrm


def test_pq_reconstruction_beats_mean():
    key = jax.random.PRNGKey(0)
    # clusterable table: 16 distinct rows + noise
    base = jax.random.normal(key, (16, 16))
    T = jnp.repeat(base, 20, axis=0) + 0.01 * jax.random.normal(
        jax.random.fold_in(key, 1), (320, 16))
    pq = product_quantize(key, T, k=16, c=4)
    err = float(jnp.mean((pq_table(pq) - T) ** 2))
    base_err = float(jnp.mean((T - T.mean(0)) ** 2))
    assert err < 0.02 * base_err


def test_pq_lookup_matches_table():
    key = jax.random.PRNGKey(1)
    T = jax.random.normal(key, (100, 8))
    pq = product_quantize(key, T, k=8, c=2)
    ids = jnp.asarray([0, 5, 99])
    np.testing.assert_allclose(
        np.asarray(pq_lookup(pq, ids)), np.asarray(pq_table(pq)[ids]), rtol=1e-6
    )


def test_pq_sampled_close_to_full():
    key = jax.random.PRNGKey(2)
    T = jax.random.normal(key, (400, 8))
    full = product_quantize(key, T, k=16, c=2)
    samp = product_quantize(key, T, k=16, c=2, sample=200)
    assert samp.mse < 2.5 * full.mse + 1e-3


def test_dlrm_forward_shapes():
    cfg = dlrm_criteo.reduced()
    params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
    batch = {
        "dense": jnp.ones((4, 13)),
        "sparse": jnp.zeros((4, cfg.n_sparse), jnp.int32),
        "label": jnp.ones((4,)),
    }
    out = dlrm.forward(params, buffers, cfg, batch)
    assert out.shape == (4,)
    assert np.isfinite(float(dlrm.bce_loss(params, buffers, cfg, batch)))


def test_dlrm_compression_accounting():
    cfg = dlrm_criteo.reduced(emb_method="cce", cap=512)
    # small tables stay full; big ones compressed to <= cap params
    for i, v in enumerate(cfg.vocab_sizes):
        t = cfg.table(i)
        if v * cfg.emb_dim <= 512:
            assert t.n_params == v * cfg.emb_dim
        else:
            assert t.n_params <= 512
    assert cfg.compression() > 1.0


def test_paper_config_compression_rate():
    cfg = dlrm_criteo.CONFIG
    # the paper's headline scale: hundreds-to-thousands x on Criteo vocabs
    assert cfg.compression() > 500
