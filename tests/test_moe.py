"""MoE routing implementations: einsum (GShard) vs sort (MegaBlocks-style)
must be numerically identical, including capacity-drop semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig


def _cfg(cf):
    return ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=48, vocab=97,
                       n_experts=8, top_k=2, capacity_factor=cf,
                       dtype=jnp.float32, remat="none")


@pytest.mark.parametrize("cf", [1.0, 1.25, 8.0])
def test_sort_equals_einsum(cf):
    cfg = _cfg(cf)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    o1, a1 = moe.apply_moe(p, cfg, x, group_size=64)
    o2, a2 = moe.apply_moe_sort(p, cfg, x, group_size=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_sort_sm_falls_back_without_mesh():
    cfg = _cfg(1.25)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    o1, _ = moe.apply_moe_sort(p, cfg, x, group_size=64)
    o2, _ = moe.apply_moe_sort_sm(p, cfg, x, group_size=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_sort_gradients_match():
    cfg = _cfg(1.25)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    g1 = jax.grad(lambda p: moe.apply_moe(p, cfg, x, group_size=64)[0].sum())(p)
    g2 = jax.grad(lambda p: moe.apply_moe_sort(p, cfg, x, group_size=64)[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_no_drops_at_high_capacity():
    """cf=8: every token keeps all top-k slots -> output equals the dense
    masked evaluation used for decode."""
    cfg = _cfg(8.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    o1, _ = moe.apply_moe_sort(p, cfg, x, group_size=16)
    o2 = moe.apply_moe_decode(p, cfg, x.reshape(16, 1, 32)).reshape(1, 16, 32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
