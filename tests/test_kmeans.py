"""K-means: quality, distributed == serial, subsampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km


def _blobs(key, n_per=50, k=5, d=8, spread=0.05):
    kc, kx = jax.random.split(key)
    centers = jax.random.normal(kc, (k, d)) * 2
    pts = centers[:, None] + spread * jax.random.normal(kx, (k, n_per, d))
    return pts.reshape(-1, d), centers


def test_kmeans_recovers_blobs():
    x, centers = _blobs(jax.random.PRNGKey(0))
    res = km.kmeans(jax.random.PRNGKey(1), x, k=5, niter=25)
    # every found centroid is near a true center
    d = np.linalg.norm(
        np.asarray(res.centroids)[:, None] - np.asarray(centers)[None], axis=-1
    )
    assert d.min(axis=1).max() < 0.2
    # inertia ~ noise level
    assert float(res.inertia) / x.shape[0] < 0.1


def test_kmeans_plus_plus_spreads_seeds():
    x, _ = _blobs(jax.random.PRNGKey(2))
    seeds = km.kmeans_plus_plus(jax.random.PRNGKey(3), x, 5)
    d = np.linalg.norm(np.asarray(seeds)[:, None] - np.asarray(seeds)[None], axis=-1)
    np.fill_diagonal(d, np.inf)
    assert d.min() > 0.5  # no two seeds from the same blob


def test_assign_kernel_route():
    x, _ = _blobs(jax.random.PRNGKey(4))
    c = jax.random.normal(jax.random.PRNGKey(5), (7, 8))
    a1 = km.assign(x, c, use_kernel=False)
    a2 = km.assign(x, c, use_kernel=True)
    assert (np.asarray(a1) == np.asarray(a2)).mean() > 0.99


def test_distributed_kmeans_matches_serial_single_shard():
    """On a 1-device axis the distributed algorithm IS the serial one."""
    x, _ = _blobs(jax.random.PRNGKey(6))
    mesh = jax.make_mesh((1,), ("data",))
    from repro.compat import shard_map

    def run(xs):
        c, a = km.distributed_kmeans(jax.random.PRNGKey(7), xs, 5, "data", niter=20)
        return c, a

    from jax.sharding import PartitionSpec as P

    f = shard_map(run, mesh=mesh, in_specs=P("data"), out_specs=(P(), P("data")))
    c_dist, a_dist = f(x)
    res = km.kmeans(jax.random.PRNGKey(7), x, 5, niter=20)
    # same seeds + same data -> same result up to float order
    d = np.linalg.norm(
        np.asarray(c_dist)[:, None] - np.asarray(res.centroids)[None], axis=-1
    )
    assert d.min(axis=1).max() < 1e-3


def test_subsample_caps_points():
    idx = km.subsample(jax.random.PRNGKey(8), n=100_000, k=16, max_points_per_centroid=256)
    assert idx.shape[0] == 16 * 256
    assert len(np.unique(np.asarray(idx))) == idx.shape[0]
    idx2 = km.subsample(jax.random.PRNGKey(8), n=100, k=16)
    assert idx2.shape[0] == 100


def test_empty_cluster_stability():
    """Centroids with no points keep their position (no NaNs)."""
    x = jnp.ones((10, 4))
    res = km.kmeans(jax.random.PRNGKey(9), x, k=5, niter=5)
    assert bool(jnp.isfinite(res.centroids).all())
