"""Universal hashing: correctness, numpy/jnp equivalence, distribution."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hashing


@given(seed=st.integers(0, 2**30), m=st.integers(2, 100_000))
@settings(max_examples=25, deadline=None)
def test_hash_range_and_np_equivalence(seed, m):
    h = hashing.make_hash(seed, m)
    ids = np.arange(0, 5000, 7)
    out_np = h.np(ids)
    out_j = np.asarray(h(jnp.asarray(ids)))
    assert np.array_equal(out_np, out_j)
    assert out_np.min() >= 0 and out_np.max() < m


def test_hash_deterministic_per_seed():
    a = hashing.make_hash(42, 1000)
    b = hashing.make_hash(42, 1000)
    c = hashing.make_hash(43, 1000)
    assert (a.a, a.b) == (b.a, b.b)
    assert (a.a, a.b) != (c.a, c.b)


def test_hash_spread():
    """Buckets should be roughly uniform (chi-square sanity, not strict)."""
    h = hashing.make_hash(7, 64)
    vals = h.np(np.arange(64 * 1000))
    counts = np.bincount(vals, minlength=64)
    assert counts.min() > 600 and counts.max() < 1500


def test_make_hashes_distinct():
    hs = hashing.make_hashes(5, 4, 100)
    assert len({(h.a, h.b) for h in hs}) == 4


def test_sign_hash_balanced():
    s = hashing.make_sign_hash(3)
    vals = np.asarray(s(jnp.arange(10000)))
    assert set(np.unique(vals)) == {-1, 1}
    assert abs(vals.mean()) < 0.05


def test_countsketch_matrix_structure():
    import jax

    H = hashing.countsketch_matrix(jax.random.PRNGKey(0), 200, 32)
    assert H.shape == (200, 32)
    # exactly one nonzero per row, values in {-1, +1}
    nz = (H != 0).sum(axis=1)
    assert np.array_equal(nz, np.ones(200))
    assert set(np.unique(H[H != 0])) <= {-1.0, 1.0}


def test_countsketch_norm_preservation():
    """Charikar et al.: E||Hx||^2 = ||x||^2 — check the empirical mean."""
    import jax

    rng = np.random.default_rng(0)
    x = rng.normal(size=500).astype(np.float32)
    ratios = []
    for s in range(30):
        H = hashing.countsketch_matrix(jax.random.PRNGKey(s), 500, 128)
        ratios.append(float((x @ H) @ (x @ H)) / float(x @ x))
    assert abs(np.mean(ratios) - 1.0) < 0.15
