"""Model substrate: every family's train/prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, ssm, xlstm
from repro.models.config import ModelConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=97, dtype=jnp.float32, remat="none")

FAMILIES = {
    "dense": ModelConfig(name="dense", family="dense", qk_norm=True, qkv_bias=True, **BASE),
    "parallel": ModelConfig(name="par", family="dense", parallel_block=True,
                            norm="layernorm", **BASE),
    "moe": ModelConfig(name="moe", family="moe", n_experts=4, top_k=2,
                       capacity_factor=8.0, **{**BASE, "d_ff": 96}),
    "hybrid": ModelConfig(name="hyb", family="hybrid", ssm_state=4,
                          sliding_window=6, **BASE),
    "xlstm": ModelConfig(name="xl", family="xlstm", slstm_every=2,
                         **{**BASE, "d_ff": 0, "n_kv_heads": 4, "n_layers": 4}),
    "vlm": ModelConfig(name="vlm", family="vlm", n_patches=4, act="gelu",
                       emb_scale=True, tie_embeddings=True, **{**BASE, "n_kv_heads": 1}),
    "audio": ModelConfig(name="aud", family="audio", n_codebooks=4,
                         norm="layernorm", act="gelu", pos_emb="sinusoidal",
                         **{**BASE, "vocab": 33, "n_kv_heads": 4}),
}


def _tokens(cfg, B, S, key):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    return jax.random.randint(key, shape, 0, cfg.vocab)


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_loss_and_grads(fam):
    cfg = FAMILIES[fam]
    key = jax.random.PRNGKey(0)
    params, buffers = lm.init(key, cfg)
    batch = {"tokens": _tokens(cfg, 2, 8, key)}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.ones((2, cfg.n_patches, cfg.d_model))
    loss, metrics = lm.next_token_loss(params, buffers, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab) + 3
    g = jax.grad(lambda p: lm.next_token_loss(p, buffers, cfg, batch)[0])(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_prefill_decode_match_forward(fam):
    cfg = FAMILIES[fam]
    key = jax.random.PRNGKey(1)
    params, buffers = lm.init(key, cfg)
    B, S = 2, 8
    toks = _tokens(cfg, B, S + 1, key)
    batch = {"tokens": toks}
    if cfg.family == "vlm":  # decode path without image for the cache test
        pass
    logits, _ = lm.forward(params, buffers, cfg, batch)
    cache = lm.init_cache(cfg, B, 16)
    lgp, cache = lm.prefill(params, buffers, cfg, toks[:, :S], cache)
    np.testing.assert_allclose(
        np.asarray(lgp), np.asarray(logits[:, S - 1]), rtol=1e-3, atol=1e-3
    )
    nxt = toks[:, S]
    lgd, cache = lm.decode_step(
        params, buffers, cfg, nxt, jnp.full((B,), S, jnp.int32), cache
    )
    np.testing.assert_allclose(
        np.asarray(lgd), np.asarray(logits[:, S]), rtol=1e-3, atol=2e-3
    )


def test_scan_equals_unrolled():
    cfg = FAMILIES["dense"]
    key = jax.random.PRNGKey(2)
    params, buffers = lm.init(key, cfg)
    batch = {"tokens": _tokens(cfg, 2, 8, key)}
    l1, _ = lm.next_token_loss(params, buffers, cfg, batch)
    import dataclasses

    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = lm.next_token_loss(params, buffers, cfg2, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_remat_does_not_change_loss():
    import dataclasses

    cfg = dataclasses.replace(FAMILIES["dense"], remat="full")
    key = jax.random.PRNGKey(3)
    params, buffers = lm.init(key, cfg)
    batch = {"tokens": _tokens(cfg, 2, 8, key)}
    l1, _ = lm.next_token_loss(params, buffers, cfg, batch)
    l2, _ = lm.next_token_loss(
        params, buffers, dataclasses.replace(cfg, remat="none"), batch
    )
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g = jax.grad(lambda p: lm.next_token_loss(p, buffers, cfg, batch)[0])(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_sliding_window_limits_attention():
    """With window w, logits at position t must not depend on tokens < t-w."""
    cfg = ModelConfig(name="swa", family="dense", sliding_window=3, **{
        k: v for k, v in BASE.items()})
    key = jax.random.PRNGKey(4)
    params, buffers = lm.init(key, cfg)
    t1 = _tokens(cfg, 1, 10, key)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)  # perturb far past
    l1, _ = lm.forward(params, buffers, cfg, {"tokens": t1})
    l2, _ = lm.forward(params, buffers, cfg, {"tokens": t2})
    # receptive field stacks: 2 layers x (window-1) = 4 positions back, so
    # positions >= 5 can't see token 0 through any path
    np.testing.assert_allclose(
        np.asarray(l1[0, 5:]), np.asarray(l2[0, 5:]), rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(np.asarray(l1[0, 0]), np.asarray(l2[0, 0]))


def test_ssm_chunk_invariance():
    cfg = FAMILIES["hybrid"]
    p = ssm.init_ssm(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 12, cfg.d_model))
    y1 = ssm.ssm_train(p, cfg, x, chunk=3)
    y2 = ssm.ssm_train(p, cfg, x, chunk=12)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)


def test_mlstm_chunk_invariance():
    cfg = FAMILIES["xlstm"]
    p = xlstm.init_mlstm(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 12, cfg.d_model)) * 0.5
    y1, s1 = xlstm.mlstm_train(p, cfg, x, chunk=4)
    y2, s2 = xlstm.mlstm_train(p, cfg, x, chunk=12)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1[0]), np.asarray(s2[0]), rtol=2e-3, atol=2e-4)


def test_vlm_patches_shift_logits():
    cfg = FAMILIES["vlm"]
    key = jax.random.PRNGKey(9)
    params, buffers = lm.init(key, cfg)
    toks = _tokens(cfg, 1, 6, key)
    pe1 = jnp.zeros((1, cfg.n_patches, cfg.d_model))
    pe2 = jnp.ones((1, cfg.n_patches, cfg.d_model))
    l1, _ = lm.forward(params, buffers, cfg, {"tokens": toks, "patch_emb": pe1})
    l2, _ = lm.forward(params, buffers, cfg, {"tokens": toks, "patch_emb": pe2})
    assert l1.shape == (1, 6, cfg.vocab)  # logits only for text positions
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
