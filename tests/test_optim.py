"""Optimizers, schedules, ZeRO-1 specs, int8 gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.optim import adamw, sgd, clip_by_global_norm, cosine_schedule
from repro.optim.compression import (
    compressed_grad_transform,
    init_error_feedback,
    int8_compress,
    int8_decompress,
)
from repro.optim.optimizers import moment_specs, zero1_specs


def test_sgd_momentum_reference():
    opt = sgd(momentum=0.9)
    p = {"w": jnp.asarray([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.5, -0.5])}
    p1, s1 = opt.update(g, s, p, 0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, 2.05])
    p2, _ = opt.update(g, s1, p1, 0.1)
    # m = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95 - 0.095, 2.05 + 0.095])


def test_adamw_matches_manual():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.1])}
    p1, s1 = opt.update(g, s, p, 0.01)
    # bias-corrected first step: update = g/|g| -> p - lr
    np.testing.assert_allclose(np.asarray(p1["w"]), [1.0 - 0.01], rtol=1e-4)


def test_adamw_weight_decay_decoupled():
    opt = adamw(weight_decay=0.1)
    p = {"w": jnp.asarray([2.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.0])}
    p1, _ = opt.update(g, s, p, 0.01)
    np.testing.assert_allclose(np.asarray(p1["w"]), [2.0 - 0.01 * 0.1 * 2.0], rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0)
    total = np.sqrt(sum(float((x**2).sum()) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1e-3, rtol=1e-5)
    assert float(lr(100)) < 1.3e-4
    assert float(lr(5)) == pytest.approx(5e-4)


def test_zero1_specs_extend_over_data():
    specs = {"w": P(None, "model"), "e": P("data", None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
              "e": jax.ShapeDtypeStruct((16, 8, 4), jnp.float32)}
    z = zero1_specs(specs, shapes, dp_axis="data", dp_size=16)
    assert z["w"] == P("data", "model")  # largest free dim gets dp
    assert z["e"] == P("data", None, "model")  # already uses data: untouched


def test_moment_specs_structure():
    specs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    m = moment_specs("adamw", specs, shapes, dp_size=16)
    assert set(m) == {"m", "v", "t"}
    assert m["t"] == P()


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.01, 10), 257).astype(np.float32))
    q, scale = int8_compress(x)
    back = int8_decompress(q, scale)
    err = float(jnp.abs(back - x).max())
    assert err <= float(scale) * 0.5 + 1e-9  # half-ULP of the int8 grid


def test_error_feedback_removes_bias():
    """Constant gradient: with error feedback the AVERAGE applied gradient
    converges to the true one even when a single step misquantizes."""
    g = {"w": jnp.full((64,), 0.31)}
    err = init_error_feedback(g)
    applied = []
    for _ in range(50):
        dq, err = compressed_grad_transform(g, err)
        applied.append(np.asarray(dq["w"]))
    mean = np.mean(applied, axis=0)
    np.testing.assert_allclose(mean, 0.31, rtol=1e-3)


def test_compression_preserves_convergence():
    """SGD on a quadratic with int8+EF reaches the optimum."""
    w = jnp.asarray([5.0, -3.0, 2.0])
    target = jnp.asarray([1.0, 1.0, 1.0])
    err = init_error_feedback({"w": w})
    for _ in range(300):
        g = {"w": w - target}
        dq, err = compressed_grad_transform(g, err)
        w = w - 0.1 * dq["w"]
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)
