"""Optimizer-state handling for the CCE clustering transition.

``CCE.cluster`` rewrites a table's rows (centroids into the main table,
zeros into the helper) and its pointer array, but the momentum / Adam
moments of those rows still describe the OLD rows.  Applying them
unchanged is the dynamic-reassignment failure mode CAFE (Zhang et al.,
2023) warns about — stale second moments throttle the effective step size
of freshly-merged rows arbitrarily — and the reason Shi et al. (2020)
keep compositional tables optimizer-stable.  ``remap_opt_state`` threads
a moment transform through the optimizer-state tree, policy-selected:

  * ``"remap"`` — per-row moments follow the cluster assignments (mean of
    the merged rows' moments, zeros for the fresh helper table — see
    ``CCE.remap_moments``, or ``CCE.remap_moments_sharded`` when the
    transition runs over a mesh: the O(d1) averaging pass then shards its
    id ranges and pointer operands over the mesh axis and psums the
    per-cluster sums, bit-identical on a 1-device axis), the moment-space
    analog of setting the main table to the centroids.
  * ``"reset"`` — zero the transitioned tables' moments (fresh start).
  * ``"keep"`` — leave the state untouched (the pre-fix behavior, kept
    for ablation).

Only per-row moment slots are touched; scalar slots (the Adam step count
``t``) pass through so bias correction stays continuous across the
transition and checkpoint resume stays restart-exact.

Under a model-sharded trainer (launch.steps.dlrm_state_specs) the moment
slabs enter sharded exactly like their params; the eager transition's
outputs land wherever jax puts them and the Trainer device_puts the whole
state back onto the step's layout (``Trainer._place``) before the next
donated step — this module stays layout-agnostic.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any

#: Per-row moment slots of the optimizers in this repo
#: (sgd-momentum: {"m"}; adamw: {"m", "v"}).
MOMENT_KEYS = ("m", "v")

POLICIES = ("remap", "reset", "keep")


def remap_opt_state(
    opt: Pytree,
    update_fn: Callable[[Pytree, str], Pytree],
    *,
    policy: str = "remap",
    moment_keys: tuple[str, ...] = MOMENT_KEYS,
) -> Pytree:
    """Apply ``update_fn(moment_tree, slot_name)`` to each per-parameter
    moment tree in an optimizer state.  ``update_fn`` receives the full
    moment tree (same structure as params) and replaces only the subtrees
    belonging to transitioned tables — non-embedding moments flow through
    untouched.  Plain-SGD state ({}) and ``policy="keep"`` are no-ops."""
    if policy not in POLICIES:
        raise ValueError(f"unknown transition policy {policy!r}; want one of {POLICIES}")
    if opt is None or policy == "keep" or not opt:
        return opt
    new = dict(opt)
    for slot in moment_keys:
        if slot in new:
            new[slot] = update_fn(new[slot], slot)
    return new


def zeros_like_moments(moments: Pytree) -> Pytree:
    """The ``"reset"`` policy for one table's moment subtree."""
    return jax.tree.map(jnp.zeros_like, moments)


def collection_moment_updater(coll, group_updates):
    """Moment transform for the GROUPED embedding layout.

    Optimizer moments mirror params, so under an ``EmbeddingCollection``
    a CCE group's moments live in one stacked (F·c, 2, k, dsub) slab.
    ``group_updates`` maps group index -> {feature-local index ->
    per-feature moment-update fn (from ``transition_table``)}; the
    returned function slices each transitioned feature's block out of the
    slab, applies its update, and re-stacks — zero-padded moment rows
    (ragged codebooks) stay zero, mirroring their never-touched params.
    Applied once per moment slot (Adam's m AND v) by ``remap_opt_state``.
    """

    def update(emb_moments):
        out = list(emb_moments)
        for g, fns in group_updates.items():
            grp = coll.groups[g]
            per = coll.unstack_group_params(grp, emb_moments[g])
            for f_local, fn in fns.items():
                per[f_local] = fn(per[f_local])
            out[g] = coll.stack_group_params(grp, per)
        return out

    return update
