"""Gradient compression for the inter-pod all-reduce path.

int8 per-tensor-scale quantization with error feedback (Seide et al. /
1-bit Adam lineage): the quantization residual is carried into the next
step's gradient, so the compression bias vanishes in expectation and SGD
convergence is preserved.  Used on the slow (DCN / inter-pod) gradient
path; intra-pod reductions stay full precision.

Pure functions so they compose inside the jitted train step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def int8_compress(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(values int8, scale f32).  Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compressed_grad_transform(grads: Pytree, error: Pytree) -> tuple[Pytree, Pytree]:
    """Quantize (grad + carried error) to int8 and return the dequantized
    gradient plus the new error feedback state.

    In the distributed step this runs *before* the inter-pod reduction:
    XLA then moves int8 tensors over DCN instead of f32 — a 4x reduction of
    the slowest collective.  (The all-reduce itself still sums dequantized
    values; true int8 ring-reduction needs a custom collective, noted in
    DESIGN.md as a TPU-runtime limitation.)
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = int8_compress(target)
        deq = int8_decompress(q, scale)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, new_e
