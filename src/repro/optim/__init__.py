from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    sgd,
    clip_by_global_norm,
    cosine_schedule,
    zero1_specs,
)
from repro.optim.remap import (  # noqa: F401
    remap_opt_state,
    zeros_like_moments,
)
from repro.optim.compression import (  # noqa: F401
    int8_compress,
    int8_decompress,
    compressed_grad_transform,
)
