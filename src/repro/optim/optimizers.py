"""Optimizers as pure (init, update) pairs over param pytrees.

SGD(+momentum) is the paper's choice for DLRM; AdamW is the LM default.
ZeRO-1: `zero1_specs` extends a parameter PartitionSpec tree so optimizer
moments are additionally sharded over the data axis wherever a dimension
is divisible — optimizer state then costs 1/(dp·tp) per device while the
params keep their own layout (XLA inserts the gather on use; with the
moments only read once per step this is the standard ZeRO-1 trade).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jax.Array], tuple[Pytree, Pytree]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def sgd(momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_params, state
        m = jax.tree.map(lambda m, g: momentum * m + g, state["m"], grads)
        new_params = jax.tree.map(lambda p, m_: p - lr * m_, params, m)
        return new_params, {"m": m}

    return Optimizer(init, update)


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def step(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p - lr * (upd + weight_decay * p)).astype(p.dtype)

        new_params = jax.tree.map(step, params, m, v)
        return new_params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def zero1_specs(param_specs: Pytree, params_shape: Pytree, dp_axis: str = "data",
                dp_size: int = 0) -> Pytree:
    """PartitionSpec tree for optimizer moments: start from the param spec
    and additionally shard the largest unsharded dim over ``dp_axis`` where
    divisible by ``dp_size`` (ZeRO-1).  ``params_shape``: matching tree of
    jax.ShapeDtypeStruct (or arrays)."""

    def extend(spec, leaf):
        if not isinstance(spec, P):
            return spec
        shape = leaf.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = {a for e in entries if e for a in ((e,) if isinstance(e, str) else e)}
        if dp_axis in used:  # EP-style params already consume the dp axis
            return spec
        best, best_dim = -1, -1
        for i, (s, e) in enumerate(zip(shape, entries)):
            if e is None and dp_size and s % dp_size == 0 and s > best:
                best, best_dim = s, i
        if best_dim >= 0:
            entries[best_dim] = dp_axis
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree.map(
        extend, param_specs, params_shape,
        is_leaf=lambda x: isinstance(x, P),
    )


def moment_specs(opt_name: str, param_specs: Pytree, params_shape: Pytree,
                 dp_axis: str = "data", dp_size: int = 0) -> Pytree:
    """Spec tree matching the optimizer *state* structure."""
    z = zero1_specs(param_specs, params_shape, dp_axis, dp_size)
    if opt_name == "sgd":
        return {}
    if opt_name == "sgdm":
        return {"m": z}
    return {"m": z, "v": z, "t": P()}
