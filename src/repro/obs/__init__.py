"""Runtime observability — DESIGN.md §10.

Four pieces, split by where they run:

  * ``telemetry``  — in-step device health metrics (per-emb-group norms,
    nonfinite counts with leaf attribution, lookup occupancy, routing
    skew) that ride the train step's existing launch as extra entries in
    the returned ``metrics`` dict.  Zero extra dispatches — asserted by
    the ``train_step_telemetry`` audit spec.
  * ``pump``       — the host-side async metrics pump: a ring of
    in-flight device metric trees drained N steps late, so reading
    metrics never forces the dispatch pipeline to sync.
  * ``runlog``     — schema-versioned JSONL run log (manifest + typed
    events: step records, trigger evaluations, transitions, checkpoint
    save/restore, fault fires, serve latency) with restart-safe
    append-and-dedupe semantics, plus the fixed-bucket
    ``LatencyHistogram`` the serve engine feeds.
  * ``trace``      — ``jax.named_scope``/profiler spans on the logical
    phases (translate, dispatch, sketch-fold, transition, checkpoint)
    and the opt-in ``ProfileWindow`` profiler-trace dump.

``python -m repro.obs summarize RUN.jsonl`` renders a run log (p50/p99
step time, loss curve, trigger/transition timeline, shard balance).
The CLI (``summary``, ``runlog``) is importable without jax — device
imports stay behind this lazy ``__getattr__``.
"""
from repro.obs.runlog import SCHEMA_VERSION, LatencyHistogram, RunLog

_LAZY = {
    "TelemetryConfig": "repro.obs.telemetry",
    "telemetry_metrics": "repro.obs.telemetry",
    "telemetry_labels": "repro.obs.telemetry",
    "MetricsPump": "repro.obs.pump",
    "span": "repro.obs.trace",
    "ProfileWindow": "repro.obs.trace",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "SCHEMA_VERSION",
    "RunLog",
    "LatencyHistogram",
    "TelemetryConfig",
    "telemetry_metrics",
    "telemetry_labels",
    "MetricsPump",
    "span",
    "ProfileWindow",
]
