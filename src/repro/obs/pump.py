"""The async metrics pump — host-side metric draining that never stalls
the dispatch pipeline.

jax dispatch is asynchronous: the step call returns futures and the
device keeps executing while the host prepares the next batch.  Reading
a metric value (``float(metrics["loss"])``) blocks until THAT step
finishes — done every step, it serializes host and device and the
measured step time quietly includes the sync (the exact bug the old
``Trainer.run`` had).

``MetricsPump`` holds a ring of in-flight device metric trees and only
``device_get``s an entry once it is ``lag`` steps behind the dispatch
front — by then the values are already materialized and the transfer is
a no-wait copy.  Host-visible effects:

  * ``history`` — bounded deque (``maxlen``) of per-step records: python
    floats for scalars, numpy arrays for telemetry vectors.
  * ``sink``    — optional callback per drained record (the Trainer
    wires ``RunLog`` step events through this).

``flush()`` drains everything in flight — the explicit sync point for
tests, checkpoint boundaries, and end-of-run (records are exact and
complete after a flush; only their *timing* is late).
"""
from __future__ import annotations

from collections import deque
from typing import Callable

import jax
import numpy as np


def _to_host(tree):
    """device tree -> record leaves: 0-d values become python floats,
    vectors become numpy arrays (json-ready via RunLog's encoder)."""
    host = jax.device_get(tree)

    def conv(x):
        arr = np.asarray(x)
        return float(arr) if arr.ndim == 0 else arr

    return jax.tree.map(conv, host)


class MetricsPump:
    """Ring of (step, device metric tree) drained ``lag`` steps late."""

    def __init__(
        self,
        *,
        lag: int = 8,
        maxlen: int | None = 10_000,
        sink: Callable[[dict], None] | None = None,
    ):
        self.lag = max(0, int(lag))
        self.history: deque[dict] = deque(maxlen=maxlen)
        self.sink = sink
        self._ring: deque = deque()

    def __len__(self) -> int:  # records still in flight
        return len(self._ring)

    def push(self, step: int, metrics, *, extra: dict | None = None) -> None:
        """Enqueue one step's device metrics; drains whatever fell
        ``lag`` steps behind.  ``extra`` carries host-side fields (dt)
        that ride the record without touching the device."""
        self._ring.append((step, metrics, extra))
        while len(self._ring) > self.lag:
            self._drain_one()

    def _drain_one(self) -> None:
        step, metrics, extra = self._ring.popleft()
        record = _to_host(metrics)
        record["step"] = int(step)
        if extra:
            record.update(extra)
        self.history.append(record)
        if self.sink is not None:
            self.sink(record)

    def flush(self) -> None:
        """Drain every in-flight record (blocks until the device catches
        up — the documented sync point for tests and checkpoints)."""
        while self._ring:
            self._drain_one()
