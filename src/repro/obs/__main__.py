"""CLI: ``python -m repro.obs summarize RUN.jsonl [--json OUT.json]``.

Stays importable (and runnable) without jax — run logs are read on
machines that never touch the accelerator stack.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.summary import summarize_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summarize", help="render a run-log report")
    s.add_argument("runlog", help="path to RUN.jsonl")
    s.add_argument("--json", default=None, help="also write the summary dict")
    args = ap.parse_args(argv)

    try:
        text, data = summarize_path(args.runlog)
    except OSError as e:
        print(f"cannot read {args.runlog}: {e}", file=sys.stderr)
        return 2
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(data, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
