"""Trace spans and the opt-in profiler window.

``span(name)`` stacks ``jax.named_scope`` (the name lands in the HLO
metadata of every op traced inside, so device timelines group by logical
phase) with ``jax.profiler.TraceAnnotation`` (the host-side interval
shows up in a captured profiler trace).  Both are metadata-only: no
device work, no effect on the jaxpr's equations — the telemetry audit
spec's launch budget is unchanged by spans.

The canonical phases the training loop annotates:

    translate    host pointer translation (data/translate.py)
    dispatch     the jitted train step call
    sketch-fold  tracker observe / async fold enqueue
    transition   the eager clustering transition (Alg. 3)
    checkpoint   async checkpoint save enqueue

``ProfileWindow`` dumps a ``jax.profiler`` trace directory for a
half-open step window [start, stop) — pass
``Trainer(profile_steps=(start, stop), profile_dir=...)`` and view the
result in TensorBoard/XProf.  One window per process: profiling is a
heavy, explicitly-requested act, not an always-on mode.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax


@contextlib.contextmanager
def span(name: str):
    """Annotate a logical phase on both the device (named_scope -> HLO
    metadata) and host (TraceAnnotation -> profiler timeline) sides."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


@dataclasses.dataclass
class ProfileWindow:
    """Opt-in [start, stop) profiler capture, driven by step number."""

    start: int
    stop: int
    log_dir: str
    active: bool = False
    done: bool = False

    def __post_init__(self):
        assert self.start < self.stop, "profile window must be non-empty"

    def observe(self, step: int) -> None:
        """Call once per loop iteration with the step about to run."""
        if self.active and step >= self.stop:
            jax.profiler.stop_trace()
            self.active = False
            self.done = True
        if not self.done and not self.active and self.start <= step < self.stop:
            jax.profiler.start_trace(self.log_dir)
            self.active = True

    def close(self) -> None:
        """Stop a still-open capture (end of run / exception path)."""
        if self.active:
            jax.profiler.stop_trace()
            self.active = False
            self.done = True
