"""Run-log analysis behind ``python -m repro.obs summarize``.

Pure host code (stdlib + numpy): reading a run log must work on a
machine with no accelerator stack installed.  Readers ignore unknown
event types and fields (the schema's compatibility rule).
"""
from __future__ import annotations

import numpy as np

from repro.obs.runlog import read_runlog


def _by_event(records):
    out: dict[str, list] = {}
    for r in records:
        out.setdefault(r.get("event", "?"), []).append(r)
    return out


def _sparkline(values, width: int = 48) -> str:
    marks = "▁▂▃▄▅▆▇█"
    v = np.asarray(values, np.float64)
    v = v[np.isfinite(v)]
    if v.size == 0:
        return ""
    if v.size > width:  # bucket means down to the display width
        edges = np.linspace(0, v.size, width + 1).astype(int)
        v = np.array([v[a:b].mean() for a, b in zip(edges[:-1], edges[1:])])
    lo, hi = float(v.min()), float(v.max())
    span = (hi - lo) or 1.0
    return "".join(marks[int((x - lo) / span * (len(marks) - 1))] for x in v)


def summarize_dict(records: list[dict]) -> dict:
    """The machine-readable summary (the ``--json`` artifact)."""
    ev = _by_event(records)
    out: dict = {}
    man = ev.get("manifest", [{}])[0]
    out["manifest"] = {
        k: man.get(k)
        for k in ("schema", "config", "backend", "n_devices", "git_sha")
    }

    steps = sorted(ev.get("step", []), key=lambda r: r["step"])
    out["steps"] = {"n": len(steps)}
    if steps:
        nums = [r["step"] for r in steps]
        out["steps"]["first"] = nums[0]
        out["steps"]["last"] = nums[-1]
        out["steps"]["contiguous"] = nums == list(range(nums[0], nums[-1] + 1))
        dts = [r["dt"] for r in steps if r.get("dt") is not None]
        if dts:
            out["steps"]["dt_p50_ms"] = float(np.percentile(dts, 50)) * 1e3
            out["steps"]["dt_p99_ms"] = float(np.percentile(dts, 99)) * 1e3
        losses = [r["loss"] for r in steps if "loss" in r]
        if losses:
            out["steps"]["loss_first"] = losses[0]
            out["steps"]["loss_last"] = losses[-1]
        nonfin = [
            int(np.sum(r["telemetry"]["param_nonfinite"]))
            for r in steps
            if "telemetry" in r and "param_nonfinite" in r["telemetry"]
        ]
        if nonfin:
            out["steps"]["nonfinite_param_steps"] = int(
                np.count_nonzero(nonfin)
            )
        occ = [
            r["telemetry"]["shard_occupancy"]
            for r in steps
            if "telemetry" in r and "shard_occupancy" in r["telemetry"]
        ]
        if occ:
            mean = np.asarray(occ, np.float64).mean(axis=0)
            out["shard_balance"] = {
                "mean_occupancy": mean.tolist(),
                # max/min per-shard load ratio: 1.0 = perfectly balanced
                "skew": float(mean.max() / max(mean.min(), 1e-12)),
            }

    out["triggers"] = {
        "n": len(ev.get("trigger", [])),
        "fired": sum(1 for r in ev.get("trigger", []) if r.get("fire")),
    }
    out["transitions"] = [
        {"step": r.get("step"), "reason": r.get("reason")}
        for r in ev.get("transition", [])
    ]
    out["checkpoints"] = {
        "saved": [r.get("step") for r in ev.get("checkpoint_save", [])],
        "restored": [r.get("step") for r in ev.get("checkpoint_restore", [])],
    }
    out["faults"] = [r.get("step") for r in ev.get("fault", [])]

    hists = ev.get("latency_hist", [])
    if hists:
        h = hists[-1]
        out["serve_latency"] = {
            k: h.get(k) for k in ("n", "p50", "p99", "label")
        }
        if len(hists) > 1:  # the serve engine writes overall/hit/cold
            out["serve_latency_by_label"] = [
                {k: h.get(k) for k in ("n", "p50", "p99", "label")}
                for h in hists
            ]

    # serve-cache health: per-request hit flags + refresh events
    # (serve/dlrm.py writes both; request events without the flag are the
    # LM engine's and are skipped)
    hits = [r["cache_hit"] for r in ev.get("request", []) if "cache_hit" in r]
    if hits:
        out["serve_cache"] = {
            "n_requests": len(hits),
            "hit_rate": float(np.mean(hits)),
        }
    refreshes = ev.get("cache_refresh", [])
    if refreshes:
        out["cache_refreshes"] = [
            {k: r.get(k) for k in ("reason", "n_slots", "n_features", "churn")}
            for r in refreshes
        ]
    return out


def format_summary(records: list[dict]) -> str:
    """The human-readable report."""
    s = summarize_dict(records)
    ev = _by_event(records)
    lines = []
    man = s["manifest"]
    lines.append(
        f"run: config={man['config']} backend={man['backend']} "
        f"devices={man['n_devices']} sha={man['git_sha']} "
        f"schema=v{man['schema']}"
    )
    st = s["steps"]
    if st["n"]:
        cont = "contiguous" if st.get("contiguous") else "GAPS"
        lines.append(
            f"steps: {st['n']} ({st.get('first')}..{st.get('last')}, {cont})"
        )
        if "dt_p50_ms" in st:
            lines.append(
                f"step time: p50 {st['dt_p50_ms']:.2f} ms   "
                f"p99 {st['dt_p99_ms']:.2f} ms"
            )
        if "loss_first" in st:
            steps = sorted(ev["step"], key=lambda r: r["step"])
            curve = _sparkline([r.get("loss", np.nan) for r in steps])
            lines.append(
                f"loss: {st['loss_first']:.4f} -> {st['loss_last']:.4f}  "
                f"{curve}"
            )
        if st.get("nonfinite_param_steps"):
            lines.append(
                f"!! nonfinite params on {st['nonfinite_param_steps']} steps"
            )
    else:
        lines.append("steps: 0")
    if "shard_balance" in s:
        occ = ", ".join(f"{x:.3f}" for x in s["shard_balance"]["mean_occupancy"])
        lines.append(
            f"shard balance: occupancy [{occ}]  "
            f"skew {s['shard_balance']['skew']:.2f}x"
        )
    tr = s["triggers"]
    if tr["n"]:
        lines.append(f"trigger: {tr['n']} evaluations, {tr['fired']} fired")
        for r in ev.get("trigger", []):
            mark = f"FIRED ({r.get('reason')})" if r.get("fire") else "held"
            lines.append(
                f"  step {r.get('step', '?'):>5}  "
                f"entropy {r.get('entropy', float('nan')):6.3f}  "
                f"drift {r.get('drift', float('nan')):5.3f}  {mark}"
            )
    for t in s["transitions"]:
        lines.append(f"transition: step {t['step']} ({t['reason']})")
    ck = s["checkpoints"]
    if ck["saved"] or ck["restored"]:
        lines.append(
            f"checkpoints: saved at {ck['saved']}; restored at {ck['restored']}"
        )
    for f in s["faults"]:
        lines.append(f"fault injected: step {f}")
    for sl in s.get("serve_latency_by_label", [s["serve_latency"]]
                    if "serve_latency" in s else []):
        lines.append(
            f"serve latency ({sl.get('label') or 'requests'}): n={sl['n']}  "
            f"p50 {sl['p50'] * 1e3:.2f} ms  p99 {sl['p99'] * 1e3:.2f} ms"
        )
    if "serve_cache" in s:
        sc = s["serve_cache"]
        lines.append(
            f"serve cache: {sc['n_requests']} requests, "
            f"hit rate {sc['hit_rate']:.1%}"
        )
    for r in s.get("cache_refreshes", []):
        churn = r.get("churn")
        extra = f"  churn {churn:.2f}" if churn is not None else ""
        lines.append(
            f"cache refresh ({r.get('reason')}): {r.get('n_slots')} slots / "
            f"{r.get('n_features')} features{extra}"
        )
    return "\n".join(lines)


def summarize_path(path) -> tuple[str, dict]:
    records = read_runlog(path)
    return format_summary(records), summarize_dict(records)
