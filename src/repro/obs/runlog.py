"""Schema-versioned JSONL run log + the fixed-bucket latency histogram.

One line per record.  The first line of a fresh file is the MANIFEST
(``event: "manifest"``) carrying ``schema`` (``SCHEMA_VERSION``) and the
run's identifying facts (config name, mesh shape, backend, git sha).
Every other line is a typed event: ``step`` records, ``trigger``
evaluations, ``transition``s, ``checkpoint_save``/``checkpoint_restore``,
``fault`` fires, serve ``request``/``latency_hist`` records.

Versioning rule: adding fields to existing events or adding new event
types is compatible and does NOT bump ``SCHEMA_VERSION``; renaming or
re-typing an existing field does.  Readers must ignore unknown fields
and unknown event types.

Restart safety mirrors the trigger's replay semantics
(``Trainer.restore_latest`` drops post-checkpoint trigger events because
resume re-evaluates them): re-opening an existing log APPENDS, and any
replayed (event, step) pair already in the file is dropped — a crash +
resume yields one contiguous set of step records and one record per
closed trigger window, not duplicates.  Events that legitimately recur
at the same step across process restarts (``fault``,
``checkpoint_restore``) opt out via ``dedupe=False``.

This module is importable without jax (the CLI and its tests stay
host-only); ``default_manifest`` probes jax/git lazily and degrades.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA_VERSION = 1


def _json_default(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()  # audit: allow-int-cast — host-side json encoding
    raise TypeError(f"not JSON-serializable: {type(x).__name__}")


def default_manifest(config: str | None = None, **extra) -> dict:
    """Best-effort run-identifying facts (backend/mesh probe jax, sha
    probes git; both degrade to None off-device / outside a checkout)."""
    man: dict = {"config": config}
    try:
        import jax

        man["backend"] = jax.default_backend()
        man["n_devices"] = jax.device_count()
    except Exception:
        man["backend"] = None
        man["n_devices"] = None
    try:
        import subprocess

        man["git_sha"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        man["git_sha"] = None
    man.update(extra)
    return man


class RunLog:
    """Append-only JSONL writer with (event, step) replay dedupe."""

    def __init__(self, path, *, manifest: dict | None = None):
        self.path = os.fspath(path)
        self._seen: set[tuple[str, int]] = set()
        self.manifest: dict | None = None
        existing = os.path.exists(self.path) and os.path.getsize(self.path) > 0
        if existing:
            for rec in read_runlog(self.path):
                if rec.get("event") == "manifest":
                    self.manifest = rec
                elif "step" in rec:
                    self._seen.add((rec["event"], int(rec["step"])))
            self._f = open(self.path, "a")
            if (
                self.manifest is not None
                and self.manifest.get("schema") != SCHEMA_VERSION
            ):
                import warnings

                warnings.warn(
                    f"resuming run log with schema "
                    f"{self.manifest.get('schema')} != {SCHEMA_VERSION}; "
                    "appended records use the current schema"
                )
        else:
            self._f = open(self.path, "w")
            self.manifest = {
                "event": "manifest",
                "schema": SCHEMA_VERSION,
                "time": time.time(),
                **(manifest or {}),
            }
            self._write(self.manifest)

    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, default=_json_default) + "\n")
        self._f.flush()

    def append(
        self, event: str, *, step: int | None = None, dedupe: bool = True,
        **fields,
    ) -> bool:
        """Write one event line.  Returns False when the (event, step)
        pair was already logged (a replayed event after resume)."""
        if step is not None:
            key = (event, int(step))
            if dedupe and key in self._seen:
                return False
            self._seen.add(key)
        rec = {"event": event}
        if step is not None:
            rec["step"] = int(step)
        rec.update(fields)
        self._write(rec)
        return True

    def log_step(self, record: dict) -> bool:
        """One drained pump record -> one ``step`` event (the pump's
        ``sink``).  Replays after a checkpoint resume dedupe away."""
        rec = dict(record)
        step = rec.pop("step")
        return self.append("step", step=step, **rec)

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_runlog(path) -> list[dict]:
    """Parse a JSONL run log (tolerates a truncated final line — the
    writer may have died mid-record)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return records


class LatencyHistogram:
    """Fixed log-spaced latency buckets (seconds) — constant memory at
    any request volume, mergeable across processes by adding counts.

    ``n_buckets`` spans [lo, hi) geometrically; observations clamp into
    the end buckets so nothing is dropped.  Percentiles are upper-edge
    estimates (conservative: the true quantile is <= the reported one).
    """

    def __init__(self, lo: float = 1e-5, hi: float = 10.0, n_buckets: int = 40):
        assert 0 < lo < hi and n_buckets >= 2
        self.lo, self.hi = float(lo), float(hi)
        # n_buckets-1 interior edges -> n_buckets bins incl. both tails
        self.edges = np.geomspace(lo, hi, n_buckets - 1)
        self.counts = np.zeros(n_buckets, np.int64)

    @property
    def n(self) -> int:
        # host-side int64 bucket counts, never traced or decayed
        return int(self.counts.sum())  # audit: allow-int-cast

    def observe(self, seconds: float) -> None:
        self.counts[int(np.searchsorted(self.edges, seconds, "right"))] += 1

    def percentile(self, q: float) -> float:
        """Upper bucket edge at quantile ``q`` in [0, 100]."""
        if self.n == 0:
            return 0.0
        rank = np.ceil(self.n * q / 100.0)
        idx = int(np.searchsorted(np.cumsum(self.counts), max(rank, 1)))
        return float(self.edges[min(idx, len(self.edges) - 1)])

    def to_dict(self) -> dict:
        return {
            "lo": self.lo,
            "hi": self.hi,
            "edges": self.edges.tolist(),
            "counts": self.counts.tolist(),
            "n": self.n,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }
