"""In-step device telemetry — health metrics that ride the train step.

Everything here is pure ``jnp`` reductions over values the step already
holds (the averaged gradients, the params, the batch), returned as extra
entries under ``metrics["telemetry"]`` — the same protocol
``metrics["sketch_delta"]`` uses.  The reductions lower INTO the step's
single program: no extra pallas launches, no callbacks, no transfers
(the ``train_step_telemetry`` audit spec pins all three).  The host side
never blocks on these values either — ``repro.obs.pump.MetricsPump``
drains them N steps behind the dispatch front.

Signals (each gated by a ``TelemetryConfig`` flag):

  * ``emb_grad_norm`` / ``emb_param_norm`` — (G,) per-embedding-group L2
    norms of gradient / slab.  A group whose grad norm collapses (or
    explodes) after a clustering transition is the first thing an
    operator checks.
  * ``grad_nonfinite`` / ``param_nonfinite`` — (L,) per-param-leaf
    counts of non-finite elements.  The leaf ORDER is the flatten order
    of the param tree; ``telemetry_labels`` names each index, which is
    what attributes a NaN to the emb group that produced it (note a NaN
    in one leaf's *params* poisons every leaf's *grads* through
    backprop — attribution reads the param side).
  * ``rows_occupancy`` — scalar fraction of non-sentinel entries in the
    host-translated ``rows`` tensor (-1 marks padded sub-table slots;
    the fused kernel treats them as no-ops).  A drifting occupancy means
    the fuse layout is wasting kernel work.
  * ``shard_occupancy`` — (M,) per-model-shard fraction of non-sentinel
    entries when rows arrive pre-bucketed (B, M, n_cols, T): the
    all-to-all routing skew.  Zipf traffic concentrates ids; a shard
    running hot here is the signal the ps-lite routing layer re-balances
    on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Which in-step signals to compute.  All default on: each is a
    handful of reductions, fused into the step program for free."""

    emb_norms: bool = True
    nonfinite: bool = True
    occupancy: bool = True


def telemetry_labels(params) -> dict:
    """Host-side companion: names for the telemetry vector indices.

    ``leaves[i]`` labels ``grad_nonfinite[i]`` / ``param_nonfinite[i]``
    (jax flatten order); ``emb_groups`` is G, the length of the
    ``emb_*_norm`` vectors (0 when params carry no per-group emb list).
    """
    paths, _ = jax.tree_util.tree_flatten_with_path(params)
    emb = params.get("emb") if isinstance(params, dict) else None
    return {
        "leaves": tuple(jax.tree_util.keystr(p) for p, _ in paths),
        "emb_groups": len(emb) if isinstance(emb, (list, tuple)) else 0,
    }


def _group_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(leaf.astype(jnp.float32)))
        for leaf in jax.tree.leaves(tree)
    )
    return jnp.sqrt(jnp.asarray(sq, jnp.float32))


def _nonfinite_counts(tree) -> jax.Array:
    return jnp.stack(
        [
            jnp.sum(~jnp.isfinite(leaf), dtype=jnp.int32)
            for leaf in jax.tree.leaves(tree)
        ]
    )


def telemetry_metrics(tcfg: TelemetryConfig, grads, params, batch) -> dict:
    """The in-step telemetry tree — call INSIDE the jitted step with the
    averaged (pre-clip) grads, the current params, and the full batch
    (leaves shaped (accum, micro, ...)).  Returns a flat dict of small
    arrays; ``telemetry_labels(params)`` names the vector indices."""
    out: dict = {}
    emb = params.get("emb") if isinstance(params, dict) else None
    if tcfg.emb_norms and isinstance(emb, (list, tuple)):
        out["emb_grad_norm"] = jnp.stack([_group_norm(g) for g in grads["emb"]])
        out["emb_param_norm"] = jnp.stack([_group_norm(p) for p in emb])
    if tcfg.nonfinite:
        out["grad_nonfinite"] = _nonfinite_counts(grads)
        out["param_nonfinite"] = _nonfinite_counts(params)
    rows = batch.get("rows") if isinstance(batch, dict) else None
    if tcfg.occupancy and rows is not None:
        live = (rows >= 0).astype(jnp.float32)
        out["rows_occupancy"] = jnp.mean(live)
        if rows.ndim == 5:  # (accum, micro, M, n_cols, T): pre-bucketed
            out["shard_occupancy"] = jnp.mean(live, axis=(0, 1, 3, 4))
    return out
