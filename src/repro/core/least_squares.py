"""Dense and Sparse CCE for Least Squares — Algorithms 1 & 2 and the
machinery of Theorem 3.1.

Problem: given X (n, d1), Y (n, d2), find T minimizing ||X T - Y||_F^2
without ever storing a d1 x d2 matrix.  We keep T factored as H @ M with
H (d1, k) sparse-or-random and M (k, d2) dense, k << d1.

Dense CCE (Alg. 1, proven):  H_i = [T_{i-1} | G_i] with G_i fresh Gaussian
noise; M_i solves the k-dim least squares; T_i = H_i M_i.  Theorem 3.1:

    E||X T_i - Y||^2 <= (1 - rho)^{i(k-d2)} ||X T*||^2 + ||X T* - Y||^2,
    rho = sigma_min(X)^2 / ||X||_F^2.

"Smart noise" variant (Appendix B): G_i = V Sigma^{-1} G' aligned with the
SVD of X improves the rate to (1 - 1/d1)^{i(k-d2)}.

Sparse CCE (Alg. 2, what the full system builds on):  instead of carrying
T_{i-1} densely, K-means it into k/2 clusters -> assignment matrix A
(one-hot, sparse) and combine with a fresh count-sketch C:
H_i = [A | C]; M_i again solved exactly.  The factored representation
(assignments + centroids) is all that's ever stored.

Everything here is pure jnp and runs on CPU in seconds at the paper's
Figure-1b scale (n=1e4, d1=1e3, d2=10).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core import kmeans as km


class LSTrace(NamedTuple):
    losses: jax.Array  # (iters+1,) ||X T_i - Y||_F^2
    T: jax.Array  # final (d1, d2)


def _solve_M(XH: jax.Array, Y: jax.Array) -> jax.Array:
    """argmin_M ||XH M - Y||_F^2 via lstsq (k x k normal equations)."""
    return jnp.linalg.lstsq(XH, Y)[0]


def loss(X, T, Y) -> jax.Array:
    return jnp.sum((X @ T - Y) ** 2)


def optimal_loss(X, Y) -> tuple[jax.Array, jax.Array]:
    T_star = jnp.linalg.lstsq(X, Y)[0]
    return loss(X, T_star, Y), T_star


def theorem_bound(X, Y, k: int, iters: int) -> jax.Array:
    """The RHS of Theorem 3.1 per iteration: (1-rho)^{i(k-d2)}||XT*||^2 + opt."""
    d2 = Y.shape[1]
    sig = jnp.linalg.svd(X, compute_uv=False)
    rho = sig[-1] ** 2 / jnp.sum(sig**2)
    opt, T_star = optimal_loss(X, Y)
    xt2 = jnp.sum((X @ T_star) ** 2)
    i = jnp.arange(iters + 1)
    return (1 - rho) ** (i * (k - d2)) * xt2 + opt


def dense_cce(
    key,
    X: jax.Array,
    Y: jax.Array,
    k: int,
    iters: int,
    *,
    smart_noise: bool = False,
    identity_prefix: bool = True,
) -> LSTrace:
    """Algorithm 1.  ``smart_noise`` uses the SVD-aligned G (Appendix B);
    ``identity_prefix=False`` restricts M to the form [I | M'] analysed in
    the proof ("half noise" in Figure 6) — the default optimizes M fully."""
    n, d1 = X.shape
    d2 = Y.shape[1]
    assert d1 > k > d2, (d1, k, d2)
    T = jnp.zeros((d1, d2), X.dtype)
    losses = [loss(X, T, Y)]
    if smart_noise:
        _, S, Vt = jnp.linalg.svd(X, full_matrices=False)
        VSinv = Vt.T / S[None, :]
    for i in range(iters):
        key, kg = jax.random.split(key)
        G = jax.random.normal(kg, (d1, k - d2), X.dtype)
        if smart_noise:
            G = VSinv @ jax.random.normal(kg, (VSinv.shape[1], k - d2), X.dtype)
        H = jnp.concatenate([T, G], axis=1)  # (d1, k)
        if identity_prefix:
            M = _solve_M(X @ H, Y)
        else:
            # M = [I | M'], only M' optimized (the proof's weaker move)
            Mp = _solve_M(X @ G, Y - X @ T)
            M = jnp.concatenate([jnp.eye(d2, dtype=X.dtype), Mp], axis=0)
        T = H @ M
        losses.append(loss(X, T, Y))
    return LSTrace(jnp.stack(losses), T)


def sparse_cce(
    key,
    X: jax.Array,
    Y: jax.Array,
    k: int,
    iters: int,
    *,
    kmeans_iters: int = 25,
) -> LSTrace:
    """Algorithm 2.  T is only ever stored factored: assignments (d1,) int
    plus centroids (k/2, d2), combined with a fresh count-sketch each round.
    """
    n, d1 = X.shape
    d2 = Y.shape[1]
    kc = k // 2  # rows given to the clustered part A
    ks = k - kc  # rows given to the count-sketch part C
    T = jnp.zeros((d1, d2), X.dtype)
    losses = [loss(X, T, Y)]
    for i in range(iters):
        key, k1, k2, k3 = jax.random.split(key, 4)
        # --- line 5: cluster the rows of the (implicit) T ---------------
        res = km.kmeans(k1, T, kc, niter=kmeans_iters)
        A_rows = res.assignments  # (d1,) int32 — the sparse A
        # --- line 6: fresh count-sketch C --------------------------------
        h = hashing.make_hash(k2, ks)
        s = hashing.make_sign_hash(k3)
        ids = jnp.arange(d1)
        C_rows = h(ids)
        C_signs = s(ids).astype(X.dtype)
        # --- line 7: solve for M on the sketched problem ----------------
        # X @ H where H = [A | C]:
        # X (n, d1) @ A (d1, kc): (XA)[:, j] = sum_{i: a_i = j} X[:, i]
        XA = jax.vmap(
            lambda xrow: jax.ops.segment_sum(xrow, A_rows, num_segments=kc)
        )(X)
        XC = jax.vmap(
            lambda xrow: jax.ops.segment_sum(xrow * C_signs, C_rows, num_segments=ks)
        )(X)
        XH = jnp.concatenate([XA, XC], axis=1)  # (n, k)
        M = _solve_M(XH, Y)  # (k, d2)
        # --- reconstruct T = H M without materializing H -----------------
        T = M[A_rows] + C_signs[:, None] * M[kc + C_rows]
        losses.append(loss(X, T, Y))
    return LSTrace(jnp.stack(losses), T)


def kmeans_factorize(key, T: jax.Array, k: int, ones_per_row: int = 1, niter: int = 50):
    """Post-hoc factorization T ~= H M via K-means (the comparison line in
    Figure 1b): 1 one per row = plain PQ on the whole row; 2 ones per row =
    residual step (cluster, then cluster the residuals)."""
    res = km.kmeans(key, T, k if ones_per_row == 1 else k // 2, niter=niter)
    if ones_per_row == 1:
        return res.centroids[res.assignments]
    resid = T - res.centroids[res.assignments]
    res2 = km.kmeans(jax.random.fold_in(key, 1), resid, k // 2, niter=niter)
    return res.centroids[res.assignments] + res2.centroids[res2.assignments]
