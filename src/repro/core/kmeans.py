"""K-means in JAX: kmeans++ init, Lloyd iterations, minibatch sampling,
and a distributed (data-parallel, psum) variant for pod-scale clustering.

This replaces the paper's FAISS dependency.  Following the paper's
reproducibility notes we default to ``niter=50`` and subsample to
``max_points_per_centroid=256`` points per centroid.

All entry points take optional per-point ``weights``: a weighted Lloyd
iteration on unique points is EXACTLY the unweighted iteration on the
multiset where point i appears weights[i] times (the transition feeds the
observed id histogram here instead of sampling with replacement — same
distribution, every observed id exactly once, no sampling variance).
``weights=None`` keeps the historical unweighted code path bit-for-bit
(including the kmeans++ seeding draws), so existing callers are unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, d)
    assignments: jax.Array  # (n,) int32
    inertia: jax.Array  # () sum of squared distances


def _sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, k) squared distances, MXU-friendly expansion."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    cn = jnp.sum(c * c, axis=-1)  # (k,)
    return xn + cn[None, :] - 2.0 * x @ c.T


def assign(x: jax.Array, c: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Nearest-centroid assignment.  ``use_kernel`` routes through the
    Pallas kmeans_assign kernel (interpret-mode on CPU)."""
    if use_kernel:
        return kops.kmeans_assign(x, c)
    return jnp.argmin(_sq_dists(x, c), axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def kmeans_plus_plus(key: jax.Array, x: jax.Array, k: int,
                     weights: jax.Array | None = None) -> jax.Array:
    """kmeans++ seeding (sequential, lax.fori_loop).  With ``weights`` the
    D² sampling distribution becomes w·D² (a weight-w point seeds exactly
    like w coincident unit-weight copies); without, the historical
    unweighted draws are reproduced bit-for-bit."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    if weights is None:
        first = x[jax.random.randint(k0, (), 0, n)]
    else:
        w = weights.astype(jnp.float32)
        first = x[jax.random.choice(k0, n, p=w / jnp.maximum(w.sum(), 1e-30))]
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    d2 = jnp.sum((x - first) ** 2, axis=-1)

    def body(i, carry):
        centroids, d2, key = carry
        key, kc = jax.random.split(key)
        score = d2 if weights is None else d2 * weights
        p = score / jnp.maximum(score.sum(), 1e-30)
        idx = jax.random.choice(kc, n, p=p)
        c = x[idx]
        centroids = centroids.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((x - c) ** 2, axis=-1))
        return centroids, d2, key

    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, d2, key))
    return centroids


def _lloyd_step(x, centroids, k, use_kernel: bool = False, weights=None):
    a = assign(x, centroids, use_kernel=use_kernel)
    onehot = jax.nn.one_hot(a, k, dtype=x.dtype)  # (n, k)
    if weights is None:
        counts = onehot.sum(axis=0)  # (k,)
        sums = onehot.T @ x  # (k, d)
    else:
        w = weights.astype(x.dtype)[:, None]  # (n, 1)
        counts = (onehot * w).sum(axis=0)
        sums = onehot.T @ (x * w)
    new_c = sums / jnp.maximum(counts[:, None], 1e-12 if weights is not None else 1.0)
    # keep empty clusters where they were
    new_c = jnp.where(counts[:, None] > 0, new_c, centroids)
    d2 = jnp.sum((x - new_c[a]) ** 2, axis=-1)
    inertia = jnp.sum(d2 if weights is None else d2 * weights)
    return new_c, a, inertia


@partial(jax.jit, static_argnames=("k", "niter", "use_kernel"))
def kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    niter: int = 50,
    use_kernel: bool = False,
    weights: jax.Array | None = None,
) -> KMeansResult:
    """Full-batch Lloyd's algorithm with kmeans++ init.  ``use_kernel``
    routes every per-iteration assignment through the Pallas kernel
    (worth it on TPU at clustering scale; interpret-mode on CPU is for
    validation only).  ``weights`` runs the count-weighted variant: the
    result equals unweighted k-means on the expanded multiset."""
    x = x.astype(jnp.float32)
    if weights is not None:
        weights = weights.astype(jnp.float32)
    centroids = kmeans_plus_plus(key, x, k, weights)

    def body(_, carry):
        c, _, _ = carry
        return _lloyd_step(x, c, k, use_kernel, weights)

    a0 = jnp.zeros((x.shape[0],), jnp.int32)
    centroids, a, inertia = jax.lax.fori_loop(
        0, niter, body, (centroids, a0, jnp.float32(0))
    )
    return KMeansResult(centroids, a, inertia)


def subsample(key: jax.Array, n: int, k: int, max_points_per_centroid: int = 256):
    """FAISS-style subsampling: train on at most 256*k points (paper §Repro)."""
    cap = max_points_per_centroid * k
    if n <= cap:
        return jnp.arange(n)
    return jax.random.choice(key, n, (cap,), replace=False)


# --- distributed k-means -----------------------------------------------------
# Each data-parallel shard holds a slice of the sample.  One Lloyd iteration:
# local assignment, local (sum, count) moments, psum over the data axis,
# identical centroid update on every shard.  Used by the pod-scale training
# loop; on 1 device it degenerates to the serial algorithm.


def distributed_lloyd_iter(x_local: jax.Array, centroids: jax.Array, k: int,
                           axis_name: str, use_kernel: bool = False,
                           weights=None):
    a = assign(x_local, centroids, use_kernel=use_kernel)
    onehot = jax.nn.one_hot(a, k, dtype=x_local.dtype)
    if weights is None:
        local_counts, local_sums = onehot.sum(axis=0), onehot.T @ x_local
    else:
        w = weights.astype(x_local.dtype)[:, None]
        local_counts, local_sums = (onehot * w).sum(axis=0), onehot.T @ (x_local * w)
    counts = jax.lax.psum(local_counts, axis_name)
    sums = jax.lax.psum(local_sums, axis_name)
    new_c = sums / jnp.maximum(counts[:, None], 1e-12 if weights is not None else 1.0)
    new_c = jnp.where(counts[:, None] > 0, new_c, centroids)
    return new_c, a


def distributed_kmeans(
    key: jax.Array,
    x_local: jax.Array,
    k: int,
    axis_name: str,
    niter: int = 50,
    use_kernel: bool = False,
    weights: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Run inside shard_map/pmap over ``axis_name``.  Seeds from the first
    shard's local sample (kmeans++ on local slice is a standard approximation).
    ``weights`` shards with the points (same leading axis)."""
    x_local = x_local.astype(jnp.float32)
    if weights is not None:
        weights = weights.astype(jnp.float32)
    centroids = kmeans_plus_plus(key, x_local, k, weights)
    # make the seed identical on all shards: average is wrong, so broadcast
    # shard 0's seed via pmean of (seed * is_shard0 * n_shards)
    idx = jax.lax.axis_index(axis_name)
    centroids = jax.lax.psum(
        jnp.where(idx == 0, centroids, jnp.zeros_like(centroids)), axis_name
    )

    def body(_, c):
        c, _ = distributed_lloyd_iter(x_local, c, k, axis_name, use_kernel,
                                      weights)
        return c

    centroids = jax.lax.fori_loop(0, niter, body, centroids)
    return centroids, assign(x_local, centroids, use_kernel=use_kernel)
