"""Every training-time table-compression method from the paper's Section 2,
in its unified sketching framework:  T = H @ M,  lookup(i) = (e_i H) M.

Each method is a frozen-config class with pure functional state:

    method.init(key)                  -> (params, buffers)
    method.lookup(params, buffers, i) -> (..., d2) embeddings
    method.logits(params, buffers, h) -> (..., d1) factored output head
    method.sketch_matrix(buffers)     -> dense H (d1, k) — tests only

``params`` are trainable pytrees; ``buffers`` are non-trainable (hash
coefficients, pointer arrays).  CCE itself lives in `core/cce.py` and
shares this interface plus a `cluster()` transition.

The factored ``logits`` head is a beyond-paper extension: for any linear
sketch, <h, T[v]> = <h, (e_v H) M> = (h M^T) H^T[v] — a k-sized matmul
plus a cheap integer gather, instead of a d1 x d2 matmul.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

Params = Any
Buffers = Any


class FuseSpec(NamedTuple):
    """A table's natural shape inside the universal supertable machinery
    (DESIGN.md §6): ``cols`` columns of ``n_tables`` stacked (k, dsub)
    sub-tables, looked up as ``sum_t tab[t][rows[:, t]]`` per column.

    Any method whose lookup is a per-column gather-sum exposes one
    (CCE: cols=c, n_tables=2; CEConcat: cols=c, n_tables=1; HashingTrick:
    1×1; FullTable: 1×1 with k=d1 and identity rows) and therefore fuses
    into a group supertable.  ``dsub`` is the NATURAL column width; a
    column always splits into ``s`` sub-columns of ``dsub/s`` sharing its
    row index, which is how tables with different natural widths share one
    launch (the collection picks the group gcd).  Methods whose lookup is
    not a gather-sum (robe/dhe/tt, hemb's shared-table multi-hash) have no
    spec and take the per-feature loop fallback.
    """

    cols: int
    n_tables: int
    k: int
    dsub: int


def _split_budget_rows(budget: int, d2: int, n_tables: int = 1) -> int:
    return max(1, budget // (d2 * n_tables))


@dataclasses.dataclass(frozen=True)
class FullTable:
    """The uncompressed baseline: one row per id."""

    d1: int
    d2: int
    dtype: Any = jnp.float32

    @property
    def n_params(self) -> int:
        return self.d1 * self.d2

    def init_buffers(self):
        return {}

    def init(self, key):
        scale = 1.0 / math.sqrt(self.d2)
        return {
            "table": (jax.random.normal(key, (self.d1, self.d2)) * scale).astype(self.dtype)
        }, {}

    def lookup(self, params, buffers, ids):
        return params["table"][ids]

    def logits(self, params, buffers, h):
        return h @ params["table"].T

    def sketch_matrix(self, buffers) -> np.ndarray:
        return np.eye(self.d1, dtype=np.float32)

    # --- collection grouping (DESIGN.md §3) ------------------------------

    def group_signature(self):
        """Full tables with the same output dim batch into one padded
        (F, max d1, d2) gather; vocab size is NOT in the signature — the
        collection sub-partitions groups whose d1 spread would make the
        padding expensive (see ``EmbeddingCollection.build``)."""
        return ("full", self.d2, str(jnp.dtype(self.dtype)))

    @staticmethod
    def stack_many(tables, params_seq):
        """Per-feature {"table": (d1_f, d2)} -> {"table": (F, max d1_f, d2)},
        zero-padding the row axis.  Padded rows are unreachable (ids are
        < d1_f) and so stay exactly zero under training."""
        d1_pad = max(t.d1 for t in tables)
        return {
            "table": jnp.stack(
                [
                    jnp.pad(p["table"], ((0, d1_pad - t.d1), (0, 0)))
                    for t, p in zip(tables, params_seq)
                ]
            )
        }

    @staticmethod
    def unstack_many(tables, group_params):
        return [
            {"table": group_params["table"][f, : t.d1]}
            for f, t in enumerate(tables)
        ]

    @staticmethod
    def lookup_many(tables, group_params, buffers_seq, ids):
        """ONE padded gather for the whole group: ids (B, F) into the
        stacked (F, d1_pad, d2) table -> (B, F, d2).  Ids clamp to each
        feature's own vocab — matching the per-table gather's out-of-range
        semantics (XLA clamps), and keeping an out-of-range id from
        reaching (and training) another feature's padding rows."""
        F = len(tables)
        caps = jnp.asarray([t.d1 - 1 for t in tables], ids.dtype)  # (F,)
        return group_params["table"][
            jnp.arange(F)[None, :], jnp.minimum(ids, caps[None, :])
        ]

    # --- universal fusion (DESIGN.md §6) ---------------------------------

    @property
    def fuse_spec(self) -> FuseSpec:
        """One column whose codebook IS the table (identity rows): the
        gather becomes a one-hot matmul over d1 rows, which is only worth
        fusing for small tables — the collection's waste bound
        (``UNIV_PAD_WASTE``) splits big full tables off, and full-only
        buckets keep the padded batched gather."""
        return FuseSpec(cols=1, n_tables=1, k=self.d1, dsub=self.d2)

    def fuse_slab(self, params):
        return params["table"][None, None]  # (1, 1, d1, d2)

    def unfuse_slab(self, slab):
        return {"table": slab[0, 0]}

    def fuse_rows(self, buffers, ids):
        # clamp to the real vocab (per-table XLA gather semantics); the
        # supertable's padding rows stay unreachable
        return jnp.clip(ids, 0, self.d1 - 1).astype(jnp.int32)[None, :, None]

    def fuse_rows_np(self, buffers, ids):
        return np.clip(np.asarray(ids), 0, self.d1 - 1).astype(np.int32)[
            None, :, None
        ]


@dataclasses.dataclass(frozen=True)
class HashingTrick:
    """Weinberger et al. 2009 — one hash, k rows shared across the vocab."""

    d1: int
    d2: int
    k: int
    seed_salt: int = 0
    dtype: Any = jnp.float32

    @classmethod
    def from_budget(cls, d1, d2, budget, **kw):
        return cls(d1, d2, k=min(d1, _split_budget_rows(budget, d2)), **kw)

    @property
    def n_params(self) -> int:
        return self.k * self.d2

    def init_buffers(self):
        """Device-free (numpy/int) buffer init — hash coefficients derive
        from ``seed_salt`` so abstract (eval_shape) and real inits agree."""
        h = hashing.make_hash(self.seed_salt * 7919 + 11, self.k)
        return {"h": (h.a, h.b)}

    def init(self, key):
        km = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2)
        M = (jax.random.normal(km, (self.k, self.d2)) * scale).astype(self.dtype)
        return {"M": M}, self.init_buffers()

    def _rows(self, buffers, ids):
        a, b = buffers["h"]
        return hashing.MultiplyShiftHash(int(a), int(b), self.k)(ids)

    def lookup(self, params, buffers, ids):
        return params["M"][self._rows(buffers, ids)]

    def logits(self, params, buffers, h):
        scores = h @ params["M"].T  # (..., k)
        rows = self._rows(buffers, jnp.arange(self.d1))
        return scores[..., rows]

    def sketch_matrix(self, buffers) -> np.ndarray:
        rows = np.asarray(self._rows(buffers, jnp.arange(self.d1)))
        H = np.zeros((self.d1, self.k), np.float32)
        H[np.arange(self.d1), rows] = 1.0
        return H

    # --- universal fusion (DESIGN.md §6) ---------------------------------

    @property
    def fuse_spec(self) -> FuseSpec:
        """One hash, one table: the QREmbeddingBag T=1 case — the hashed
        gather is a one-hot matmul over the k shared rows."""
        return FuseSpec(cols=1, n_tables=1, k=self.k, dsub=self.d2)

    def fuse_slab(self, params):
        return params["M"][None, None]  # (1, 1, k, d2)

    def unfuse_slab(self, slab):
        return {"M": slab[0, 0]}

    def fuse_rows(self, buffers, ids):
        return self._rows(buffers, ids)[None, :, None]  # (1, B, 1)

    def fuse_rows_np(self, buffers, ids):
        a, b = buffers["h"]
        return hashing.multiply_shift_np(np.asarray(ids), a, b, self.k)[
            None, :, None
        ]


@dataclasses.dataclass(frozen=True)
class HashEmbedding:
    """Tito Svenstrup et al. 2017 — sum of ``n_hash`` rows (H has n_hash 1s/row)."""

    d1: int
    d2: int
    k: int
    n_hash: int = 2
    seed_salt: int = 0
    dtype: Any = jnp.float32

    @classmethod
    def from_budget(cls, d1, d2, budget, **kw):
        return cls(d1, d2, k=min(d1, _split_budget_rows(budget, d2)), **kw)

    @property
    def n_params(self) -> int:
        return self.k * self.d2

    def init_buffers(self):
        hs = hashing.make_hashes(self.seed_salt * 7919 + 22, self.n_hash, self.k)
        return {"hs": tuple((h.a, h.b) for h in hs)}

    def init(self, key):
        km = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2 * self.n_hash)
        M = (jax.random.normal(km, (self.k, self.d2)) * scale).astype(self.dtype)
        return {"M": M}, self.init_buffers()

    def _rows(self, buffers, ids):
        return jnp.stack(
            [
                hashing.MultiplyShiftHash(int(a), int(b), self.k)(ids)
                for (a, b) in buffers["hs"]
            ],
            axis=-1,
        )  # (..., n_hash)

    def lookup(self, params, buffers, ids):
        rows = self._rows(buffers, ids)
        return params["M"][rows].sum(axis=-2)

    def logits(self, params, buffers, h):
        scores = h @ params["M"].T
        rows = self._rows(buffers, jnp.arange(self.d1))  # (d1, n_hash)
        return sum(scores[..., rows[:, j]] for j in range(self.n_hash))

    def sketch_matrix(self, buffers) -> np.ndarray:
        rows = np.asarray(self._rows(buffers, jnp.arange(self.d1)))
        H = np.zeros((self.d1, self.k), np.float32)
        for j in range(self.n_hash):
            H[np.arange(self.d1), rows[:, j]] += 1.0
        return H


@dataclasses.dataclass(frozen=True)
class CEConcat:
    """Shi et al. 2020 compositional embeddings, hashed variant with
    concatenation: c tables of (k, d2/c); block-diagonal M."""

    d1: int
    d2: int
    k: int
    c: int = 4
    seed_salt: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert self.d2 % self.c == 0, (self.d2, self.c)

    @classmethod
    def from_budget(cls, d1, d2, budget, c=4, **kw):
        return cls(d1, d2, k=min(d1, _split_budget_rows(budget, d2)), c=c, **kw)

    @property
    def dsub(self) -> int:
        return self.d2 // self.c

    @property
    def n_params(self) -> int:
        return self.k * self.d2

    def init_buffers(self):
        hs = hashing.make_hashes(self.seed_salt * 7919 + 33, self.c, self.k)
        return {"hs": tuple((h.a, h.b) for h in hs)}

    def init(self, key):
        km = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2)
        tables = (
            jax.random.normal(km, (self.c, self.k, self.dsub)) * scale
        ).astype(self.dtype)
        return {"tables": tables}, self.init_buffers()

    def _rows(self, buffers, ids):
        return jnp.stack(
            [
                hashing.MultiplyShiftHash(int(a), int(b), self.k)(ids)
                for (a, b) in buffers["hs"]
            ],
            axis=0,
        )  # (c, ...)

    def lookup(self, params, buffers, ids):
        rows = self._rows(buffers, ids)  # (c, ...)
        pieces = jax.vmap(lambda tab, r: tab[r])(params["tables"], rows)
        return jnp.moveaxis(pieces, 0, -2).reshape(*ids.shape, self.d2)

    def logits(self, params, buffers, h):
        hc = h.reshape(*h.shape[:-1], self.c, self.dsub)
        rows = self._rows(buffers, jnp.arange(self.d1))  # (c, d1)
        out = 0.0
        for i in range(self.c):
            scores = hc[..., i, :] @ params["tables"][i].T  # (..., k)
            out = out + scores[..., rows[i]]
        return out

    def sketch_matrix(self, buffers) -> np.ndarray:
        """H (d1, c*k) against block-diagonal M."""
        rows = np.asarray(self._rows(buffers, jnp.arange(self.d1)))
        H = np.zeros((self.d1, self.c * self.k), np.float32)
        for i in range(self.c):
            H[np.arange(self.d1), i * self.k + rows[i]] = 1.0
        return H

    # --- universal fusion (DESIGN.md §6) ---------------------------------

    @property
    def fuse_spec(self) -> FuseSpec:
        """c hashed columns, one table each — CCE's shape minus the
        learned pointer and the helper table (T=1)."""
        return FuseSpec(cols=self.c, n_tables=1, k=self.k, dsub=self.dsub)

    def fuse_slab(self, params):
        return params["tables"][:, None]  # (c, 1, k, dsub)

    def unfuse_slab(self, slab):
        return {"tables": slab[:, 0]}

    def fuse_rows(self, buffers, ids):
        return self._rows(buffers, ids)[..., None]  # (c, B, 1)

    def fuse_rows_np(self, buffers, ids):
        ids = np.asarray(ids)
        return np.stack(
            [
                hashing.multiply_shift_np(ids, a, b, self.k)
                for (a, b) in buffers["hs"]
            ]
        )[..., None]


@dataclasses.dataclass(frozen=True)
class ROBE:
    """Desai et al. 2022 — chunks read from one flat array with wrap-around."""

    d1: int
    d2: int
    m: int  # flat array length
    c: int = 4
    seed_salt: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert self.d2 % self.c == 0

    @classmethod
    def from_budget(cls, d1, d2, budget, c=4, **kw):
        return cls(d1, d2, m=max(d2, min(d1 * d2, budget)), c=c, **kw)

    @property
    def dsub(self) -> int:
        return self.d2 // self.c

    @property
    def n_params(self) -> int:
        return self.m

    def init_buffers(self):
        hs = hashing.make_hashes(self.seed_salt * 7919 + 44, self.c, self.m)
        return {"hs": tuple((h.a, h.b) for h in hs)}

    def init(self, key):
        km = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2)
        flat = (jax.random.normal(km, (self.m,)) * scale).astype(self.dtype)
        return {"flat": flat}, self.init_buffers()

    def lookup(self, params, buffers, ids):
        pieces = []
        offs = jnp.arange(self.dsub)
        for a, b in buffers["hs"]:
            start = hashing.MultiplyShiftHash(int(a), int(b), self.m)(ids)
            idx = (start[..., None] + offs) % self.m
            pieces.append(params["flat"][idx])
        return jnp.concatenate(pieces, axis=-1)

    def logits(self, params, buffers, h):
        # no small-matmul factorization (chunks overlap arbitrarily); chunked
        # materialization keeps memory bounded.
        return _chunked_logits(self, params, buffers, h)

    def sketch_matrix(self, buffers) -> np.ndarray:
        raise NotImplementedError("ROBE's H is structured over chunks; see tests")


def _chunked_logits(method, params, buffers, h, chunk: int = 8192):
    """Default output head: materialize vocab embeddings in chunks."""
    d1 = method.d1
    outs = []
    for s in range(0, d1, chunk):
        ids = jnp.arange(s, min(s + chunk, d1))
        emb = method.lookup(params, buffers, ids)  # (chunk, d2)
        outs.append(h @ emb.T)
    return jnp.concatenate(outs, axis=-1)


@dataclasses.dataclass(frozen=True)
class DHE:
    """Kang et al. 2021 Deep Hash Embeddings: n_hash pseudo-random features
    in [-1,1] -> MLP with Mish.  Paper repro note: 2 hidden layers, width =
    n_hash, solved from the parameter budget."""

    d1: int
    d2: int
    width: int
    n_hash: int
    seed_salt: int = 0
    dtype: Any = jnp.float32

    @classmethod
    def from_budget(cls, d1, d2, budget, **kw):
        # params ~= w*w + w*w + w*d2  (2 hidden layers of width w)
        w = int((-d2 + math.sqrt(d2 * d2 + 8 * budget)) / 4)
        w = max(8, w)
        return cls(d1, d2, width=w, n_hash=w, **kw)

    @property
    def n_params(self) -> int:
        w = self.width
        return w * w + w * w + w * self.d2 + 2 * w + self.d2

    def init_buffers(self):
        rng = np.random.default_rng(self.seed_salt * 7919 + 55)
        a = (rng.integers(0, 2**31 - 1, self.n_hash, dtype=np.int32) * 2 + 1).astype(np.int32)
        b = rng.integers(0, 2**31 - 1, self.n_hash, dtype=np.int32)
        return {"a": a, "b": b}

    def init(self, key):
        key = jax.random.fold_in(key, self.seed_salt)
        _, k1, k2, k3 = jax.random.split(key, 4)
        w = self.width
        params = {
            "w1": jax.random.normal(k1, (self.n_hash, w)) * (1 / math.sqrt(self.n_hash)),
            "b1": jnp.zeros((w,)),
            "w2": jax.random.normal(k2, (w, w)) * (1 / math.sqrt(w)),
            "b2": jnp.zeros((w,)),
            "w3": jax.random.normal(k3, (w, self.d2)) * (1 / math.sqrt(w)),
            "b3": jnp.zeros((self.d2,)),
        }
        params = jax.tree.map(lambda x: x.astype(self.dtype), params)
        return params, self.init_buffers()

    def _features(self, buffers, ids):
        x = ids.astype(jnp.uint32)[..., None]
        h = x * buffers["a"].astype(jnp.uint32) + buffers["b"].astype(jnp.uint32)
        h = (h ^ (h >> 15)) * jnp.uint32(2654435761)
        h = h ^ (h >> 13)
        return (h.astype(jnp.float32) / jnp.float32(2**31) - 1.0).astype(self.dtype)

    def lookup(self, params, buffers, ids):
        x = self._features(buffers, ids)
        def mish(v):
            return v * jnp.tanh(jax.nn.softplus(v))

        x = mish(x @ params["w1"] + params["b1"])
        x = mish(x @ params["w2"] + params["b2"])
        return x @ params["w3"] + params["b3"]

    def logits(self, params, buffers, h):
        return _chunked_logits(self, params, buffers, h)


@dataclasses.dataclass(frozen=True)
class TensorTrain:
    """Yin et al. 2021 TT-Rec, 3-core tensor-train factorization."""

    d1: int
    d2: int
    rank: int
    seed_salt: int = 0
    dtype: Any = jnp.float32

    @classmethod
    def from_budget(cls, d1, d2, budget, **kw):
        q = cls._factor3(d1)
        p = cls._factor3(d2)
        # params(r) = q1*p1*r + q2*p2*r^2 + q3*p3*r
        a = q[1] * p[1]
        b = q[0] * p[0] + q[2] * p[2]
        r = int((-b + math.sqrt(b * b + 4 * a * budget)) / (2 * a))
        return cls(d1, d2, rank=max(1, r), **kw)

    @staticmethod
    def _factor3(n: int) -> tuple[int, int, int]:
        """q1*q2*q3 >= n with qi ~ n^(1/3)."""
        q = int(math.ceil(n ** (1 / 3)))
        q1 = q
        q2 = q
        q3 = int(math.ceil(n / (q1 * q2)))
        return (q1, q2, q3)

    @property
    def qs(self):
        return self._factor3(self.d1)

    @property
    def ps(self):
        # exact factorization of d2 into 3 factors (d2 is a model dim,
        # typically highly composite)
        d2 = self.d2
        p1 = _largest_divisor_leq(d2, round(d2 ** (1 / 3)))
        rest = d2 // p1
        p2 = _largest_divisor_leq(rest, round(math.sqrt(rest)))
        return (p1, p2, rest // p2)

    @property
    def n_params(self) -> int:
        q, p, r = self.qs, self.ps, self.rank
        return q[0] * p[0] * r + r * q[1] * p[1] * r + r * q[2] * p[2]

    def init(self, key):
        key = jax.random.fold_in(key, self.seed_salt)
        q, p, r = self.qs, self.ps, self.rank
        k1, k2, k3 = jax.random.split(key, 3)
        s = (1.0 / math.sqrt(self.d2)) ** (1 / 3)
        params = {
            "g1": jax.random.normal(k1, (q[0], p[0], r)) * s,
            "g2": jax.random.normal(k2, (q[1], r, p[1], r)) * s,
            "g3": jax.random.normal(k3, (q[2], r, p[2])) * s,
        }
        params = jax.tree.map(lambda x: x.astype(self.dtype), params)
        return params, self.init_buffers()

    def init_buffers(self):
        return {}

    def lookup(self, params, buffers, ids):
        q, p = self.qs, self.ps
        i1 = ids // (q[1] * q[2])
        i2 = (ids // q[2]) % q[1]
        i3 = ids % q[2]
        g1 = params["g1"][i1]  # (..., p1, r)
        g2 = params["g2"][i2]  # (..., r, p2, r)
        g3 = params["g3"][i3]  # (..., r, p3)
        x = jnp.einsum("...ar,...rbs->...abs", g1, g2)  # (..., p1, p2, r)
        x = jnp.einsum("...abs,...sc->...abc", x, g3)  # (..., p1, p2, p3)
        return x.reshape(*ids.shape, self.d2)

    def logits(self, params, buffers, h):
        return _chunked_logits(self, params, buffers, h)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


METHODS = {
    "full": FullTable,
    "hash": HashingTrick,
    "hemb": HashEmbedding,
    "ce": CEConcat,
    "robe": ROBE,
    "dhe": DHE,
    "tt": TensorTrain,
}


def lookup_many_loop(tables, params_seq, buffers_seq, ids):
    """Fallback batched-lookup protocol: any method without a fused
    ``lookup_many`` loops feature-by-feature.  ids (B, F) -> (B, F, d2)."""
    return jnp.stack(
        [
            t.lookup(params_seq[f], buffers_seq[f], ids[:, f])
            for f, t in enumerate(tables)
        ],
        axis=1,
    )


def make_table(method: str, d1: int, d2: int, budget: int | None = None, **kw):
    """Factory: budget-driven construction of any method (incl. 'cce')."""
    if method == "cce":
        from repro.core.cce import CCE

        return CCE.from_budget(d1, d2, budget, **kw)
    if method == "full":
        kw.pop("c", None)
        return FullTable(d1, d2, **kw)
    cls = METHODS[method]
    if method in ("hash", "hemb", "dhe", "tt"):
        kw.pop("c", None)
    return cls.from_budget(d1, d2, budget, **kw)
