"""Universal hashing and count-sketch utilities (Appendix D of the paper).

Multiply-shift hashing (Dietzfelbinger et al., 1997): h(x) = (a*x + b) >> s,
computed in uint32/uint64 arithmetic so a hash function is two integers —
"very cheap to store" per the paper.  All functions are pure jnp and
vectorize over id arrays, so they run on device or host.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# 64-bit multiply-shift needs uint64; enable x64 ops locally via astype —
# jax defaults to 32-bit, so we build the hash out of 32-bit multiplies.

_MERSENNE = np.uint32(2654435761)  # Knuth's multiplicative constant


@dataclasses.dataclass(frozen=True)
class MultiplyShiftHash:
    """h : [d1] -> [m].  Stored as (a, b) uint32 pairs; odd `a`."""

    a: int
    b: int
    m: int  # range

    def __call__(self, ids: jax.Array) -> jax.Array:
        # delegates to the array-coefficient pipeline so the static and
        # dynamic hs representations stay bit-exact by construction
        return multiply_shift(ids, jnp.uint32(self.a), jnp.uint32(self.b), self.m)

    def np(self, ids: np.ndarray) -> np.ndarray:
        """Pure-numpy twin (bit-exact with __call__) — host-side pointer
        translation and device-free buffer init."""
        return multiply_shift_np(ids, self.a, self.b, self.m)


def multiply_shift(ids, a, b, m: int):
    """THE jnp multiply-shift pipeline — ``MultiplyShiftHash.__call__``
    delegates here, and ``.np`` is its bit-exact numpy twin.  ``a``/``b``
    may be traced uint32 arrays (hash coefficients that ride the train
    state so the clustering transition can refresh them without
    re-jitting), broadcast against ``ids``."""
    x = jnp.asarray(ids).astype(jnp.uint32)
    h = x * jnp.asarray(a).astype(jnp.uint32) + jnp.asarray(b).astype(jnp.uint32)
    # fibonacci-style mix then reduce to range; m need not be a power of 2.
    # modulo bias is O(m / 2^32) — irrelevant at these table sizes, and it
    # avoids uint64 (not available without x64).
    h = (h ^ (h >> 15)) * _MERSENNE
    h = h ^ (h >> 13)
    return (h % jnp.uint32(m)).astype(jnp.int32)


def multiply_shift_np(ids, a, b, m: int) -> np.ndarray:
    """Bit-exact numpy twin of ``multiply_shift`` — the host-side pointer
    translation stage (DESIGN.md §4/§6) hashes with this so host-computed
    rows equal device-computed rows bit for bit.  ``a``/``b`` are scalars
    or arrays broadcast against ``ids`` (e.g. a packed (c, 2) ``hs``
    buffer's columns)."""
    with np.errstate(over="ignore"):
        x = np.asarray(ids).astype(np.uint32)
        h = x * np.asarray(a).astype(np.uint32) + np.asarray(b).astype(np.uint32)
        h = (h ^ (h >> np.uint32(15))) * _MERSENNE
        h = h ^ (h >> np.uint32(13))
        return (h % np.uint32(m)).astype(np.int32)


def pack_hashes(hashes) -> np.ndarray:
    """(n, 2) uint32 coefficient array from MultiplyShiftHash list — the
    dynamic-buffer representation (arrays ride TrainState.ebuf; python-int
    tuples would be closed over statically and go stale after cluster())."""
    return np.asarray([[h.a, h.b] for h in hashes], np.uint32)


@dataclasses.dataclass(frozen=True)
class SignHash:
    """s : [d1] -> {-1, +1} for count-sketch."""

    a: int
    b: int

    def __call__(self, ids: jax.Array) -> jax.Array:
        x = ids.astype(jnp.uint32)
        h = x * jnp.uint32(self.a) + jnp.uint32(self.b)
        h = (h ^ (h >> 16)) * _MERSENNE
        return jnp.where((h >> jnp.uint32(31)) > 0, 1, -1).astype(jnp.int32)


def _seed_of(key) -> int:
    """Derive a python-int seed from a PRNG key.  Abstract-safe: under
    eval_shape/jit tracing the coefficients fall back to a fixed seed —
    hash ints are static metadata and never appear in abstract shapes, so
    this only ever matters for the (concrete) real init path."""
    try:
        data = np.asarray(key)
    except Exception:
        try:
            data = np.asarray(jax.random.key_data(key))
        except Exception:  # tracer — fixed fallback
            return 0x5EED
    return int(data.astype(np.uint64).sum())  # audit: allow-int-cast (host np)


def make_hash(key, m: int) -> MultiplyShiftHash:
    """Sample a multiply-shift hash with range ``m``.  ``key`` may be a PRNG
    key (concrete or abstract) or a python int seed."""
    seed = key if isinstance(key, int) else _seed_of(key)
    rng = np.random.default_rng(seed)
    # 31-bit coefficients: eval_shape must be able to type returned ints
    # as int32; the LSB mask keeps `a` odd (multiply-shift requirement).
    a = (int(rng.integers(0, 2**31 - 1)) * 2 + 1) & 0x7FFFFFFF
    b = int(rng.integers(0, 2**31 - 1)) & 0x7FFFFFFF
    return MultiplyShiftHash(a=a, b=b, m=m)


def make_sign_hash(key) -> SignHash:
    seed = key if isinstance(key, int) else _seed_of(key)
    rng = np.random.default_rng(seed ^ 0xABCDEF)
    a = (int(rng.integers(0, 2**31 - 1)) * 2 + 1) & 0x7FFFFFFF
    b = int(rng.integers(0, 2**31 - 1)) & 0x7FFFFFFF
    return SignHash(a=a, b=b)


def make_hashes(key, n: int, m: int) -> list[MultiplyShiftHash]:
    seed = key if isinstance(key, int) else _seed_of(key)
    return [make_hash(seed * 1_000_003 + i, m) for i in range(n)]


# --- count-sketch as an explicit (sparse) linear map ------------------------


def countsketch_matrix(key: jax.Array, d1: int, k: int, signed: bool = True) -> np.ndarray:
    """Materialize the d1 x k count-sketch matrix H (for tests / tiny d1).

    H[j, h(j)] = s(j); one nonzero per row (Charikar et al. 2002).
    """
    kh, ks = jax.random.split(key)
    h = make_hash(kh, k)
    s = make_sign_hash(ks)
    ids = jnp.arange(d1)
    rows = np.asarray(h(ids))
    signs = np.asarray(s(ids)) if signed else np.ones(d1, np.int32)
    H = np.zeros((d1, k), np.float32)
    H[np.arange(d1), rows] = signs
    return H


@partial(jax.jit, static_argnums=(2,))
def apply_countsketch(x: jax.Array, hs: tuple[int, int, int, int], k: int) -> jax.Array:
    """Sketch a batch of one-hot-ish sparse vectors given by integer ids.

    For CCE we only ever sketch basis vectors e_i, so the sketch of ``ids``
    is just (row, sign) pairs; this helper returns the dense k-vector sum
    for testing norm-preservation properties.
    """
    a, b, sa, sb = hs
    h = MultiplyShiftHash(a, b, k)
    s = SignHash(sa, sb)
    rows = h(x)
    signs = s(x).astype(jnp.float32)
    return jax.ops.segment_sum(signs, rows, num_segments=k)
