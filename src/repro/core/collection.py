"""EmbeddingCollection — grouped supertables for multi-feature models.

The paper's hot loop is ``concat_i M_i[h_i(id)] + M'_i[h'_i(id)]`` per
categorical feature; DLRM has 26 of them.  Issuing 26 independent gathers
per step wastes the fused one-hot-matmul kernel (``kernels/cce_lookup``)
and launches O(n_features) ops where O(1) suffices — the
``QREmbeddingBag`` lesson from Shi et al. 2020, and the precondition CAFE
(Zhang et al. 2023) names for adaptive per-feature compression to pay off.

The collection groups a model's tables by fuse compatibility and stacks
each group's parameters (DESIGN.md §3/§6):

  * UNIVERSAL groups — every method whose lookup is a per-column
    gather-sum (``table.fuse_spec``: CCE, CEConcat, HashingTrick, and
    small FullTables) stacks into ONE supertable
    (total cols, T, max k_f, dsub) and runs as ONE ``kops.cce_lookup``
    launch per step, forward AND backward.  Tables with different natural
    column widths split into sub-columns of the group gcd; tables with
    fewer than T sub-tables pad their row tensor with the ``-1`` sentinel
    (a sentinel row matches no one-hot lane: exactly-zero forward
    contribution and exactly-zero gradient).  On the compressed Criteo
    DLRM config every table joins one universal group — the whole
    embedding stack is a single heavy launch.
  * Full groups with equal (d2, dtype) — big uncompressed tables (gated
    out of universal fusion: their one-hot matmul would be O(d1) wide)
    batch into ONE padded (F, max d1, d2) gather, sub-partitioned when
    the d1 spread would make padding cost more than the fusion saves.
  * Everything else (hemb/robe/dhe/tt) falls back to a per-feature loop.

State layout (the "grouped layout", DESIGN.md §3):

    params["emb"]  : [group_params, ...]       one entry per group
    buffers["emb"] : [[feat_buffers, ...], ...]  per group, per feature

Buffers are NEVER stacked — pointer arrays have per-feature vocabularies
and stay exactly as the per-feature methods wrote them, so every CCE
method (cluster, remap_moments, materialize) applies unchanged to a
feature's slice.  ``stack_params``/``unstack_params`` convert between the
grouped layout and the legacy per-feature layout (used by the checkpoint
migration: pre-collection checkpoints restore bit-exact, see
``legacy_layout_migration``).  Stacking is value-preserving by
construction: sub-column splits are reshapes, T/codebook padding is
zeros, and padded/sentinel regions receive exactly-zero gradient so they
STAY zero under training.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embeddings as emb_lib
from repro.core.cce import CCE

#: Sub-partition a "full" group when padding every table to the group max
#: would blow past this multiple of the smallest table in the bucket —
#: bounds the padded-parameter waste at ~FULL_PAD_RATIO per bucket while a
#: budget-capped config (all small tables) still lands in one gather.
FULL_PAD_RATIO = 8

#: Universal groups pad every member's codebook axis to the group max k
#: (and its sub-table axis to the group max T), so the supertable must not
#: cost more than this multiple of the members' NATURAL parameter count —
#: otherwise one large-k member would inflate every other member's slab
#: (params, optimizer moments, AND per-column one-hot work all scale with
#: k_pad).  Buckets greedily split (largest k first) to stay inside the
#: bound; a split-off all-full bucket reverts to the padded gather.  The
#: compressed Criteo config sits well inside the bound (~1.8x) and stays
#: ONE launch.
UNIV_PAD_WASTE = 3.5

#: The aggregate bound alone would let a dominant huge-k member carry a
#: tiny member to astronomical PER-MEMBER inflation (an 8-row table padded
#: to a 100k-row codebook is megabytes of dead params and 100k-row one-hot
#: work per lookup, yet barely moves the bucket total).  So each member's
#: padded slab must ALSO stay within UNIV_PAD_WASTE of its own natural
#: size — unless the padded slab is small in ABSOLUTE terms (below this
#: many elements), where relative inflation is irrelevant: Criteo's d1=3
#: full table padded to the CCE codebook costs kilobytes and one launch
#: saved is worth far more.
UNIV_PAD_SLACK_ELEMS = 1 << 20


@dataclasses.dataclass(frozen=True)
class TableGroup:
    kind: str  # "univ" | "full" | "loop"
    features: tuple[int, ...]  # global feature indices, ascending
    tables: tuple[Any, ...]  # the features' method objects, same order
    # universal groups only: the shared sub-column width (gcd of member
    # natural dsubs) and stacked-table count (max member n_tables)
    dsub: int | None = None
    n_tables: int | None = None
    #: round the codebook axis up to a multiple of this — the model-shard
    #: count must divide k_pad so the slab splits evenly across devices.
    #: Extra rows are zero, unreachable (every row index < the natural
    #: k), and therefore zero-grad: they stay zero under training, so a
    #: k_multiple=1 and a k_multiple=M layout are bit-interconvertible
    #: (``grouped_layout_migration``).
    k_multiple: int = 1

    @functools.cached_property
    def col_counts(self) -> tuple[int, ...]:
        """Supertable columns per feature (natural cols × dsub split)."""
        return tuple(
            t.fuse_spec.cols * (t.fuse_spec.dsub // self.dsub)
            for t in self.tables
        )

    @property
    def n_cols(self) -> int:
        return sum(self.col_counts)

    @property
    def k_pad(self) -> int:
        k = max(t.fuse_spec.k for t in self.tables)
        return -(-k // self.k_multiple) * self.k_multiple


# --- universal-slab plumbing (shared by device + host paths) ----------------


def _split_slab(nat, dsub: int, n_tables: int):
    """Natural (c, T, k, d) slab -> group layout (c*s, T_g, k, dsub):
    each column splits into s = d/dsub sub-columns (a pure reshape —
    sub-column j of column i holds rows' [j*dsub:(j+1)*dsub] slice, so
    concatenating sub-column outputs reconstructs the original d2
    layout), then missing sub-tables zero-pad the T axis (their rows are
    the -1 sentinel: unreachable, zero-grad, stays zero)."""
    c, T, k, d = nat.shape
    s = d // dsub
    x = nat.reshape(c, T, k, s, dsub)
    x = jnp.moveaxis(x, 3, 1).reshape(c * s, T, k, dsub)
    if T < n_tables:
        x = jnp.pad(x, ((0, 0), (0, n_tables - T), (0, 0), (0, 0)))
    return x


def _merge_slab(slab, spec: emb_lib.FuseSpec, dsub: int):
    """Inverse of ``_split_slab`` (slab already sliced to the feature's
    k): drop T padding, re-interleave sub-columns."""
    s = spec.dsub // dsub
    x = slab[:, : spec.n_tables]
    x = x.reshape(spec.cols, s, spec.n_tables, x.shape[2], dsub)
    x = jnp.moveaxis(x, 1, 3).reshape(spec.cols, spec.n_tables, x.shape[3], spec.dsub)
    return x


def _expand_rows(rows, s: int, n_tables: int, xp):
    """Natural (c, B, T) rows -> group (c*s, B, T_g): sub-columns share
    their parent column's rows; padded T slots get the -1 sentinel.
    ``xp`` is numpy (host translation) or jnp (device) — bit-identical."""
    if s > 1:
        rows = xp.repeat(rows, s, axis=0)
    T = rows.shape[-1]
    if T < n_tables:
        pad = xp.full(rows.shape[:-1] + (n_tables - T,), -1, np.int32)
        rows = xp.concatenate([rows, pad.astype(rows.dtype)], axis=-1)
    return rows


def bucket_rows(rows, k_loc: int, n_shards: int, xp):
    """Route global row indices to their owning model shard.

    ``rows`` int32 with the -1 no-op sentinel, any shape; shard ``s``
    owns the contiguous codebook slice ``[s*k_loc, (s+1)*k_loc)``.
    Returns a stacked (n_shards, *rows.shape) tensor where bucket ``s``
    holds shard-LOCAL indices for the ids it owns and the -1 sentinel
    everywhere else — each global row appears in exactly one bucket, so
    summing the buckets' lookups reproduces the unsharded lookup
    exactly.  ``xp`` is numpy (host translation) or jnp (in-step device
    bucketing) — bit-identical, same twin pattern as ``_expand_rows``.
    """
    owner = rows // k_loc
    return xp.stack(
        [
            xp.where((rows >= 0) & (owner == s), rows - s * k_loc, -1)
            for s in range(n_shards)
        ],
        axis=0,
    ).astype(np.int32)


def _gcd_all(vals) -> int:
    return functools.reduce(math.gcd, vals)


@dataclasses.dataclass(frozen=True)
class EmbeddingCollection:
    tables: tuple[Any, ...]
    groups: tuple[TableGroup, ...]

    # --- construction ----------------------------------------------------

    @classmethod
    def build(cls, tables: Sequence[Any], mode: str = "univ",
              k_multiple: int = 1) -> "EmbeddingCollection":
        """``mode``:
        * "univ" (default) — universal fusion: every gather-sum table
          (``fuse_spec``) joins one supertable per dtype; ONE launch for
          the whole embedding stack on the Criteo config.
        * "group" — the pre-universal grouping (per-signature CCE groups
          + padded full-gather buckets); kept as the benchmark baseline.
        * "loop" — one loop group per feature (the pre-collection hot
          loop); benchmark baseline only.

        ``k_multiple`` rounds every universal group's ``k_pad`` up so a
        model mesh axis of that size divides the slab evenly (sharded
        configs set it to the shard count; layouts with different
        ``k_multiple`` stay bit-interconvertible, see ``TableGroup``).
        Historical "group"/"loop" layouts ignore it by construction.
        """
        tables = tuple(tables)
        if mode == "loop":
            groups = tuple(
                TableGroup("loop", (i,), (t,)) for i, t in enumerate(tables)
            )
            return cls(tables, groups)
        if mode not in ("univ", "group"):
            raise ValueError(f"unknown collection mode {mode!r}")

        legacy: list[int] = []  # features grouped by the pre-universal rules
        groups: list[TableGroup] = []
        if mode == "univ":
            fusable: dict[str, list[int]] = {}
            for i, t in enumerate(tables):
                if hasattr(t, "fuse_spec"):
                    fusable.setdefault(str(jnp.dtype(t.dtype)), []).append(i)
                else:
                    legacy.append(i)
            for _, feats in fusable.items():
                for bucket in cls._partition_univ(feats, tables):
                    if all(
                        isinstance(tables[i], emb_lib.FullTable) for i in bucket
                    ):
                        # full-only bucket: a one-hot matmul over k = d1
                        # rows has nothing to amortize against — keep the
                        # padded gather
                        legacy.extend(bucket)
                        continue
                    members = sorted(bucket)
                    specs = [tables[i].fuse_spec for i in members]
                    groups.append(
                        TableGroup(
                            "univ",
                            tuple(members),
                            tuple(tables[i] for i in members),
                            dsub=_gcd_all(s.dsub for s in specs),
                            n_tables=max(s.n_tables for s in specs),
                            k_multiple=k_multiple,
                        )
                    )
        else:
            legacy = list(range(len(tables)))

        by_sig: dict[Any, list[int]] = {}
        for i in legacy:
            t = tables[i]
            if mode == "group" and isinstance(t, CCE):
                sig = ("cce", t.c, t.dsub, str(jnp.dtype(t.dtype)))
            elif isinstance(t, emb_lib.FullTable):
                sig = t.group_signature()
            else:
                sig = ("loop", i)
            by_sig.setdefault(sig, []).append(i)
        for sig, feats in by_sig.items():  # insertion order: first feature
            if sig[0] == "cce":
                specs = [tables[i].fuse_spec for i in feats]
                groups.append(
                    TableGroup(
                        "univ", tuple(feats), tuple(tables[i] for i in feats),
                        dsub=_gcd_all(s.dsub for s in specs),
                        n_tables=max(s.n_tables for s in specs),
                    )
                )
                continue
            kind = "full" if sig[0] == "full" else "loop"
            for bucket in cls._partition(kind, feats, tables):
                groups.append(
                    TableGroup(kind, tuple(bucket), tuple(tables[i] for i in bucket))
                )
        if mode == "univ":
            groups.sort(key=lambda g: g.features[0])
        # mode="group" keeps the HISTORICAL order (signature insertion +
        # d1-sorted full buckets) so its layout matches PR-3 checkpoints
        # byte for byte — grouped_layout_migration depends on this
        return cls(tables, tuple(groups))

    @staticmethod
    def _partition_univ(feats, tables):
        """Split a universal bucket so the padded supertable never costs
        more than ``UNIV_PAD_WASTE``× the members' natural parameters.

        Greedy, largest k first: each candidate joins the current bucket
        only while (a) the combined padded size (every member's width ×
        the bucket max T × the bucket max k) stays inside the aggregate
        bound AND (b) every member individually stays inside the bound
        (or below the UNIV_PAD_SLACK_ELEMS absolute allowance — tiny
        tables may inflate relative to themselves, never in absolute
        terms).  One huge-k member (a big hash table, a full table with
        k = d1) can therefore never inflate a small-k member's slab —
        they end up in separate buckets.  Deterministic given the table
        list."""

        def admits(members):
            specs = [tables[i].fuse_spec for i in members]
            k_pad = max(s.k for s in specs)
            T = max(s.n_tables for s in specs)
            padded = natural = 0
            for s in specs:
                w = s.cols * s.dsub
                p, n = w * T * k_pad, w * s.n_tables * s.k
                if p > UNIV_PAD_WASTE * n and p > UNIV_PAD_SLACK_ELEMS:
                    return False  # per-member inflation, large in absolute terms
                padded += p
                natural += n
            return padded <= UNIV_PAD_WASTE * natural

        order = sorted(feats, key=lambda i: (-tables[i].fuse_spec.k, i))
        buckets, cur = [], [order[0]]
        for i in order[1:]:
            if admits(cur + [i]):
                cur.append(i)
            else:
                buckets.append(cur)
                cur = [i]
        buckets.append(cur)
        return buckets

    @staticmethod
    def _partition(kind, feats, tables):
        """Split a signature bucket when padding would be pathological:
        full tables pad the VOCAB axis, so a (tiny, huge) mix is re-split
        by d1 ratio; universal groups are waste-bounded separately
        (``_partition_univ``)."""
        if kind != "full" or len(feats) <= 1:
            return [feats]
        feats = sorted(feats, key=lambda i: tables[i].d1)
        buckets, cur = [], [feats[0]]
        for i in feats[1:]:
            if tables[i].d1 > FULL_PAD_RATIO * tables[cur[0]].d1:
                buckets.append(cur)
                cur = [i]
            else:
                cur.append(i)
        buckets.append(cur)
        return buckets

    # --- shape facts ------------------------------------------------------

    @property
    def n_features(self) -> int:
        return len(self.tables)

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_lookup_launches(self) -> int:
        """Heavy table-lookup ops per forward pass: 1 per fused group
        (universal supertable launch or padded full gather), 1 per
        feature of a loop group — the quantity the fusion work drives
        from O(n_features) to O(1).  Asserted against a jaxpr-level
        kernel-launch count in tests/test_collection.py so a refactor
        cannot silently reintroduce the per-feature loop."""
        return sum(
            len(g.features) if g.kind == "loop" else 1 for g in self.groups
        )

    @functools.cached_property
    def _locate(self) -> dict[int, tuple[int, int]]:
        """feature index -> (group index, index within group)."""
        out = {}
        for g, grp in enumerate(self.groups):
            for f_local, i in enumerate(grp.features):
                out[i] = (g, f_local)
        return out

    @functools.cached_property
    def univ_groups(self) -> tuple[int, ...]:
        return tuple(g for g, grp in enumerate(self.groups) if grp.kind == "univ")

    @property
    def rows_n_tables(self) -> int:
        """T of the host-translated rows tensor: max over universal
        groups (narrower groups read their leading T slots)."""
        return max((self.groups[g].n_tables for g in self.univ_groups), default=0)

    @property
    def rows_n_cols(self) -> int:
        """Total supertable columns across universal groups — the rows
        tensor is (B, rows_n_cols, rows_n_tables) int32, the ONLY sparse
        input a host-translating pipeline ships (DESIGN.md §4/§6)."""
        return sum(self.groups[g].n_cols for g in self.univ_groups)

    @functools.cached_property
    def rows_col_feature(self):
        """(rows_n_cols,) int32: GLOBAL feature index owning each column
        of the host-translated rows tensor.  Lets a serve-side cache mask
        exactly the columns of a cache-hit feature to the -1 sentinel
        (``HostTranslator.rows_masked``) so the fused kernel does zero
        work for them — per-feature column spans, in the same order
        ``rows`` concatenates universal groups."""
        out = []
        for g in self.univ_groups:
            grp = self.groups[g]
            for f_local, n in enumerate(grp.col_counts):
                out.extend([grp.features[f_local]] * n)
        return np.asarray(out, np.int32)

    # --- init / stacking --------------------------------------------------

    def init(self, key):
        """Per-feature init (same fold_in(key, i) schedule as the legacy
        per-table loop, so the stacked slices are bit-identical to the
        old layout), then stack into the grouped layout."""
        per_p, per_b = [], []
        for i, t in enumerate(self.tables):
            p, b = t.init(jax.random.fold_in(key, i))
            per_p.append(p)
            per_b.append(b)
        return self.stack_params(per_p), self.stack_buffers(per_b)

    def stack_group_params(self, grp: TableGroup, params_seq):
        if grp.kind == "univ":
            from repro.kernels import ops as kops

            slabs = [
                _split_slab(t.fuse_slab(p), grp.dsub, grp.n_tables)
                for t, p in zip(grp.tables, params_seq)
            ]
            return {"tables": kops.pad_stack_tables(slabs, k_pad=grp.k_pad)}
        if grp.kind == "full":
            return emb_lib.FullTable.stack_many(grp.tables, params_seq)
        return list(params_seq)

    def unstack_group_params(self, grp: TableGroup, group_params):
        if grp.kind == "univ":
            out, off = [], 0
            for t, n in zip(grp.tables, grp.col_counts):
                spec = t.fuse_spec
                slab = group_params["tables"][off : off + n, :, : spec.k, :]
                out.append(t.unfuse_slab(_merge_slab(slab, spec, grp.dsub)))
                off += n
            return out
        if grp.kind == "full":
            return emb_lib.FullTable.unstack_many(grp.tables, group_params)
        return list(group_params)

    def stack_params(self, per_feature):
        """Legacy per-feature params list -> grouped layout."""
        return [
            self.stack_group_params(grp, [per_feature[i] for i in grp.features])
            for grp in self.groups
        ]

    def unstack_params(self, grouped):
        """Grouped layout -> legacy per-feature params list."""
        out = [None] * self.n_features
        for g, grp in enumerate(self.groups):
            per = self.unstack_group_params(grp, grouped[g])
            for f_local, i in enumerate(grp.features):
                out[i] = per[f_local]
        return out

    def stack_buffers(self, per_feature):
        """Buffers regroup only (no array surgery — see module docstring)."""
        return [[per_feature[i] for i in grp.features] for grp in self.groups]

    def unstack_buffers(self, grouped):
        out = [None] * self.n_features
        for g, grp in enumerate(self.groups):
            for f_local, i in enumerate(grp.features):
                out[i] = grouped[g][f_local]
        return out

    def feature_params(self, emb_params, i: int):
        """Per-feature view into the grouped params (tests, serving)."""
        g, f_local = self._locate[i]
        return self.unstack_group_params(self.groups[g], emb_params[g])[f_local]

    def feature_buffers(self, emb_buffers, i: int):
        g, f_local = self._locate[i]
        return emb_buffers[g][f_local]

    # --- the hot path -----------------------------------------------------

    def group_rows(self, grp: TableGroup, buffers_seq, ids):
        """Device-side row translation for one universal group:
        ids (B, Fg) -> (n_cols, B, T) int32.  Cheap int math (pointer
        gather + multiply-shift hashes) next to the heavy launch; the
        host twin is ``data.translate.HostTranslator``."""
        return jnp.concatenate(
            [
                _expand_rows(
                    t.fuse_rows(buffers_seq[f], ids[:, f]),
                    grp.col_counts[f] // t.fuse_spec.cols,
                    grp.n_tables,
                    jnp,
                )
                for f, t in enumerate(grp.tables)
            ],
            axis=0,
        )

    def _univ_lookup(self, grp: TableGroup, group_params, rows, use_kernel):
        """(n_cols, B, T) rows + supertable -> (B, n_cols*dsub)."""
        from repro.kernels import ops as kops

        # trace span only (HLO metadata — profiler timelines group the
        # fused lookup under one name); no effect on the jaxpr
        with jax.named_scope("emb/fused-lookup"):
            if use_kernel:
                return kops.cce_lookup(rows, group_params["tables"])
            return self._univ_lookup_jnp(group_params, rows)

    def _univ_lookup_jnp(self, group_params, rows):
        tabs = group_params["tables"]  # (C, T, k, dsub)

        def col(tab, r):  # (T, k, dsub), (B, T)
            picked = jax.vmap(
                lambda tt, rt: tt[jnp.maximum(rt, 0)] * (rt >= 0)[:, None],
                in_axes=(0, 1),
            )(tab, r)  # (T, B, dsub) — sentinel rows contribute exact zero
            return picked.sum(axis=0)

        pieces = jax.vmap(col)(tabs, rows)  # (C, B, dsub)
        B = rows.shape[1]
        return jnp.moveaxis(pieces, 0, 1).reshape(B, -1)

    def _univ_lookup_sharded(self, grp: TableGroup, group_params, rows,
                             use_kernel, *, mesh, model_axis, batch_axes):
        """Model-parallel universal lookup: the slab lives row(k)-sharded
        over ``model_axis``, the batch lives sharded over ``batch_axes``
        (which INCLUDE the model axis — every device works a distinct
        batch slice), and ids route to their owning shard via all-to-all.

        ``rows`` is (B, n_cols, T) global rows (bucketed on device) or
        (B, M, n_cols, T) host-bucketed shard-local rows
        (``HostTranslator(..., n_shards=M)``).  Per shard_map body:
        bucket → all-to-all (each shard receives the ids it owns from
        every peer's batch slice) → local kernel launch (non-owned slots
        are the -1 sentinel: exact-zero partials) → all-to-all back →
        sum over shards.  Both all-to-alls transpose to all-to-alls, so
        the backward pass keeps the same routing and the slab cotangent
        psums over the unmentioned batch axes automatically — forward
        AND gradient are bit-identical to the unsharded launch (tested
        in test_sharded_lookup.py).

        ``check_rep`` is off (no replication rule for pallas_call on
        jax 0.4.x) — out_specs are correct by the argument above.
        """
        from repro import compat

        M = int(mesh.shape[model_axis])
        k_loc = grp.k_pad // M
        if k_loc * M != grp.k_pad:
            raise ValueError(
                f"k_pad {grp.k_pad} not divisible by model shards {M}; "
                f"build the collection with k_multiple={M}"
            )
        T_g = grp.n_tables
        n_cols = grp.n_cols
        P = jax.sharding.PartitionSpec
        pre_bucketed = rows.ndim == 4

        def body(slab_loc, rows_loc):
            # slab_loc (n_cols, T, k_loc, dsub); rows_loc (B_loc, n_cols, T)
            # global rows or (B_loc, M, n_cols, T) shard-local buckets
            with jax.named_scope("emb/route"):
                if pre_bucketed:
                    b = jnp.moveaxis(rows_loc, 1, 0)  # (M, B_loc, n_cols, T)
                else:
                    b = bucket_rows(rows_loc, k_loc, M, jnp)
                recv = jax.lax.all_to_all(
                    b, model_axis, split_axis=0, concat_axis=0
                )
            B_loc = rows_loc.shape[0]
            r = jnp.moveaxis(recv.reshape(M * B_loc, n_cols, T_g), 0, 1)
            part = self._univ_lookup(grp, {"tables": slab_loc}, r, use_kernel)
            part = part.reshape(M, B_loc, n_cols * grp.dsub)
            with jax.named_scope("emb/route-back"):
                back = jax.lax.all_to_all(
                    part, model_axis, split_axis=0, concat_axis=0
                )
            return back.sum(axis=0)  # (B_loc, n_cols*dsub)

        rows_spec = P(batch_axes, *([None] * (rows.ndim - 1)))
        return compat.shard_map_unchecked(
            body,
            mesh=mesh,
            in_specs=(P(None, None, model_axis, None), rows_spec),
            out_specs=P(batch_axes, None),
        )(group_params["tables"], rows)

    def lookup_all(self, emb_params, emb_buffers, sparse, *, use_kernel=True,
                   rows=None, mesh=None, model_axis=None,
                   batch_axes=None):
        """All features' embeddings in O(n_groups) heavy lookups — ONE on
        the compressed Criteo config.

        sparse (B, n_features) int32 -> (B, n_features, d2).  Universal
        groups route through the fused Pallas kernel when ``use_kernel``
        (Mosaic on TPU, interpret mode on CPU); ``use_kernel=False`` is
        the masked-gather jnp path — identical math, used as the numerics
        oracle and as the GPU fallback.

        ``rows`` (B, rows_n_cols, rows_n_tables) int32 — HOST-translated
        row indices (``data.translate``): universal groups consume their
        column slice directly and the device program never touches the
        (c, d1) pointer buffers.  ``sparse`` may then be None when every
        feature is universally fused.

        ``mesh``/``model_axis``/``batch_axes`` switch universal groups to
        the model-parallel path (``_univ_lookup_sharded``): the slab is
        k-sharded over ``model_axis``, host rows may additionally arrive
        pre-bucketed as (B, n_shards, rows_n_cols, rows_n_tables).  Axis
        names are plain strings supplied by the caller (canonically
        ``launch.mesh.DATA_AXIS``/``MODEL_AXIS`` — core stays
        launch-agnostic).  The 1-device path is untouched.
        """
        sharded = mesh is not None and model_axis is not None
        if not sharded and rows is not None and rows.ndim == 4:
            raise ValueError("pre-bucketed 4-d rows require a model mesh")
        outs = [None] * self.n_features
        col_off = 0
        for g, grp in enumerate(self.groups):
            if grp.kind == "univ":
                if sharded:
                    if rows is None:
                        raise NotImplementedError(
                            "sharded lookup needs host-translated rows "
                            "(the device program must not gather ptr)"
                        )
                    sl = (slice(None), slice(col_off, col_off + grp.n_cols),
                          slice(None, grp.n_tables))
                    grows = rows[(slice(None), slice(None)) + sl[1:]] \
                        if rows.ndim == 4 else rows[sl]
                    col_off += grp.n_cols
                    flat = self._univ_lookup_sharded(
                        grp, emb_params[g], grows, use_kernel,
                        mesh=mesh, model_axis=model_axis,
                        batch_axes=batch_axes,
                    )
                elif rows is not None:
                    grows = jnp.moveaxis(
                        rows[:, col_off : col_off + grp.n_cols, : grp.n_tables],
                        0, 1,
                    )  # (n_cols, B, T)
                    col_off += grp.n_cols
                    flat = self._univ_lookup(grp, emb_params[g], grows, use_kernel)
                else:
                    ids = jnp.take(sparse, jnp.asarray(grp.features), axis=1)
                    grows = self.group_rows(grp, emb_buffers[g], ids)
                    flat = self._univ_lookup(grp, emb_params[g], grows, use_kernel)
                off = 0
                for f_local, i in enumerate(grp.features):
                    n = grp.col_counts[f_local]
                    outs[i] = flat[:, off * grp.dsub : (off + n) * grp.dsub]
                    off += n
                continue
            ids = jnp.take(sparse, jnp.asarray(grp.features), axis=1)  # (B, Fg)
            if grp.kind == "full":
                vecs = emb_lib.FullTable.lookup_many(
                    grp.tables, emb_params[g], emb_buffers[g], ids
                )
            else:
                vecs = emb_lib.lookup_many_loop(
                    grp.tables, emb_params[g], emb_buffers[g], ids
                )
            for f_local, i in enumerate(grp.features):
                outs[i] = vecs[:, f_local]
        return jnp.stack(outs, axis=1)


def _emb_layout_migration(old_p, old_b, new_p, new_b):
    """(to_old, to_new) pair converting a checkpoint tree's embedding
    subtrees (params["emb"] / optimizer moment slots / err, and
    ebuf["emb"]) between two layouts via the given emb-tree transforms.
    Every transform is value-preserving (unstack slices bit-identical
    blocks; stacking only reshapes and pads with zeros that training
    provably keeps zero), so restores through a migration are BIT-EXACT.
    """

    def _emb(tree, fn):
        return dict(tree, emb=fn(tree["emb"])) if isinstance(tree, dict) and "emb" in tree else tree

    def _state(state, pfn, bfn):
        opt = state.opt
        if isinstance(opt, dict):
            opt = {k: _emb(v, pfn) if isinstance(v, dict) else v for k, v in opt.items()}
        return state._replace(
            params=_emb(state.params, pfn),
            opt=opt,
            ebuf=_emb(state.ebuf, bfn),
            err=_emb(state.err, pfn) if isinstance(state.err, dict) else state.err,
        )

    def to_old(tree):
        return dict(tree, state=_state(tree["state"], old_p, old_b))

    def to_new(tree):
        return dict(tree, state=_state(tree["state"], new_p, new_b))

    return to_old, to_new


def legacy_layout_migration(coll: EmbeddingCollection):
    """Checkpoint migration pair for pre-collection (per-feature) layouts:
    ``to_old(new_template)`` derives the legacy template a per-table-era
    writer produced (params["emb"] / optimizer moments / err per feature,
    ebuf per feature), ``to_new(old_tree)`` re-stacks a restored legacy
    tree into the grouped layout — bit-exact, tested in
    test_collection.py."""
    return _emb_layout_migration(
        coll.unstack_params, coll.unstack_buffers,
        coll.stack_params, coll.stack_buffers,
    )


def grouped_layout_migration(coll: EmbeddingCollection,
                             old_coll: EmbeddingCollection):
    """Checkpoint migration pair between two GROUPED layouts — e.g. a
    checkpoint written under the pre-universal grouping
    (``build(mode="group")``: per-signature CCE slab + full buckets)
    restoring into today's universal layout.  Both layouts convert
    losslessly through the per-feature view, so the restore is bit-exact
    (tested in test_collection.py)."""
    return _emb_layout_migration(
        lambda emb: old_coll.stack_params(coll.unstack_params(emb)),
        lambda emb: old_coll.stack_buffers(coll.unstack_buffers(emb)),
        lambda emb: coll.stack_params(old_coll.unstack_params(emb)),
        lambda emb: coll.stack_buffers(old_coll.unstack_buffers(emb)),
    )
