"""EmbeddingCollection — grouped supertables for multi-feature models.

The paper's hot loop is ``concat_i M_i[h_i(id)] + M'_i[h'_i(id)]`` per
categorical feature; DLRM has 26 of them.  Issuing 26 independent gathers
per step wastes the fused one-hot-matmul kernel (``kernels/cce_lookup``)
and launches O(n_features) ops where O(n_groups) suffice — the
``QREmbeddingBag`` lesson from Shi et al. 2020, and the precondition CAFE
(Zhang et al. 2023) names for adaptive per-feature compression to pay off.

The collection groups a model's tables by fuse-compatibility signature
(``table.group_signature()``) and stacks each group's parameters:

  * CCE tables with equal (c, dsub, dtype) -> ONE supertable
    (F·c, 2, max k_f, dsub) + per-feature pointer arrays; the whole group
    is one ``kops.cce_lookup`` launch per step, forward AND backward
    (ragged codebooks zero-padded by ``kops.pad_stack_tables`` — padded
    rows are unreachable and get exactly-zero gradient).
  * Full tables with equal (d2, dtype) -> ONE padded (F, max d1, d2)
    stack; the whole group is a single gather.  Groups are sub-partitioned
    when the d1 spread would make padding cost more than the fusion saves.
  * Everything else (hash/ce/robe/dhe/tt and methods without a signature)
    falls back to a per-feature loop group.

State layout (the "grouped layout", DESIGN.md §3):

    params["emb"]  : [group_params, ...]       one entry per group
    buffers["emb"] : [[feat_buffers, ...], ...]  per group, per feature

Buffers are NEVER stacked — pointer arrays have per-feature vocabularies
and stay exactly as the per-feature methods wrote them, so every CCE
method (cluster, remap_moments, materialize) applies unchanged to a
feature's slice.  ``stack_params``/``unstack_params`` convert between the
grouped layout and the legacy per-feature layout (used by the checkpoint
migration: pre-collection checkpoints restore bit-exact, see
``legacy_layout_migration``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import embeddings as emb_lib
from repro.core.cce import CCE

#: Sub-partition a "full" group when padding every table to the group max
#: would blow past this multiple of the smallest table in the bucket —
#: bounds the padded-parameter waste at ~FULL_PAD_RATIO per bucket while a
#: budget-capped config (all small tables) still lands in one gather.
FULL_PAD_RATIO = 8


@dataclasses.dataclass(frozen=True)
class TableGroup:
    kind: str  # "cce" | "full" | "loop"
    features: tuple[int, ...]  # global feature indices, ascending
    tables: tuple[Any, ...]  # the features' method objects, same order


@dataclasses.dataclass(frozen=True)
class EmbeddingCollection:
    tables: tuple[Any, ...]
    groups: tuple[TableGroup, ...]

    # --- construction ----------------------------------------------------

    @classmethod
    def build(cls, tables: Sequence[Any]) -> "EmbeddingCollection":
        tables = tuple(tables)
        by_sig: dict[Any, list[int]] = {}
        for i, t in enumerate(tables):
            sig_fn = getattr(t, "group_signature", None)
            sig = sig_fn() if sig_fn is not None else ("loop", i)
            by_sig.setdefault(sig, []).append(i)
        groups = []
        for sig, feats in by_sig.items():  # insertion order: first feature
            kind = sig[0] if sig[0] in ("cce", "full") else "loop"
            for bucket in cls._partition(kind, feats, tables):
                groups.append(
                    TableGroup(kind, tuple(bucket), tuple(tables[i] for i in bucket))
                )
        return cls(tables, tuple(groups))

    @staticmethod
    def _partition(kind, feats, tables):
        """Split a signature bucket when padding would be pathological:
        full tables pad the VOCAB axis, so a (tiny, huge) mix is re-split
        by d1 ratio; cce pads only the (budget-bounded) codebook axis and
        never splits."""
        if kind != "full" or len(feats) <= 1:
            return [feats]
        feats = sorted(feats, key=lambda i: tables[i].d1)
        buckets, cur = [], [feats[0]]
        for i in feats[1:]:
            if tables[i].d1 > FULL_PAD_RATIO * tables[cur[0]].d1:
                buckets.append(cur)
                cur = [i]
            else:
                cur.append(i)
        buckets.append(cur)
        return buckets

    # --- shape facts ------------------------------------------------------

    @property
    def n_features(self) -> int:
        return len(self.tables)

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_lookup_launches(self) -> int:
        """Heavy table-lookup ops per forward pass: 1 per fused group,
        1 per feature of a loop group (the quantity the refactor drives
        from O(n_features) to O(n_groups))."""
        return sum(
            len(g.features) if g.kind == "loop" else 1 for g in self.groups
        )

    @functools.cached_property
    def _locate(self) -> dict[int, tuple[int, int]]:
        """feature index -> (group index, index within group)."""
        out = {}
        for g, grp in enumerate(self.groups):
            for f_local, i in enumerate(grp.features):
                out[i] = (g, f_local)
        return out

    # --- init / stacking --------------------------------------------------

    def init(self, key):
        """Per-feature init (same fold_in(key, i) schedule as the legacy
        per-table loop, so the stacked slices are bit-identical to the
        old layout), then stack into the grouped layout."""
        per_p, per_b = [], []
        for i, t in enumerate(self.tables):
            p, b = t.init(jax.random.fold_in(key, i))
            per_p.append(p)
            per_b.append(b)
        return self.stack_params(per_p), self.stack_buffers(per_b)

    def stack_group_params(self, grp: TableGroup, params_seq):
        if grp.kind == "cce":
            return CCE.stack_many(grp.tables, params_seq)
        if grp.kind == "full":
            return emb_lib.FullTable.stack_many(grp.tables, params_seq)
        return list(params_seq)

    def unstack_group_params(self, grp: TableGroup, group_params):
        if grp.kind == "cce":
            return CCE.unstack_many(grp.tables, group_params)
        if grp.kind == "full":
            return emb_lib.FullTable.unstack_many(grp.tables, group_params)
        return list(group_params)

    def stack_params(self, per_feature):
        """Legacy per-feature params list -> grouped layout."""
        return [
            self.stack_group_params(grp, [per_feature[i] for i in grp.features])
            for grp in self.groups
        ]

    def unstack_params(self, grouped):
        """Grouped layout -> legacy per-feature params list."""
        out = [None] * self.n_features
        for g, grp in enumerate(self.groups):
            per = self.unstack_group_params(grp, grouped[g])
            for f_local, i in enumerate(grp.features):
                out[i] = per[f_local]
        return out

    def stack_buffers(self, per_feature):
        """Buffers regroup only (no array surgery — see module docstring)."""
        return [[per_feature[i] for i in grp.features] for grp in self.groups]

    def unstack_buffers(self, grouped):
        out = [None] * self.n_features
        for g, grp in enumerate(self.groups):
            for f_local, i in enumerate(grp.features):
                out[i] = grouped[g][f_local]
        return out

    def feature_params(self, emb_params, i: int):
        """Per-feature view into the grouped params (tests, serving)."""
        g, f_local = self._locate[i]
        return self.unstack_group_params(self.groups[g], emb_params[g])[f_local]

    def feature_buffers(self, emb_buffers, i: int):
        g, f_local = self._locate[i]
        return emb_buffers[g][f_local]

    # --- the hot path -----------------------------------------------------

    def lookup_all(self, emb_params, emb_buffers, sparse, *, use_kernel=True):
        """All features' embeddings in O(n_groups) heavy lookups.

        sparse (B, n_features) int32 -> (B, n_features, d2).  CCE groups
        route through the fused Pallas kernel when ``use_kernel`` (Mosaic
        on TPU, interpret mode on CPU); ``use_kernel=False`` is the vmapped
        jnp gather path — identical math, used as the numerics oracle and
        as the GPU fallback."""
        outs = [None] * self.n_features
        for g, grp in enumerate(self.groups):
            ids = jnp.take(sparse, jnp.asarray(grp.features), axis=1)  # (B, Fg)
            if grp.kind == "cce":
                vecs = CCE.lookup_many(
                    grp.tables, emb_params[g], emb_buffers[g], ids,
                    use_kernel=use_kernel,
                )
            elif grp.kind == "full":
                vecs = emb_lib.FullTable.lookup_many(
                    grp.tables, emb_params[g], emb_buffers[g], ids
                )
            else:
                vecs = emb_lib.lookup_many_loop(
                    grp.tables, emb_params[g], emb_buffers[g], ids
                )
            for f_local, i in enumerate(grp.features):
                outs[i] = vecs[:, f_local]
        return jnp.stack(outs, axis=1)


def legacy_layout_migration(coll: EmbeddingCollection):
    """Checkpoint migration pair for pre-collection (per-feature) layouts.

    Returns ``(to_old, to_new)`` for ``checkpoint.load_checkpoint``'s
    ``migrations``: ``to_old(new_template)`` derives the legacy template a
    per-table-era writer produced (params["emb"] / optimizer moments / err
    per feature, ebuf per feature), and ``to_new(old_tree)`` re-stacks a
    restored legacy tree into the grouped layout.  Stacking only pads with
    zeros (codebook / vocab padding), so a legacy checkpoint restores
    BIT-EXACT into the grouped state — tested in test_collection.py.
    """

    def _emb(tree, fn):
        return dict(tree, emb=fn(tree["emb"])) if isinstance(tree, dict) and "emb" in tree else tree

    def _state(state, pfn, bfn):
        opt = state.opt
        if isinstance(opt, dict):
            opt = {k: _emb(v, pfn) if isinstance(v, dict) else v for k, v in opt.items()}
        return state._replace(
            params=_emb(state.params, pfn),
            opt=opt,
            ebuf=_emb(state.ebuf, bfn),
            err=_emb(state.err, pfn) if isinstance(state.err, dict) else state.err,
        )

    def to_old(tree):
        return dict(
            tree,
            state=_state(tree["state"], coll.unstack_params, coll.unstack_buffers),
        )

    def to_new(tree):
        return dict(
            tree,
            state=_state(tree["state"], coll.stack_params, coll.stack_buffers),
        )

    return to_old, to_new
