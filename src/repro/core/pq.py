"""Post-training Product Quantization — the paper's post-hoc baseline
(Figure 4a's "Product Quantization" line).

PQ splits the trained table T (d1, d2) into c column blocks and K-means
each block into k codewords: T ~= concat_i( M_i[h_i(id)] ).  Unlike CCE it
can only run AFTER training — it never reduces training memory, and
fine-tuning the codebooks post-PQ overfits immediately (paper §4, Fig. 4a).

The quantized table is exactly a CE-concat structure, so it shares the
lookup/logits code path with `core/embeddings.CEConcat`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import kmeans as km


@dataclasses.dataclass(frozen=True)
class PQResult:
    codebooks: Any  # (c, k, d2/c)
    assignments: Any  # (c, d1) int32
    mse: float


def product_quantize(
    key,
    table: jax.Array,
    k: int,
    c: int = 4,
    *,
    niter: int = 50,
    sample: int | None = None,
) -> PQResult:
    """Quantize a trained table into c codebooks of k codewords each."""
    d1, d2 = table.shape
    assert d2 % c == 0
    dsub = d2 // c
    blocks = table.reshape(d1, c, dsub)
    codebooks, assigns = [], []
    mse = 0.0
    for i in range(c):
        x = blocks[:, i]
        ki = jax.random.fold_in(key, i)
        if sample is not None and sample < d1:
            idx = jax.random.choice(ki, d1, (sample,), replace=False)
            res = km.kmeans(ki, x[idx], k, niter=niter)
            a = km.assign(x, res.centroids)
        else:
            res = km.kmeans(ki, x, k, niter=niter)
            a = res.assignments
        codebooks.append(res.centroids)
        assigns.append(a)
        mse += float(jnp.mean((x - res.centroids[a]) ** 2))  # audit: allow-int-cast (eager)
    return PQResult(
        codebooks=jnp.stack(codebooks),
        assignments=jnp.stack(assigns),
        mse=mse / c,
    )


def pq_lookup(pq: PQResult, ids: jax.Array) -> jax.Array:
    """Reconstruct embeddings for ``ids`` from the PQ codebooks."""
    c, k, dsub = pq.codebooks.shape
    rows = pq.assignments[:, ids]  # (c, ...)
    pieces = jax.vmap(lambda tab, r: tab[r])(pq.codebooks, rows)
    return jnp.moveaxis(pieces, 0, -2).reshape(*ids.shape, c * dsub)


def pq_table(pq: PQResult) -> jax.Array:
    """The full reconstructed table (tests / small vocabs only)."""
    d1 = pq.assignments.shape[1]
    return pq_lookup(pq, jnp.arange(d1))
