"""Clustered Compositional Embeddings — Algorithm 3 of the paper.

A CCE table with vocabulary ``d1``, output dim ``d2``, ``c`` columns and
``2k`` rows per column (main table M indexed by a *learned* pointer array
``h`` + helper table M' indexed by a *random* hash ``h'``):

    lookup(id) = concat_i( M_i[h_i(id)] + M'_i[h'_i(id)] )

``cluster()`` is the paper's training-time transition (Alg. 3, lines 10-17):
per column, materialize (a sample of) the current vocab embeddings, K-means
them into k centroids, set ``h_i <- assignments``, ``M_i <- centroids``,
draw a fresh random ``h'_i`` and zero ``M'_i``.  The helper table restores
the ability to differentiate ids the clustering merged; the next clustering
can undo bad merges.

State layout (chosen for the TPU kernels and for sharding):

    params["tables"]  : (c, 2, k, dsub) — [:,0] main M, [:,1] helper M'
    buffers["ptr"]    : (c, d1) int32   — learned pointer arrays h_i
    buffers["hs"]     : (c, 2) uint32   — multiply-shift coeffs for h'_i
    buffers["epoch"]  : () int32        — transition counter (keys cluster())

All three buffers are ARRAYS and change on cluster(); they must ride the
train state dynamically (python-int leaves would be closed over statically
by the jitted step and go stale after a transition).

The pointer arrays are plain int32 tensors: on a pod they are host-resident
and ride the input pipeline (ids are translated to per-column rows on host,
see DESIGN.md §4); on a single device they are gathered on device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embeddings as emb_lib
from repro.core import hashing
from repro.core import kmeans as km
from repro.kernels import ops as kops
from repro.launch.mesh import DATA_AXIS


@dataclasses.dataclass(frozen=True)
class CCE:
    """Algorithm 3: CCE table with ``c`` columns and ``2k`` rows/column."""

    d1: int
    d2: int
    k: int
    c: int = 4
    seed_salt: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert self.d2 % self.c == 0, (self.d2, self.c)
        assert self.k >= 1

    @classmethod
    def from_budget(cls, d1, d2, budget, c=4, **kw):
        # 2 tables of (k, d2/c) per column -> 2*k*d2 params total
        k = max(1, min(d1, budget // (2 * d2)))
        return cls(d1, d2, k=k, c=c, **kw)

    @property
    def dsub(self) -> int:
        return self.d2 // self.c

    @property
    def n_params(self) -> int:
        return 2 * self.k * self.d2

    # --- collection grouping (DESIGN.md §3/§6) ---------------------------

    @property
    def fuse_spec(self) -> emb_lib.FuseSpec:
        """c columns of T=2 stacked sub-tables (main + helper): the
        universal-fusion shape every gather-sum method shares.  ``k`` is
        NOT part of the group key — the supertable pads ragged codebooks
        to the group max (``kops.pad_stack_tables``), so tables fuse even
        when per-table budgets differ."""
        return emb_lib.FuseSpec(cols=self.c, n_tables=2, k=self.k, dsub=self.dsub)

    def fuse_slab(self, params):
        return params["tables"]  # (c, 2, k, dsub) — already the natural slab

    def unfuse_slab(self, slab):
        return {"tables": slab}

    def fuse_rows(self, buffers, ids):
        return self._rows(buffers, ids)  # (c, B, 2)

    def fuse_rows_np(self, buffers, ids):
        """Bit-exact numpy twin of the JITTED ``fuse_rows`` — the
        host-side pointer translation (DESIGN.md §4): learned-pointer
        gather + helper hash computed against host mirrors of the
        buffers, so the device program never gathers the (c, d1) pointer
        table.  The ptr gather clamps out-of-range ids exactly like the
        XLA gather does (numpy would raise where the device clamps); the
        helper hash consumes the RAW id, also matching the device."""
        ids = np.asarray(ids)
        ptr = np.asarray(buffers["ptr"])
        hs = np.asarray(buffers["hs"])  # (c, 2) uint32
        main = ptr[:, np.clip(ids, 0, self.d1 - 1)]  # (c, B)
        helper = hashing.multiply_shift_np(
            ids[None], hs[:, :1], hs[:, 1:], self.k
        )  # (c, B)
        return np.stack([main, helper], axis=-1).astype(np.int32)

    # --- init -----------------------------------------------------------

    def init_buffers(self):
        """Device-free buffer init (numpy): hash coefficients derive from
        ``seed_salt`` so abstract (eval_shape) and real inits agree, and the
        pointer table never touches a device mesh.

        Every buffer is an ARRAY (``hs`` a (c, 2) uint32 coefficient pack,
        ``epoch`` a 0-d int32): the transition rewrites all three, and only
        array leaves ride ``TrainState.ebuf`` through the jitted step —
        python ints would be closed over statically and the step would keep
        training against the pre-transition hash functions."""
        ptr_hashes = hashing.make_hashes(self.seed_salt * 7919 + 66, self.c, self.k)
        ids = np.arange(self.d1)
        ptr = np.stack([h.np(ids) for h in ptr_hashes])  # (c, d1) int32
        hs = hashing.pack_hashes(
            hashing.make_hashes(self.seed_salt * 7919 + 77, self.c, self.k)
        )
        return {"ptr": ptr, "hs": hs, "epoch": np.int32(0)}

    def init(self, key):
        km_ = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2)
        tables = (
            jax.random.normal(km_, (self.c, 2, self.k, self.dsub)) * scale
        ).astype(self.dtype)
        buffers = self.init_buffers()
        return {"tables": tables}, dict(buffers, ptr=jnp.asarray(buffers["ptr"]))

    # --- lookup ---------------------------------------------------------

    def _helper_rows(self, buffers, ids):
        hs = jnp.asarray(buffers["hs"])  # (c, 2) uint32, possibly traced
        shape = (self.c,) + (1,) * jnp.ndim(ids)
        return hashing.multiply_shift(
            ids[None], hs[:, 0].reshape(shape), hs[:, 1].reshape(shape), self.k
        )  # (c, ...)

    def _rows(self, buffers, ids):
        """(c, ..., 2) int32 — main rows from the learned ptr, helper rows
        from the random hash."""
        main = buffers["ptr"][:, ids]  # (c, ...)
        helper = self._helper_rows(buffers, ids)
        return jnp.stack([main, helper], axis=-1)

    def lookup(self, params, buffers, ids, *, use_kernel: bool = False):
        rows = self._rows(buffers, ids)  # (c, ..., 2)
        if use_kernel:
            flat = rows.reshape(self.c, -1, 2)
            out = kops.cce_lookup(flat, params["tables"])  # (B, c*dsub)
            return out.reshape(*ids.shape, self.d2)
        tabs = params["tables"]  # (c, 2, k, dsub)
        main = jax.vmap(lambda t, r: t[r])(tabs[:, 0], rows[..., 0])
        helper = jax.vmap(lambda t, r: t[r])(tabs[:, 1], rows[..., 1])
        pieces = main + helper  # (c, ..., dsub)
        return jnp.moveaxis(pieces, 0, -2).reshape(*ids.shape, self.d2)

    def logits(self, params, buffers, h):
        """Factored output head: per column a k-sized matmul + int gather.

        logits[b, v] = sum_i  scores_i[b, h_i(v)] + scores'_i[b, h'_i(v)]
        where scores_i = h_col_i @ M_i^T   (B, k).
        """
        hc = h.reshape(*h.shape[:-1], self.c, self.dsub)
        all_ids = jnp.arange(self.d1)
        rows = self._rows({"ptr": buffers["ptr"], "hs": buffers["hs"]}, all_ids)
        out = 0.0
        for i in range(self.c):
            scores = hc[..., i, :] @ params["tables"][i].reshape(
                2 * self.k, self.dsub
            ).T  # (..., 2k)
            out = out + scores[..., rows[i, :, 0]]
            out = out + scores[..., self.k + rows[i, :, 1]]
        return out

    # --- the clustering transition (Alg. 3 lines 10-17) ------------------

    def materialize(self, params, buffers, ids):
        """Current embeddings of ``ids``, per column: (c, n, dsub)."""
        rows = self._rows(buffers, ids)
        tabs = params["tables"]
        return jax.vmap(lambda t, r: t[r])(
            tabs[:, 0], rows[..., 0]
        ) + jax.vmap(lambda t, r: t[r])(tabs[:, 1], rows[..., 1])

    def _id_chunks(self, chunk_size: int | None):
        """Full-vocab id ranges: one range when unchunked, else a stream of
        ``chunk_size`` slices so (c, d1, dsub) is never materialized."""
        if not chunk_size or chunk_size >= self.d1:
            yield jnp.arange(self.d1)
            return
        for s in range(0, self.d1, chunk_size):
            yield jnp.arange(s, min(s + chunk_size, self.d1))

    def assign_all(
        self,
        params,
        buffers,
        centroids: jax.Array,
        *,
        chunk_size: int | None = None,
        use_kernel: bool | None = None,
    ) -> jax.Array:
        """Single-pass full-vocab nearest-centroid assignment.

        ``centroids`` (c, k, dsub) -> (c, d1) int32.  The vocabulary is
        materialized exactly once (Alg. 3 line 13), in ``chunk_size`` id
        slices; per chunk the assignment routes through the Pallas
        ``kmeans_assign`` kernel when ``use_kernel`` (default: on TPU
        only — the kernel carries its (min, argmin) accumulator across
        the k grid axis, which needs TPU's sequential grid; GPU gets the
        jnp argmin path).  Chunking is bit-exact: distances are computed
        row-wise, so the chunk boundaries cannot change any argmin.
        """
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        outs = []
        for ids in self._id_chunks(chunk_size):
            emb = self.materialize(params, buffers, ids)  # (c, n, dsub)
            outs.append(
                jnp.stack(
                    [
                        km.assign(emb[i], centroids[i], use_kernel=use_kernel)
                        for i in range(self.c)
                    ]
                )
            )
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

    def _finish_transition(self, key, centroids, assignments, buffers):
        """Common tail of cluster()/cluster_sharded(): install centroids as
        the main tables, zero the helper tables (Alg. 3 line 17), draw
        fresh helper hashes, advance the epoch."""
        tables = jnp.stack(
            [centroids.astype(self.dtype), jnp.zeros_like(centroids, self.dtype)],
            axis=1,
        )  # (c, 2, k, dsub)
        hs = hashing.pack_hashes(
            hashing.make_hashes(jax.random.fold_in(key, 777), self.c, self.k)
        )
        new_buffers = {
            "ptr": assignments,
            "hs": hs,
            "epoch": jnp.asarray(buffers["epoch"], jnp.int32) + 1,
        }
        return {"tables": tables}, new_buffers

    def cluster(
        self,
        key,
        params,
        buffers,
        *,
        sample_ids: jax.Array | None = None,
        sample_weights: jax.Array | None = None,
        niter: int = 50,
        max_points_per_centroid: int = 256,
        chunk_size: int | None = None,
        use_kernel: bool | None = None,
    ):
        """One CCE iteration: returns new (params, buffers).

        K-means runs on a sample (FAISS-style, 256 pts/centroid by default,
        paper §Reproducibility); assignments for the FULL vocab are then
        ONE materialization pass shared by all columns (``assign_all``) —
        the per-column recompute this replaces was O(c²·d1·dsub).

        ``sample_weights`` (aligned with ``sample_ids``) runs COUNT-WEIGHTED
        k-means: each observed id appears once, weighted by its frequency —
        the zero-variance form of the paper's epoch-boundary sample (a
        with-replacement draw from the same histogram converges to it).
        """
        k1, k2 = jax.random.split(jax.random.fold_in(key, buffers["epoch"]))
        if sample_ids is None:
            sample_ids = km.subsample(k1, self.d1, self.k, max_points_per_centroid)

        sample = self.materialize(params, buffers, sample_ids)  # (c, n, dsub)
        centroids = jnp.stack(
            [
                km.kmeans(
                    jax.random.fold_in(k2, i), sample[i], self.k, niter=niter,
                    weights=sample_weights,
                ).centroids
                for i in range(self.c)
            ]
        )  # (c, k, dsub)
        new_ptr = self.assign_all(
            params, buffers, centroids, chunk_size=chunk_size, use_kernel=use_kernel
        )
        return self._finish_transition(k2, centroids, new_ptr, buffers)

    def _ptr_padded(self, ptr, d1_pad: int):
        """(c, d1) -> (c, d1_pad), tail repeating the last column so an
        even id-axis shard exists; padded entries are either masked out
        or produce row-wise duplicates that change no result."""
        ptr = jnp.asarray(ptr)
        if d1_pad > self.d1:
            ptr = jnp.concatenate(
                [ptr, jnp.tile(ptr[:, -1:], (1, d1_pad - self.d1))], axis=1
            )
        return ptr

    def materialize_sharded(self, params, buffers, ids, mesh, *,
                            axis_name: str = DATA_AXIS):
        """``materialize`` for arbitrary (scattered) ids against an
        ID-SHARDED pointer table — no shard ever holds the full (c, d1)
        ptr.  Shard ``s`` owns the contiguous id slice
        ``[s*d1_loc, (s+1)*d1_loc)``: it gathers main rows for the sample
        ids it owns, zeros the rest, and a psum assembles the full main
        part on every shard (exactly one non-zero term per id, so the
        sum is bit-exact regardless of reduction order).  The helper
        part needs only the tiny (c, 2) hash pack and is computed
        replicated; main + helper keeps ``materialize``'s addition
        order, so a 1-device axis reproduces it bit-exactly."""
        from jax.sharding import PartitionSpec as P

        from repro import compat

        nsh = mesh.shape[axis_name]
        d1_loc = (self.d1 + nsh - 1) // nsh
        ptr = self._ptr_padded(buffers["ptr"], d1_loc * nsh)
        hs = jnp.asarray(buffers["hs"])
        tabs = params["tables"]

        def body(ptr_local):
            lo = jax.lax.axis_index(axis_name) * d1_loc
            owned = (ids >= lo) & (ids < lo + d1_loc)
            local = jnp.clip(ids - lo, 0, d1_loc - 1)
            main_rows = ptr_local[:, local]  # (c, n)
            main = jax.vmap(lambda t, r: t[r])(tabs[:, 0], main_rows)
            main = jnp.where(owned[None, :, None], main, 0)
            main = jax.lax.psum(main, axis_name)
            helper = jax.vmap(lambda t, r: t[r])(
                tabs[:, 1], self._helper_rows({"hs": hs}, ids)
            )
            return main + helper

        return compat.shard_map_unchecked(
            body, mesh=mesh, in_specs=(P(None, axis_name),), out_specs=P(),
        )(ptr)

    def cluster_sharded(
        self,
        key,
        params,
        buffers,
        mesh,
        *,
        axis_name: str = DATA_AXIS,
        sample_ids: jax.Array | None = None,
        sample_weights: jax.Array | None = None,
        niter: int = 50,
        max_points_per_centroid: int = 256,
        chunk_size: int | None = None,
        use_kernel: bool | None = None,
    ):
        """Distributed transition: BOTH phases run data-parallel over
        ``axis_name``, and the (c, d1) pointer table only ever appears
        ID-SHARDED (``no-replicated-param`` holds at error severity for
        the captured transition programs).  The sample phase assembles
        the sample embeddings from the sharded ptr via masked psum
        (``materialize_sharded``); the k-means phase shards the sample
        points (local (sum, count) moments + psum — see
        ``kmeans.distributed_kmeans``); the full-vocab assignment phase
        shards the id range (``assign_all_sharded``) and returns the
        complete (c, d1) pointer as one global array, sharded over ids,
        gathered only where a consumer needs remote rows.  Sample
        weights shard with the points.  On a 1-device axis this
        reproduces ``cluster()`` exactly (same key schedule; the
        collectives degenerate to identity)."""
        from jax.sharding import PartitionSpec as P

        from repro import compat

        nsh = mesh.shape[axis_name]
        k1, k2 = jax.random.split(jax.random.fold_in(key, buffers["epoch"]))
        if sample_ids is None:
            sample_ids = km.subsample(k1, self.d1, self.k, max_points_per_centroid)
        # shard the sample evenly; the (< nsh) remainder is dropped, which
        # FAISS-style subsampling tolerates by construction
        n = sample_ids.shape[0] - sample_ids.shape[0] % nsh
        sample = self.materialize_sharded(
            params, buffers, sample_ids[:n], mesh, axis_name=axis_name
        )  # (c, n, dsub)
        w = None if sample_weights is None else sample_weights[:n].astype(jnp.float32)

        def per_shard(sample_local, w_local):
            return jnp.stack(
                [
                    km.distributed_kmeans(
                        jax.random.fold_in(k2, i),
                        sample_local[i],
                        self.k,
                        axis_name,
                        niter=niter,
                        weights=None if w_local is None else w_local,
                    )[0]
                    for i in range(self.c)
                ]
            )

        if w is None:
            centroids = compat.shard_map(
                lambda s: per_shard(s, None), mesh=mesh,
                in_specs=P(None, axis_name), out_specs=P(),
            )(sample)
        else:
            centroids = compat.shard_map(
                per_shard, mesh=mesh,
                in_specs=(P(None, axis_name), P(axis_name)), out_specs=P(),
            )(sample, w)
        new_ptr = self.assign_all_sharded(
            params, buffers, centroids, mesh, axis_name=axis_name,
            chunk_size=chunk_size, use_kernel=use_kernel,
        )
        return self._finish_transition(k2, centroids, new_ptr, buffers)

    def assign_all_sharded(
        self,
        params,
        buffers,
        centroids: jax.Array,
        mesh,
        *,
        axis_name: str = DATA_AXIS,
        chunk_size: int | None = None,
        use_kernel: bool | None = None,
    ) -> jax.Array:
        """``assign_all`` with the id range sharded over ``axis_name``.

        Each shard materializes and assigns d1/nsh ids (streamed in
        ``chunk_size`` slices like the serial pass) — the full-vocab pass
        is the transition's only O(d1) step, and it now scales with the
        data axis instead of running replicated on every host.  The OLD
        pointer table enters as a SHARDED operand (``P(None, axis)``):
        shard ``s`` owns the contiguous id slice ``[s*d1_loc,
        (s+1)*d1_loc)``, and because ptr is indexed by id, its local tile
        ``ptr[:, lo:hi]`` IS exactly the main rows of the ids the shard
        assigns — no shard ever holds the full (c, d1) table.  The
        per-shard (c, d1/nsh) tiles come back through
        ``out_specs=P(None, axis)``, i.e. the returned pointer is the
        full (c, d1) table as ONE global array sharded over the id axis
        — XLA inserts the all-gather lazily where a consumer needs rows
        from other shards.  The tail is padded with clamped ids and
        edge-repeated ptr columns (assignments are computed row-wise, so
        the padded duplicates change nothing) and sliced off after."""
        from jax.sharding import PartitionSpec as P

        from repro import compat

        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        nsh = mesh.shape[axis_name]
        d1_pad = ((self.d1 + nsh - 1) // nsh) * nsh
        ids = jnp.minimum(jnp.arange(d1_pad), self.d1 - 1)
        ptr = self._ptr_padded(buffers["ptr"], d1_pad)
        hs = jnp.asarray(buffers["hs"])
        tabs = params["tables"]

        def _chunk_assign(main_rows, ids_chunk):
            main = jax.vmap(lambda t, r: t[r])(tabs[:, 0], main_rows)
            helper = jax.vmap(lambda t, r: t[r])(
                tabs[:, 1], self._helper_rows({"hs": hs}, ids_chunk)
            )
            emb = main + helper  # (c, n, dsub)
            return jnp.stack(
                [
                    km.assign(emb[i], centroids[i], use_kernel=use_kernel)
                    for i in range(self.c)
                ]
            )

        def per_shard(ids_local, ptr_local):
            n_local = ids_local.shape[0]
            step = chunk_size if chunk_size and chunk_size < n_local else n_local
            outs = [
                _chunk_assign(
                    ptr_local[:, s : s + step], ids_local[s : s + step]
                )
                for s in range(0, n_local, step)
            ]
            return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

        ptr_new = compat.shard_map_unchecked(
            per_shard, mesh=mesh, in_specs=(P(axis_name), P(None, axis_name)),
            out_specs=P(None, axis_name),
        )(ids, ptr)
        return ptr_new[:, : self.d1]

    def assignment_counts(self, buffers) -> jax.Array:
        """Per-cluster id counts (c, k) from the pointer table.  Depends
        only on the assignments — callers remapping several moment slots
        (Adam's m AND v) compute it once and pass it to every
        ``remap_moments`` call."""
        ptr = jnp.asarray(buffers["ptr"])
        return jax.vmap(lambda a: jnp.bincount(a, length=self.k))(ptr).astype(
            jnp.float32
        )

    def remap_moments(self, moments, old_buffers, new_buffers, *,
                      chunk_size=None, counts=None, id_weights=None):
        """Carry per-row optimizer moments (momentum / Adam m, v) through a
        cluster() transition.

        ``moments`` mirrors params ({"tables": (c, 2, k, dsub)}) and
        describes the OLD rows; the transition rewrote both tables and the
        pointer array, so applying them unchanged starves freshly-written
        centroids with stale second moments (the CAFE failure mode).  The
        remap is the moment-space analog of the centroid update: an id's
        virtual moment is its materialized row-sum (main + helper) under
        the OLD pointers, and each new main row j takes the mean over the
        ids assigned to it; the fresh helper table starts at zero moments,
        matching its zero-initialized params.  Streams the vocab in
        ``chunk_size`` slices like ``assign_all``.

        ``id_weights`` (d1,) — typically the observed id histogram — makes
        the per-cluster mean COUNT-WEIGHTED, matching the frequency-weighted
        centroids: a cluster's moment is dominated by the ids that actually
        trained its rows.  Clusters whose ids were never observed (zero
        total weight) fall back to the uniform mean.
        """
        mt = jnp.asarray(moments["tables"])
        new_ptr = jnp.asarray(new_buffers["ptr"])  # (c, d1) assignments
        if counts is None:
            counts = self.assignment_counts(new_buffers)  # (c, k)
        sums = jnp.zeros((self.c, self.k, self.dsub), jnp.float32)
        wsums = jnp.zeros_like(sums)
        wcounts = jnp.zeros((self.c, self.k), jnp.float32)
        def seg(vals, idx):
            return jax.ops.segment_sum(vals, idx, num_segments=self.k)

        for ids in self._id_chunks(chunk_size):
            per_id = self.materialize({"tables": mt}, old_buffers, ids)
            per_id = per_id.astype(jnp.float32)
            idx = new_ptr[:, ids]
            sums = sums + jax.vmap(seg)(per_id, idx)
            if id_weights is not None:
                w = jnp.asarray(id_weights)[ids].astype(jnp.float32)  # (n,)
                wsums = wsums + jax.vmap(seg)(per_id * w[None, :, None], idx)
                wcounts = wcounts + jax.vmap(seg)(jnp.tile(w[None], (self.c, 1)), idx)
        mean = sums / jnp.maximum(counts[..., None], 1.0)
        if id_weights is not None:
            wmean = wsums / jnp.maximum(wcounts[..., None], 1e-12)
            mean = jnp.where(wcounts[..., None] > 0, wmean, mean)
        mean = mean.astype(mt.dtype)
        return {"tables": jnp.stack([mean, jnp.zeros_like(mean)], axis=1)}

    def remap_moments_sharded(self, moments, old_buffers, new_buffers, mesh, *,
                              axis_name: str = DATA_AXIS, chunk_size=None,
                              counts=None, id_weights=None):
        """``remap_moments`` with the vocab sharded over ``axis_name``.

        Both pointer tables enter as id-sharded operands (their local
        tiles align with the shard's contiguous id slice, exactly like
        ``assign_all_sharded``); each shard segment-sums the virtual
        moments of its own ids into (c, k) accumulators and a psum
        assembles the global sums — the (c, k, dsub) result is tiny, the
        (c, d1) tables never leave their shards.  The tail padding is
        MASKED (weight zero), not clamped: a clamped duplicate would be
        COUNTED twice by the segment sums, unlike the row-wise
        assignment pass where duplicates are harmless.  When ``counts``
        is None the per-cluster id counts are accumulated in the same
        pass (masked ones), matching ``assignment_counts`` exactly.  On
        a 1-device axis this reproduces ``remap_moments`` bit-exactly
        (same chunk boundaries, same addition order, identity psums)."""
        from jax.sharding import PartitionSpec as P

        from repro import compat

        nsh = mesh.shape[axis_name]
        d1_pad = ((self.d1 + nsh - 1) // nsh) * nsh
        ids = jnp.minimum(jnp.arange(d1_pad), self.d1 - 1)
        valid = (jnp.arange(d1_pad) < self.d1).astype(jnp.float32)
        old_ptr = self._ptr_padded(old_buffers["ptr"], d1_pad)
        new_ptr = self._ptr_padded(new_buffers["ptr"], d1_pad)
        mt = jnp.asarray(moments["tables"])
        old_hs = jnp.asarray(old_buffers["hs"])
        weighted = id_weights is not None
        w_pad = jnp.zeros(d1_pad, jnp.float32)
        if weighted:
            w_pad = w_pad.at[: self.d1].set(
                jnp.asarray(id_weights).astype(jnp.float32)
            )

        def seg(vals, idx):
            return jax.ops.segment_sum(vals, idx, num_segments=self.k)

        def per_shard(ids_local, valid_local, w_local, old_local, new_local):
            n_local = ids_local.shape[0]
            step = chunk_size if chunk_size and chunk_size < n_local else n_local
            sums = jnp.zeros((self.c, self.k, self.dsub), jnp.float32)
            cnts = jnp.zeros((self.c, self.k), jnp.float32)
            wsums = jnp.zeros_like(sums)
            wcounts = jnp.zeros_like(cnts)
            for s in range(0, n_local, step):
                ids_c = ids_local[s : s + step]
                v = valid_local[s : s + step]
                main = jax.vmap(lambda t, r: t[r])(
                    mt[:, 0], old_local[:, s : s + step]
                )
                helper = jax.vmap(lambda t, r: t[r])(
                    mt[:, 1], self._helper_rows({"hs": old_hs}, ids_c)
                )
                per_id = (main + helper).astype(jnp.float32)
                per_id = per_id * v[None, :, None]
                idx = new_local[:, s : s + step]
                sums = sums + jax.vmap(seg)(per_id, idx)
                cnts = cnts + jax.vmap(seg)(jnp.tile(v[None], (self.c, 1)), idx)
                if weighted:
                    w = w_local[s : s + step] * v
                    wsums = wsums + jax.vmap(seg)(per_id * w[None, :, None], idx)
                    wcounts = wcounts + jax.vmap(seg)(
                        jnp.tile(w[None], (self.c, 1)), idx
                    )
            return jax.lax.psum((sums, cnts, wsums, wcounts), axis_name)

        sums, cnts, wsums, wcounts = compat.shard_map_unchecked(
            per_shard, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name), P(axis_name),
                      P(None, axis_name), P(None, axis_name)),
            out_specs=(P(), P(), P(), P()),
        )(ids, valid, w_pad, old_ptr, new_ptr)
        if counts is None:
            counts = cnts
        mean = sums / jnp.maximum(counts[..., None], 1.0)
        if weighted:
            wmean = wsums / jnp.maximum(wcounts[..., None], 1e-12)
            mean = jnp.where(wcounts[..., None] > 0, wmean, mean)
        mean = mean.astype(mt.dtype)
        return {"tables": jnp.stack([mean, jnp.zeros_like(mean)], axis=1)}

    # --- diagnostics (Appendix H) ----------------------------------------

    def collapse_entropies(self, buffers) -> dict[str, float]:
        """H1 (min column entropy) and H2 (min pairwise entropy) of the
        learned pointer table — the paper's table-collapse detectors.

        H1 near log(k): healthy spread.  H1 near 0: column collapse.
        H2 much below 2*log(k) (and below H1 + log(k)): pairwise collapse
        (one column is a permutation of another).
        """
        ptr = np.asarray(buffers["ptr"])  # (c, d1)
        c = ptr.shape[0]

        def entropy(vals):
            _, counts = np.unique(vals, return_counts=True)
            p = counts / counts.sum()
            return float(-(p * np.log(p)).sum())

        h1 = min(entropy(ptr[i]) for i in range(c))
        h2 = math.inf
        for i in range(c):
            for j in range(i + 1, c):
                pair = ptr[i].astype(np.int64) * (ptr[j].max() + 1) + ptr[j]
                h2 = min(h2, entropy(pair))
        return {"H1": h1, "H2": h2 if c > 1 else float("nan"), "max_H1": math.log(self.k)}

    def sketch_matrix(self, buffers) -> np.ndarray:
        """Dense H (d1, c*2k) for tests: one 1 per (column, table) block."""
        ptr = np.asarray(buffers["ptr"])
        helper = np.asarray(self._helper_rows(buffers, jnp.arange(self.d1)))
        H = np.zeros((self.d1, self.c * 2 * self.k), np.float32)
        rows = np.arange(self.d1)
        for i in range(self.c):
            base = i * 2 * self.k
            H[rows, base + ptr[i]] = 1.0
            H[rows, base + self.k + helper[i]] += 1.0
        return H
