"""Clustered Compositional Embeddings — Algorithm 3 of the paper.

A CCE table with vocabulary ``d1``, output dim ``d2``, ``c`` columns and
``2k`` rows per column (main table M indexed by a *learned* pointer array
``h`` + helper table M' indexed by a *random* hash ``h'``):

    lookup(id) = concat_i( M_i[h_i(id)] + M'_i[h'_i(id)] )

``cluster()`` is the paper's training-time transition (Alg. 3, lines 10-17):
per column, materialize (a sample of) the current vocab embeddings, K-means
them into k centroids, set ``h_i <- assignments``, ``M_i <- centroids``,
draw a fresh random ``h'_i`` and zero ``M'_i``.  The helper table restores
the ability to differentiate ids the clustering merged; the next clustering
can undo bad merges.

State layout (chosen for the TPU kernels and for sharding):

    params["tables"] : (c, 2, k, dsub)  — [:,0] main M, [:,1] helper M'
    buffers["ptr"]   : (c, d1) int32    — learned pointer arrays h_i
    buffers["hs"]    : c × (a, b)       — multiply-shift coeffs for h'_i

The pointer arrays are plain int32 tensors: on a pod they are host-resident
and ride the input pipeline (ids are translated to per-column rows on host,
see DESIGN.md §4); on a single device they are gathered on device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core import kmeans as km
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class CCE:
    """Algorithm 3: CCE table with ``c`` columns and ``2k`` rows/column."""

    d1: int
    d2: int
    k: int
    c: int = 4
    seed_salt: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert self.d2 % self.c == 0, (self.d2, self.c)
        assert self.k >= 1

    @classmethod
    def from_budget(cls, d1, d2, budget, c=4, **kw):
        # 2 tables of (k, d2/c) per column -> 2*k*d2 params total
        k = max(1, min(d1, budget // (2 * d2)))
        return cls(d1, d2, k=k, c=c, **kw)

    @property
    def dsub(self) -> int:
        return self.d2 // self.c

    @property
    def n_params(self) -> int:
        return 2 * self.k * self.d2

    # --- init -----------------------------------------------------------

    def init_buffers(self):
        """Device-free buffer init (numpy): hash coefficients derive from
        ``seed_salt`` so abstract (eval_shape) and real inits agree, and the
        pointer table never touches a device mesh."""
        ptr_hashes = hashing.make_hashes(self.seed_salt * 7919 + 66, self.c, self.k)
        ids = np.arange(self.d1)
        ptr = np.stack([h.np(ids) for h in ptr_hashes])  # (c, d1) int32
        hs = tuple(
            (h.a, h.b)
            for h in hashing.make_hashes(self.seed_salt * 7919 + 77, self.c, self.k)
        )
        return {"ptr": ptr, "hs": hs, "epoch": 0}

    def init(self, key):
        km_ = jax.random.fold_in(key, self.seed_salt)
        scale = 1.0 / math.sqrt(self.d2)
        tables = (
            jax.random.normal(km_, (self.c, 2, self.k, self.dsub)) * scale
        ).astype(self.dtype)
        buffers = self.init_buffers()
        return {"tables": tables}, dict(buffers, ptr=jnp.asarray(buffers["ptr"]))

    # --- lookup ---------------------------------------------------------

    def _helper_rows(self, buffers, ids):
        return jnp.stack(
            [
                hashing.MultiplyShiftHash(int(a), int(b), self.k)(ids)
                for (a, b) in buffers["hs"]
            ]
        )  # (c, ...)

    def _rows(self, buffers, ids):
        """(c, ..., 2) int32 — main rows from the learned ptr, helper rows
        from the random hash."""
        main = buffers["ptr"][:, ids]  # (c, ...)
        helper = self._helper_rows(buffers, ids)
        return jnp.stack([main, helper], axis=-1)

    def lookup(self, params, buffers, ids, *, use_kernel: bool = False):
        rows = self._rows(buffers, ids)  # (c, ..., 2)
        if use_kernel:
            flat = rows.reshape(self.c, -1, 2)
            out = kops.cce_lookup(flat, params["tables"])  # (B, c*dsub)
            return out.reshape(*ids.shape, self.d2)
        tabs = params["tables"]  # (c, 2, k, dsub)
        main = jax.vmap(lambda t, r: t[r])(tabs[:, 0], rows[..., 0])
        helper = jax.vmap(lambda t, r: t[r])(tabs[:, 1], rows[..., 1])
        pieces = main + helper  # (c, ..., dsub)
        return jnp.moveaxis(pieces, 0, -2).reshape(*ids.shape, self.d2)

    def logits(self, params, buffers, h):
        """Factored output head: per column a k-sized matmul + int gather.

        logits[b, v] = sum_i  scores_i[b, h_i(v)] + scores'_i[b, h'_i(v)]
        where scores_i = h_col_i @ M_i^T   (B, k).
        """
        hc = h.reshape(*h.shape[:-1], self.c, self.dsub)
        all_ids = jnp.arange(self.d1)
        rows = self._rows({"ptr": buffers["ptr"], "hs": buffers["hs"]}, all_ids)
        out = 0.0
        for i in range(self.c):
            scores = hc[..., i, :] @ params["tables"][i].reshape(
                2 * self.k, self.dsub
            ).T  # (..., 2k)
            out = out + scores[..., rows[i, :, 0]]
            out = out + scores[..., self.k + rows[i, :, 1]]
        return out

    # --- the clustering transition (Alg. 3 lines 10-17) ------------------

    def materialize(self, params, buffers, ids):
        """Current embeddings of ``ids``, per column: (c, n, dsub)."""
        rows = self._rows(buffers, ids)
        tabs = params["tables"]
        return jax.vmap(lambda t, r: t[r])(
            tabs[:, 0], rows[..., 0]
        ) + jax.vmap(lambda t, r: t[r])(tabs[:, 1], rows[..., 1])

    def cluster(
        self,
        key,
        params,
        buffers,
        *,
        sample_ids: jax.Array | None = None,
        niter: int = 50,
        max_points_per_centroid: int = 256,
    ):
        """One CCE iteration: returns new (params, buffers).

        K-means runs on a sample (FAISS-style, 256 pts/centroid by default,
        paper §Reproducibility); assignments for the FULL vocab are then one
        nearest-centroid pass per column.
        """
        k1, k2 = jax.random.split(jax.random.fold_in(key, buffers["epoch"]))
        if sample_ids is None:
            idx = km.subsample(k1, self.d1, self.k, max_points_per_centroid)
            sample_ids = jnp.arange(self.d1)[idx] if idx.shape[0] != self.d1 else idx

        sample = self.materialize(params, buffers, sample_ids)  # (c, n, dsub)
        new_tables = []
        new_ptr = []
        all_ids = jnp.arange(self.d1)
        for i in range(self.c):
            res = km.kmeans(jax.random.fold_in(k2, i), sample[i], self.k, niter=niter)
            # full-vocab assignment against the final centroids
            full = self.materialize(params, buffers, all_ids)[i]
            assignments = km.assign(full, res.centroids)
            new_ptr.append(assignments)
            helper = jnp.zeros((self.k, self.dsub), self.dtype)
            new_tables.append(
                jnp.stack([res.centroids.astype(self.dtype), helper])
            )
        # fresh random helper hashes
        hs = tuple(
            (h.a, h.b)
            for h in hashing.make_hashes(
                jax.random.fold_in(k2, 777), self.c, self.k
            )
        )
        params = {"tables": jnp.stack(new_tables)}
        buffers = {
            "ptr": jnp.stack(new_ptr),
            "hs": hs,
            "epoch": buffers["epoch"] + 1,
        }
        return params, buffers

    # --- diagnostics (Appendix H) ----------------------------------------

    def collapse_entropies(self, buffers) -> dict[str, float]:
        """H1 (min column entropy) and H2 (min pairwise entropy) of the
        learned pointer table — the paper's table-collapse detectors.

        H1 near log(k): healthy spread.  H1 near 0: column collapse.
        H2 much below 2*log(k) (and below H1 + log(k)): pairwise collapse
        (one column is a permutation of another).
        """
        ptr = np.asarray(buffers["ptr"])  # (c, d1)
        c = ptr.shape[0]

        def entropy(vals):
            _, counts = np.unique(vals, return_counts=True)
            p = counts / counts.sum()
            return float(-(p * np.log(p)).sum())

        h1 = min(entropy(ptr[i]) for i in range(c))
        h2 = math.inf
        for i in range(c):
            for j in range(i + 1, c):
                pair = ptr[i].astype(np.int64) * (ptr[j].max() + 1) + ptr[j]
                h2 = min(h2, entropy(pair))
        return {"H1": h1, "H2": h2 if c > 1 else float("nan"), "max_H1": math.log(self.k)}

    def sketch_matrix(self, buffers) -> np.ndarray:
        """Dense H (d1, c*2k) for tests: one 1 per (column, table) block."""
        ptr = np.asarray(buffers["ptr"])
        helper = np.asarray(self._helper_rows(buffers, jnp.arange(self.d1)))
        H = np.zeros((self.d1, self.c * 2 * self.k), np.float32)
        rows = np.arange(self.d1)
        for i in range(self.c):
            base = i * 2 * self.k
            H[rows, base + ptr[i]] = 1.0
            H[rows, base + self.k + helper[i]] += 1.0
        return H
