"""Nearest-centroid assignment as a Pallas TPU kernel.

The inner loop of the paper's clustering step (Algorithm 3 line 13).
``argmin_j ||x - c_j||^2`` expands to ``argmin_j (||c_j||^2 - 2 <x, c_j>)``
(the ``||x||^2`` term is constant in j), i.e. a blocked X @ C.T on the MXU
fused with a running (min, argmin) accumulator — only the (n,) assignment
vector ever leaves the kernel, the (n, k) distance matrix is never
materialized in HBM.

Grid: (n/n_blk, k/k_blk), k innermost; running best distance + index are
carried in the two output refs (revisited across the k axis).

VMEM per step (defaults n_blk=256, k_blk=512, d<=512 f32): x tile 512 KiB,
c tile 1 MiB, outputs 2 KiB — double-buffers comfortably in 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import pallas as pl

DEFAULT_N_BLK = 256
DEFAULT_K_BLK = 512


def _kernel(x_ref, c_ref, cn_ref, best_ref, arg_ref, *, k_blk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    x = x_ref[...].astype(jnp.float32)  # (n_blk, d)
    c = c_ref[...].astype(jnp.float32)  # (k_blk, d)
    cn = cn_ref[...].astype(jnp.float32)  # (k_blk, 1) precomputed ||c||^2
    # partial squared distance (missing ||x||^2, constant in j)
    d2 = cn[:, 0][None, :] - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    local_best = jnp.min(d2, axis=-1)  # (n_blk,)
    local_arg = jnp.argmin(d2, axis=-1).astype(jnp.int32) + j * k_blk
    prev_best = best_ref[:, 0]
    prev_arg = arg_ref[:, 0]
    take_new = local_best < prev_best
    best_ref[:, 0] = jnp.where(take_new, local_best, prev_best)
    arg_ref[:, 0] = jnp.where(take_new, local_arg, prev_arg)


def kmeans_assign_pallas(
    x: jax.Array,
    centroids: jax.Array,
    *,
    n_blk: int = DEFAULT_N_BLK,
    k_blk: int = DEFAULT_K_BLK,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x (n, d), centroids (k, d) -> (assignments (n,) int32, partial-d2 (n,)).

    n % n_blk == 0 and k % k_blk == 0 required (`ops.kmeans_assign` pads).
    """
    n, d = x.shape
    k, _ = centroids.shape
    assert n % n_blk == 0 and k % k_blk == 0, (n, n_blk, k, k_blk)
    cn = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    grid = (n // n_blk, k // k_blk)
    best, arg = pl.pallas_call(
        functools.partial(_kernel, k_blk=k_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, d), lambda i, j: (i, 0)),
            pl.BlockSpec((k_blk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((k_blk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_blk, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((n_blk, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids, cn)
    return arg[:, 0], best[:, 0]
