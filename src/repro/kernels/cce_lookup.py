"""Fused CCE multi-column embedding lookup as a Pallas TPU kernel.

TPU adaptation of the paper's hot loop (`concat_i M_i[h_i(id)] + M'_i[h'_i(id)]`,
Algorithm 3 line 8).  GPUs do this with a memory-bound sparse gather; TPUs
have no fast random gather but a 128x128 systolic MXU, so we express the
gather as a *blocked one-hot matmul*:

    M[idx]  ==  onehot(idx) @ M

The one-hot block ``(B_blk, k_blk)`` is built in-register from an
``iota == idx`` comparison (it never exists in HBM), multiplied against an
``M`` tile staged in VMEM by the BlockSpec pipeline, and accumulated over
k-blocks.  The CCE sum over the main + helper table fuses into the same
accumulation loop, so the 2c gathers of Algorithm 3 are a single kernel
launch.  The backward scatter-add is the transposed matmul
``onehot.T @ dout`` — same trick, and deterministic (no GPU-style atomics).

The kernel is TABLE-COUNT-GENERIC: T is any stacked sub-table count
(T=2 CCE, T=1 CE-concat / hashed / full tables), and a NEGATIVE row index
is a free no-op sentinel — ``local == iota`` never matches, so the lane
contributes exactly zero forward and exactly zero backward.  That is what
lets the ``EmbeddingCollection`` fuse methods with different T into ONE
supertable launch (a T=1 method pads its row tensor with -1; see
DESIGN.md §6) without masks or extra branches in the kernel.

Grid: (c columns, B/B_blk batch blocks, k/k_blk codebook blocks); the
k axis is innermost so the output block revisits and accumulates.

VMEM working set per step (defaults B_blk=256, k_blk=512, dsub<=512 f32):
  tables tile  T*k_blk*dsub*4  = 2*512*128*4  = 512 KiB
  out tile     B_blk*dsub*4    = 256*128*4    = 128 KiB
  idx tile     B_blk*T*4       = 2 KiB          (SMEM-resident scalars)
well under the ~16 MiB/core VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import pallas as pl


DEFAULT_B_BLK = 256
DEFAULT_K_BLK = 512


def _fwd_kernel(idx_ref, tab_ref, out_ref, *, k_blk: int, n_tables: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[0]  # (B_blk, T) int32, global row ids
    local = idx - j * k_blk  # row ids relative to this k block
    b_blk = idx.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (b_blk, k_blk), 1)
    acc = jnp.zeros((b_blk, out_ref.shape[-1]), jnp.float32)
    for t in range(n_tables):
        onehot = (local[:, t : t + 1] == iota).astype(tab_ref.dtype)
        acc += jnp.dot(
            onehot, tab_ref[0, t], preferred_element_type=jnp.float32
        )
    out_ref[...] += acc[:, None, :].astype(out_ref.dtype)


def _bwd_kernel(idx_ref, dout_ref, dtab_ref, *, k_blk: int):
    """dM[i, t] = onehot(idx[i,:,t]).T @ dout[:, i] — grid (c, T, nk, nb)."""
    b = pl.program_id(3)

    @pl.when(b == 0)
    def _init():
        dtab_ref[...] = jnp.zeros_like(dtab_ref)

    t = pl.program_id(1)
    j = pl.program_id(2)
    idx = idx_ref[0, :, t]  # (B_blk,)
    local = idx - j * k_blk
    b_blk = idx.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (b_blk, k_blk), 1)
    onehot = (local[:, None] == iota).astype(dout_ref.dtype)  # (B_blk, k_blk)
    dout = dout_ref[:, 0, :]  # (B_blk, dsub)
    dtab_ref[0, 0] += jnp.dot(
        onehot.T, dout, preferred_element_type=jnp.float32
    ).astype(dtab_ref.dtype)


def cce_lookup_fwd_pallas(
    idx: jax.Array,
    tables: jax.Array,
    *,
    b_blk: int = DEFAULT_B_BLK,
    k_blk: int = DEFAULT_K_BLK,
    interpret: bool = False,
) -> jax.Array:
    """Forward lookup.  idx (c, B, T) int32; tables (c, T, k, dsub).

    Returns (B, c, dsub).  B % b_blk == 0 and k % k_blk == 0 are required —
    `ops.cce_lookup` pads.
    """
    c, B, T = idx.shape
    _, _, k, dsub = tables.shape
    assert B % b_blk == 0 and k % k_blk == 0, (B, b_blk, k, k_blk)
    grid = (c, B // b_blk, k // k_blk)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, k_blk=k_blk, n_tables=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b_blk, T), lambda i, b, j: (i, b, 0)),
            pl.BlockSpec((1, T, k_blk, dsub), lambda i, b, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, 1, dsub), lambda i, b, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, c, dsub), tables.dtype),
        interpret=interpret,
    )(idx, tables)


def cce_lookup_bwd_pallas(
    idx: jax.Array,
    dout: jax.Array,
    k: int,
    *,
    b_blk: int = DEFAULT_B_BLK,
    k_blk: int = DEFAULT_K_BLK,
    interpret: bool = False,
) -> jax.Array:
    """Backward scatter-add.  idx (c, B, T); dout (B, c, dsub) -> dtables
    (c, T, k, dsub)."""
    c, B, T = idx.shape
    dsub = dout.shape[-1]
    assert B % b_blk == 0 and k % k_blk == 0
    grid = (c, T, k // k_blk, B // b_blk)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, k_blk=k_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b_blk, T), lambda i, t, j, b: (i, b, 0)),
            pl.BlockSpec((b_blk, 1, dsub), lambda i, t, j, b: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, k_blk, dsub), lambda i, t, j, b: (i, t, j, 0)),
        out_shape=jax.ShapeDtypeStruct((c, T, k, dsub), dout.dtype),
        interpret=interpret,
    )(idx, dout)
