"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cce_lookup_ref(idx: jax.Array, tables: jax.Array) -> jax.Array:
    """Reference for the fused multi-column gather-sum.

    Args:
      idx:    (c, B, T) int32 — per column, per batch element, T row indices
              (T=2 for CCE main+helper, T=1 for plain CE-concat / hashed /
              full tables).  A NEGATIVE index is the sentinel for "no
              sub-table here" (a T=1 method riding a T=2 supertable): it
              matches no one-hot lane, so it contributes exactly zero
              forward and receives exactly zero gradient.
      tables: (c, T, k, dsub) — per column, T tables of k rows.

    Returns:
      (B, c * dsub): concat over columns of sum over tables of gathered rows.
    """
    c, B, T = idx.shape
    _, _, k, dsub = tables.shape
    # out[i, b] = sum_t [idx >= 0] * tables[i, t, idx[i, b, t]]
    gathered = jax.vmap(  # over columns
        lambda ti, ii: sum(
            ti[t][jnp.maximum(ii[:, t], 0)] * (ii[:, t] >= 0)[:, None].astype(ti.dtype)
            for t in range(T)
        )
    )(tables, idx)  # (c, B, dsub)
    return jnp.transpose(gathered, (1, 0, 2)).reshape(B, c * dsub)


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Reference nearest-centroid assignment.

    Args:
      x: (n, d); centroids: (k, d).
    Returns:
      (n,) int32 argmin_j ||x - c_j||^2  (ties -> lowest index).
    """
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(x * x, -1, keepdims=True)
        + jnp.sum(c * c, -1)[None, :]
        - 2.0 * x @ c.T
    )
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """Reference for the flash-attention kernel: dense causal GQA SDPA.

    q (B, Sq, H, D); k/v (B, S, KVH, D) -> (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    k = jnp.repeat(k.astype(jnp.float32), G, axis=2)
    v = jnp.repeat(v.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k) / (D ** 0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v).astype(q.dtype)


def cce_logits_ref(h: jax.Array, idx: jax.Array, tables: jax.Array) -> jax.Array:
    """Reference for the factored CCE logits head (beyond-paper extension).

    logits[b, v] = <h[b], E[v]> where E[v] = concat_i sum_t tables[i,t,idx[i,v,t]].

    Args:
      h:      (B, c * dsub) activations.
      idx:    (c, V, T) pointer arrays over the vocab.
      tables: (c, T, k, dsub).
    Returns:
      (B, V) logits.
    """
    c, V, T = idx.shape
    _, _, k, dsub = tables.shape
    B = h.shape[0]
    hc = h.reshape(B, c, dsub)
    out = jnp.zeros((B, V), jnp.float32)
    for i in range(c):
        scores = hc[:, i].astype(jnp.float32) @ tables[i].astype(jnp.float32).reshape(
            T * k, dsub
        ).T  # (B, T*k)
        for t in range(T):
            out = out + scores[:, t * k : (t + 1) * k][:, idx[i, :, t]]
    return out
