"""Public jit'd wrappers for the Pallas kernels.

Handles: shape padding to block multiples, interpret-mode fallback on CPU
(this container validates kernels with interpret=True; on TPU the same
code path compiles to Mosaic), and custom VJPs (the backward of the
one-hot-matmul gather is the transposed one-hot matmul — a deterministic
scatter-add on the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cce_lookup as _cl
from repro.kernels import kmeans_assign as _ka


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# --- cce_lookup ---------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _cce_lookup(idx: jax.Array, tables: jax.Array, b_blk: int, k_blk: int):
    return _cce_lookup_fwd(idx, tables, b_blk, k_blk)[0]


def _cce_lookup_fwd(idx, tables, b_blk, k_blk):
    c, B, T = idx.shape
    _, _, k, dsub = tables.shape
    B_pad = _round_up(B, b_blk)
    k_pad = _round_up(k, k_blk)
    idx_p = jnp.pad(idx, ((0, 0), (0, B_pad - B), (0, 0)))
    tab_p = jnp.pad(tables, ((0, 0), (0, 0), (0, k_pad - k), (0, 0)))
    out = _cl.cce_lookup_fwd_pallas(
        idx_p, tab_p, b_blk=b_blk, k_blk=k_blk, interpret=_on_cpu()
    )  # (B_pad, c, dsub)
    out = out[:B].reshape(B, c * dsub)
    return out, (idx, k, jnp.zeros((0,), tables.dtype))


def _cce_lookup_bwd(b_blk, k_blk, res, g):
    idx, k, dtype_token = res
    tdtype = dtype_token.dtype
    c, B, T = idx.shape
    dsub = g.shape[-1] // c
    B_pad = _round_up(B, b_blk)
    k_pad = _round_up(k, k_blk)
    idx_p = jnp.pad(idx, ((0, 0), (0, B_pad - B), (0, 0)))
    g_p = jnp.pad(
        g.reshape(B, c, dsub).astype(tdtype), ((0, B_pad - B), (0, 0), (0, 0))
    )
    # padded batch rows all point at row 0 — mask their contribution by
    # zeroing the padded gradient rows (jnp.pad already zero-fills).
    dtab = _cl.cce_lookup_bwd_pallas(
        idx_p, g_p, k_pad, b_blk=b_blk, k_blk=k_blk, interpret=_on_cpu()
    )[:, :, :k, :]
    zero_idx = np.zeros(idx.shape, jax.dtypes.float0)
    return (zero_idx, dtab)


_cce_lookup.defvjp(_cce_lookup_fwd, _cce_lookup_bwd)


def cce_lookup(
    idx: jax.Array,
    tables: jax.Array,
    *,
    b_blk: int = _cl.DEFAULT_B_BLK,
    k_blk: int | None = None,
) -> jax.Array:
    """Fused multi-table gather-sum: (c, B, T) idx + (c, T, k, dsub) tables
    -> (B, c*dsub) embeddings.  Differentiable w.r.t. ``tables``.

    Table-count-generic (any T) with the -1 no-op row sentinel (zero
    forward contribution, zero gradient) — the universal-fusion contract
    (see kernels/cce_lookup.py and DESIGN.md §6)."""
    k = tables.shape[2]
    if k_blk is None:
        k_blk = min(_cl.DEFAULT_K_BLK, _round_up(k, 128))
    b_blk = min(b_blk, _round_up(idx.shape[1], 8))
    return _cce_lookup(idx, tables, b_blk, k_blk)


def pad_stack_tables(slabs, *, k_pad: int | None = None) -> jax.Array:
    """Ragged group stacking for the ``EmbeddingCollection`` supertable.

    Per-feature table slabs (c_f, T, k_f, dsub) — same T/dsub, ragged
    codebook size k_f — concatenate along columns into a single
    (sum c_f, T, max k_f, dsub) supertable, zero-padding the codebook
    axis.  The contract that makes the padding free: row ids into column
    f are always < k_f (learned pointers and helper hashes are both
    mod-k_f), so padded rows are never touched by the forward one-hot
    and receive exactly-zero gradient from the backward scatter-add.
    ``cce_lookup`` then pads max k_f up to the k_blk multiple on top.
    """
    k_pad = k_pad or max(s.shape[2] for s in slabs)
    return jnp.concatenate(
        [
            jnp.pad(s, ((0, 0), (0, 0), (0, k_pad - s.shape[2]), (0, 0)))
            for s in slabs
        ],
        axis=0,
    )


# --- flash attention ----------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True,
                    bq: int | None = None, bk: int | None = None):
    """Pallas flash attention (see kernels/flash_attention.py).  q (B,Sq,H,D),
    k/v (B,S,KVH,D) -> (B,Sq,H,D).  Pads Sq/S to block multiples."""
    from repro.kernels import flash_attention as _fa

    B, Sq, H, D = q.shape
    S = k.shape[1]
    bq = bq or min(_fa.DEFAULT_BQ, _round_up(Sq, 128))
    bk = bk or min(_fa.DEFAULT_BK, _round_up(S, 128))
    Sq_p, S_p = _round_up(Sq, bq), _round_up(S, bk)
    q_p = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    # padded kv rows must never win the softmax: causal masking already
    # excludes them for q < Sq when S_p == Sq_p (the causal contract here)
    out = _fa.flash_attention_pallas(
        q_p, k_p, v_p, causal=causal, bq=bq, bk=bk, interpret=_on_cpu()
    )
    return out[:, :Sq]


# --- kmeans_assign ------------------------------------------------------------

_PAD_CENTROID = 1e15  # ||pad||^2 ~ 1e30 * d — never the argmin, no inf-inf NaNs


def kmeans_assign(
    x: jax.Array,
    centroids: jax.Array,
    *,
    n_blk: int = _ka.DEFAULT_N_BLK,
    k_blk: int | None = None,
) -> jax.Array:
    """(n, d) points, (k, d) centroids -> (n,) int32 nearest-centroid ids."""
    n, d = x.shape
    k = centroids.shape[0]
    if k_blk is None:
        k_blk = min(_ka.DEFAULT_K_BLK, _round_up(k, 128))
    n_blk = min(n_blk, _round_up(n, 8))
    n_pad = _round_up(n, n_blk)
    k_pad = _round_up(k, k_blk)
    x_p = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    c_p = jnp.pad(
        centroids, ((0, k_pad - k), (0, 0)), constant_values=_PAD_CENTROID
    )
    arg, _ = _ka.kmeans_assign_pallas(
        x_p, c_p, n_blk=n_blk, k_blk=k_blk, interpret=_on_cpu()
    )
    return arg[:n]
