"""Flash attention (causal, GQA) as a Pallas TPU kernel.

The §Perf analysis (EXPERIMENTS.md) shows the dominant HBM-traffic term of
every *_train cell is the f32 S^2 score/softmax chain — ~3.2 TB/step/chip
on qwen3-14b train_4k, 40-50% of the memory roofline term.  XLA cannot fix
this: the online-softmax rewrite is not expressible as a fusion of the
dense graph (verified: a chunked lax.scan formulation still materializes
every per-chunk block at instruction boundaries).  A kernel is the
mechanism: scores live in VMEM registers only, HBM sees Q, K, V, O exactly
once.

Layout: grid (batch*q_heads, Sq/bq).  Per grid step the q block (bq, D)
and the FULL per-head K/V (S, D) are staged in VMEM (bf16 at S=32k, D=128:
8 MB both — within the 16 MB budget; longer sequences stream K/V with a
third grid axis).  The kv loop runs online softmax with f32 accumulators
in VMEM scratch.

Validated in interpret mode against ref.flash_attention_ref over
shape/dtype sweeps (tests/test_kernels_flash.py); on TPU the same
pallas_call compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import pallas as pl

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, scale: float, causal: bool,
            q_offset_den: int):
    # q_ref (bq, D); k_ref/v_ref (S, D); o_ref (bq, D)
    bq, D = q_ref.shape
    S = k_ref.shape[0]
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    nk = S // bk

    def body(j, carry):
        acc, m, ell = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos[:, None] >= k_pos, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - safe[:, None]), 0.0)
        ell = ell * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc, m_new, ell

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # causal: kv blocks beyond this q block never contribute — bound the loop
    # (program_id is traced: ceil-div in lax arithmetic)
    hi = nk if not causal else jnp.minimum(((qi + 1) * bq + bk - 1) // bk, nk)
    acc, m, ell = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(ell, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, S, KVH, D) -> (B, Sq, H, D).

    GQA: query head h reads kv head h // (H // KVH).  Sq % bq == 0 and
    S % bk == 0 required (ops.flash_attention pads).
    """
    B, Sq, H, D = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    bq = min(bq, Sq)
    bk = min(bk, S)
    assert Sq % bq == 0 and S % bk == 0
    scale = 1.0 / (D ** 0.5)
    # (B*H, S, D) layouts; kv head index derived from the fused b*h axis
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, S, D)

    grid = (B * H, Sq // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, scale=scale, causal=causal,
                          q_offset_den=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, D), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, S, D), lambda bh, i: (bh // G, 0, 0)),
            pl.BlockSpec((None, S, D), lambda bh, i: (bh // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, D), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
