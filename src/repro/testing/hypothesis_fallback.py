"""Deterministic mini-`hypothesis`, used when the real package is absent.

pyproject.toml declares `hypothesis` as a test dependency, but hermetic
containers (and minimal CI lanes) may not have it installed — and the
property tests should still COLLECT and RUN there rather than error the
whole suite.  `tests/conftest.py` installs this module into
``sys.modules["hypothesis"]`` as a fallback.

Scope: exactly the API surface this repo's tests use —
``@given`` (positional or keyword strategies), ``@settings(max_examples=,
deadline=)``, ``strategies.integers`` and ``strategies.sampled_from``.
Each property runs on a fixed-seed sample that always includes the
all-min and all-max corner, then uniform draws — strictly weaker than
hypothesis's adaptive search + shrinking, strictly stronger than skipping
the tests.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

DEFAULT_MAX_EXAMPLES = 20
_ATTR = "_fallback_max_examples"


class SearchStrategy:
    def __init__(self, draw, lo=None, hi=None):
        self._draw = draw
        self.lo = lo  # corner values (None: no meaningful corner)
        self.hi = hi

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value), min_value, max_value
    )


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))], seq[0], seq[-1])


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            setattr(fn, _ATTR, max_examples)
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*fixture_args, **fixture_kw):
            n = getattr(runner, _ATTR, getattr(fn, _ATTR, DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0xC0FFEE)
            for i in range(n):
                if i == 0:  # corners first: the bugs property tests exist for
                    args = [s.lo for s in arg_strategies]
                    kw = {k: s.lo for k, s in kw_strategies.items()}
                elif i == 1:
                    args = [s.hi for s in arg_strategies]
                    kw = {k: s.hi for k, s in kw_strategies.items()}
                else:
                    args = [s.example(rng) for s in arg_strategies]
                    kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*fixture_args, *args, **fixture_kw, **kw)

        # pytest must not see the strategy-bound parameters as fixtures:
        # like hypothesis, expose only the leftovers (pytest fixtures).
        # Positional strategies bind the RIGHTMOST params, kw by name.
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[: -len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        runner.__signature__ = inspect.Signature(params)
        del runner.__wrapped__  # or inspect ignores __signature__
        return runner

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from
strategies.SearchStrategy = SearchStrategy
