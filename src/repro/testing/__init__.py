"""Test-support utilities (deterministic property-testing fallback)."""
