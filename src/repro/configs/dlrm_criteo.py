"""DLRM on (synthetic) Criteo — the paper's own architecture (config #11).

26 categorical features with Criteo-Kaggle-like vocabulary spread (three
decades of sizes, a few multi-million-row tables dominating memory), 13
dense features, emb_dim 16, SGD — per Naumov et al. 2019 / the paper §4.1.
At full scale the 26 tables hold ~540M embedding rows; the CCE cap below
reproduces the paper's compressed operating point.
"""
from repro.models.dlrm import DLRMConfig
from repro.stream import StreamConfig

# Criteo Kaggle vocab sizes (the published counts, descending spread)
CRITEO_KAGGLE_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
)

CONFIG = DLRMConfig(
    vocab_sizes=CRITEO_KAGGLE_VOCABS,
    n_dense=13,
    emb_dim=16,
    bottom_mlp=(512, 256, 64, 16),
    top_mlp=(512, 256, 1),
    emb_method="cce",
    emb_param_cap=8000,  # the paper's Fig. 4a operating point
)


# Streaming frequency statistics at Criteo scale (DESIGN.md §5): the
# dense tracker would hold one int64 per vocab row (~270 MB over the 26
# Kaggle features, and ~6.4 GB at Terabyte scale — a second full-vocab
# array, defeating CCE's point); the sketch tracker holds
# O(width·depth + heavy + ring) per CCE feature (~13 MB total here)
# REGARDLESS of vocabulary.  The head is exact (4096 heavy hitters per
# feature); the 16k-cell conservative-update rows only have to rank the
# tail.  One window ≈ 256 batches; decay 0.95/window ≈ a half-life of
# ~13 windows, so the histogram tracks the recent stream and the
# entropy/drift trigger can see shift.
STREAM = StreamConfig(
    width=1 << 14, depth=4, heavy=4096, ring=1 << 14,
    decay=0.95, window=256, async_fold=True,
)


def reduced(emb_method: str = "cce", cap: int = 512,
            k_multiple: int = 1) -> DLRMConfig:
    """Small synthetic-Criteo config for CPU training runs.

    ``k_multiple`` is the model-parallel shard count the supertable
    codebook axis must divide by (sharded trainers pass the model mesh
    size; the layouts stay bit-interconvertible — see DLRMConfig)."""
    return DLRMConfig(
        vocab_sizes=(1000, 5000, 20000, 100, 50000),
        n_dense=13,
        emb_dim=16,
        bottom_mlp=(64, 32, 16),
        top_mlp=(64, 1),
        emb_method=emb_method,
        emb_param_cap=cap,
        emb_k_multiple=k_multiple,
    )


def reduced_stream(window: int = 8, *, async_fold: bool = False) -> StreamConfig:
    """Sketch-tracker shape matched to ``reduced()``'s vocabs — big enough
    that head+tail statistics are faithful at CPU test scale, small enough
    to stay obviously vocab-independent."""
    return StreamConfig(
        width=1 << 11, depth=4, heavy=128, ring=2048,
        decay=0.9, window=window, async_fold=async_fold,
    )
