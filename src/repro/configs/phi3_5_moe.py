"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8,
head_dim=128) expert d_ff=6400 vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    emb_method="cce",
    emb_budget=32064 * 4096 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=16,
    moe_group=2048,
)
