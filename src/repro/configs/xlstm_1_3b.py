"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM +
mLSTM blocks at the xLSTM[7:1] ratio (1 sLSTM per 8 blocks).
[arXiv:2405.04517; unverified]

No KV cache at all — decode state is O(1) in sequence length, so this
arch runs long_500k natively.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,
    pos_emb="none",
    emb_method="cce",
    emb_budget=50304 * 2048 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=32,
)
