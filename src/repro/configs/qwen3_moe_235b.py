"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4,
head_dim=128) expert d_ff=1536 vocab=151936, MoE 128 experts top-8,
qk_norm.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    n_experts=128,
    top_k=8,
    capacity_factor=1.25,
    qk_norm=True,
    rope_theta=1_000_000.0,
    emb_method="cce",
    emb_budget=151936 * 4096 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=8,
    moe_group=2048,
)
