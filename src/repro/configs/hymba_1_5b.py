"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
ssm_state=16.  [arXiv:2411.13676; hf]

Hymba runs sliding-window attention in most layers (the SSM branch carries
global context), which is what makes it eligible for long_500k decode:
O(window) KV cache + O(1) SSM state per token.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    rope_theta=10_000.0,
    # the paper's technique on the vocab table: 16x compression budget
    emb_method="cce",
    emb_budget=32001 * 1600 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=32,
)
