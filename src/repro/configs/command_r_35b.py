"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000; GQA, no-bias, parallel attention+FFN blocks, LayerNorm.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    parallel_block=True,
    norm="layernorm",
    rope_theta=8_000_000.0,
    emb_method="cce",
    emb_budget=256000 * 8192 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=16,
)
