"""musicgen-medium [audio] — decoder-only over EnCodec tokens: 48L
d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 per codebook, 4
codebooks.  [arXiv:2306.05284; hf]

Backbone only per the brief: the EnCodec frontend is a stub — inputs are
the 4 codebook token streams (delay pattern applied upstream); the model
sums the 4 codebook embeddings per frame and predicts all 4 codebooks with
separate heads.  Vanilla transformer details: LayerNorm, GELU, sinusoidal
positions.

Note: vocab 2048 x 4 codebooks = 8192 effective rows — CCE is applicable
but pointless at this size (compression ~1x); config keeps the full table
(DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
    norm="layernorm",
    act="gelu",
    pos_emb="sinusoidal",
    emb_method="full",
    dtype=jnp.bfloat16,
    train_microbatch=32,
)
