"""Config registry: ``get(name)`` -> full-size ModelConfig;
``get_reduced(name)`` -> CPU smoke-test variant of the same family."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    command_r_35b,
    dlrm_criteo,
    hymba_1_5b,
    musicgen_medium,
    paligemma_3b,
    phi3_5_moe,
    qwen2_1_5b,
    qwen3_14b,
    qwen3_4b,
    qwen3_moe_235b,
    xlstm_1_3b,
)

ARCHS = {
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "qwen3-14b": qwen3_14b.CONFIG,
    "qwen2-1.5b": qwen2_1_5b.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "xlstm-1.3b": xlstm_1_3b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe.CONFIG,
}

DLRM = dlrm_criteo.CONFIG


def get(name: str, **overrides):
    cfg = ARCHS[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_reduced(name: str, **overrides):
    return ARCHS[name].reduced(**overrides)
