"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; qk_norm, GQA, head_dim=128.  [hf:Qwen/Qwen3-8B; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    emb_method="cce",
    emb_budget=151936 * 5120 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=16,
)
