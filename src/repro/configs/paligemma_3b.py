"""paligemma-3b [vlm] — gemma-2b text backbone: 18L d_model=2048 8H
(MQA kv=1, head_dim=256) d_ff=16384 vocab=257216.  [arXiv:2407.07726; hf]

The SigLIP vision frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (B, 256, d_model) which the backbone projects
and prepends to the text sequence.  Gemma details: GELU MLP, sqrt(d)
embedding scaling, tied input/output embeddings.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    act="gelu",
    emb_scale=True,
    tie_embeddings=True,
    n_patches=256,
    rope_theta=10_000.0,
    emb_method="cce",
    emb_budget=257216 * 2048 // 16,
    dtype=jnp.bfloat16,
    train_microbatch=32,
)
