"""The single recursive jaxpr walker every analysis rule shares.

Every program-level invariant this repo has earned (one pallas launch per
step, zero pointer gathers on device, donated state, no host callbacks)
is a statement about the *traced program*, and every one of them needs
the same traversal: visit each equation of a (closed) jaxpr, then recurse
into every sub-jaxpr hiding in equation params — pjit bodies, scan/while
bodies, cond branches, custom_vjp call jaxprs, shard_map bodies, pallas
kernel bodies.  Rules must never hand-roll that recursion (the pre-PR-6
copies in tests drifted exactly this way); they consume ``walk`` /
``count_primitive`` / ``used_var_ids`` and stay one-liners.

Traversal contract (DESIGN.md §7): sub-jaxprs are discovered by duck
typing on equation param values — anything with ``.eqns`` is a jaxpr,
anything with ``.jaxpr`` is a closed jaxpr, and lists/tuples are searched
elementwise.  That keeps the walker robust across jax API drift (the set
of higher-order primitives and their param names change; the two shapes
of "a jaxpr value" do not).  Thunks and callables in params (e.g.
``custom_vjp``'s ``fwd_jaxpr_thunk``) are deliberately NOT forced: the
walker only audits program structure that already exists.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator


def as_jaxpr(jaxpr_like):
    """Accept a ``ClosedJaxpr``, a raw ``Jaxpr``, or anything wrapping one
    (e.g. the object ``jax.make_jaxpr`` returns) and hand back the raw
    jaxpr the walker iterates."""
    inner = getattr(jaxpr_like, "jaxpr", None)
    if inner is not None:
        return inner
    if hasattr(jaxpr_like, "eqns"):
        return jaxpr_like
    raise TypeError(f"not a jaxpr: {type(jaxpr_like).__name__}")


def sub_jaxprs(value) -> Iterator[Any]:
    """Yield every raw jaxpr contained in one equation-param value."""
    if hasattr(value, "eqns"):
        yield value
    elif hasattr(value, "jaxpr"):
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from sub_jaxprs(item)


def closed_sub_jaxprs(value) -> Iterator[Any]:
    """Like ``sub_jaxprs`` but yields only CLOSED jaxprs (the ones that
    carry ``.consts``) — the traversal ``ConstantCapture`` needs."""
    if hasattr(value, "jaxpr") and hasattr(value, "consts"):
        yield value
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from closed_sub_jaxprs(item)


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One visited equation plus the path of enclosing equations, e.g.
    ``eqns[3]:scan/eqns[0]:pallas_call`` — stable enough to point a human
    at the offending sub-program."""

    eqn: Any
    path: str

    @property
    def primitive(self) -> str:
        return self.eqn.primitive.name


def walk(jaxpr_like, _path: str = "") -> Iterator[EqnSite]:
    """Depth-first over every equation of ``jaxpr_like`` and all its
    sub-jaxprs.  The yielded path names each enclosing equation by index
    and primitive."""
    jaxpr = as_jaxpr(jaxpr_like)
    for i, eqn in enumerate(jaxpr.eqns):
        here = f"{_path}eqns[{i}]:{eqn.primitive.name}"
        yield EqnSite(eqn, here)
        for key, value in eqn.params.items():
            for sub in sub_jaxprs(value):
                yield from walk(sub, _path=f"{here}.{key}/")


def count_primitive(jaxpr_like, name: str) -> int:
    """Recursive count of equations binding primitive ``name`` (e.g.
    ``pallas_call`` — the heavy launch count the fusion work optimizes)."""
    return sum(1 for site in walk(jaxpr_like) if site.primitive == name)


def primitive_counts(jaxpr_like) -> dict[str, int]:
    """Histogram of every primitive in the program — the report's
    at-a-glance program shape."""
    counts: dict[str, int] = {}
    for site in walk(jaxpr_like):
        counts[site.primitive] = counts.get(site.primitive, 0) + 1
    return counts


def used_var_ids(jaxpr_like, *, include_outputs: bool = True) -> set[int]:
    """``id()`` of every variable consumed by any equation (recursively)
    or returned as an output.  Sub-jaxprs bind fresh variable objects, so
    membership tests against the TOP-LEVEL invars are exact: a top-level
    invar is "used" iff its id lands in this set."""
    jaxpr = as_jaxpr(jaxpr_like)
    used: set[int] = set()
    if include_outputs:
        used.update(map(id, jaxpr.outvars))
    for site in walk(jaxpr):
        used.update(map(id, site.eqn.invars))
    return used


def iter_consts(closed) -> Iterator[tuple[str, Any]]:
    """Yield ``(path, const)`` for every constant baked into the closed
    jaxpr — top level first, then constants of closed sub-jaxprs (a
    sub-program can capture its own)."""
    for const in getattr(closed, "consts", ()):
        yield "consts", const
    for site in walk(closed):
        for key, value in site.eqn.params.items():
            for sub in closed_sub_jaxprs(value):
                for const in sub.consts:
                    yield f"{site.path}.{key}", const
