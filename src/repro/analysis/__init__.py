"""Static analysis over traced jaxprs, lowered HLO, and source ASTs.

Public surface:

  * walker   — ``walk``/``count_primitive``/``used_var_ids`` (the single
               shared jaxpr traversal; tests use these instead of local
               copies)
  * program  — ``AuditProgram.capture`` (abstract capture + input labels)
  * rules    — the registry (``RULES``) and shipped rule dataclasses
  * cost_rules — ``CostProfile``/``cost_profile`` + quantitative budget
               rules over AOT-compiled modules
  * budget   — committed budget files (``BudgetFile``) and the
               current-vs-committed diff (``diff_profiles``)
  * audit    — per-entry-point specs, ``run_audit``, the JSON ``Report``
  * source_rules — stdlib-only AST rules (usable without jax)

Exports resolve lazily (PEP 562) so ``repro.analysis.source_rules`` and
the ``--source-only`` CLI path import WITHOUT jax — the lint CI job runs
them in a bare interpreter.
"""
from __future__ import annotations

_EXPORTS = {
    # walker
    "walk": "repro.analysis.walker",
    "count_primitive": "repro.analysis.walker",
    "primitive_counts": "repro.analysis.walker",
    "used_var_ids": "repro.analysis.walker",
    "sub_jaxprs": "repro.analysis.walker",
    "iter_consts": "repro.analysis.walker",
    "EqnSite": "repro.analysis.walker",
    # program
    "AuditProgram": "repro.analysis.program",
    "label_matches": "repro.analysis.program",
    # rules
    "Finding": "repro.analysis.rules",
    "Rule": "repro.analysis.rules",
    "RULES": "repro.analysis.rules",
    "register": "repro.analysis.rules",
    "audit_program": "repro.analysis.rules",
    "LaunchBudget": "repro.analysis.rules",
    "NoDeviceGatherOf": "repro.analysis.rules",
    "DonationCoverage": "repro.analysis.rules",
    "DtypeHygiene": "repro.analysis.rules",
    "NoHostCallback": "repro.analysis.rules",
    "NoTransfers": "repro.analysis.rules",
    "ConstantCapture": "repro.analysis.rules",
    "DeadInput": "repro.analysis.rules",
    # cost rules (AOT-compiled quantitative budgets)
    "CostProfile": "repro.analysis.cost_rules",
    "cost_profile": "repro.analysis.cost_rules",
    "FlopBudget": "repro.analysis.cost_rules",
    "BytesBudget": "repro.analysis.cost_rules",
    "PeakMemoryBudget": "repro.analysis.cost_rules",
    "CollectiveBudget": "repro.analysis.cost_rules",
    "NoReplicatedParam": "repro.analysis.cost_rules",
    # budget files + diff
    "BudgetFile": "repro.analysis.budget",
    "MetricDiff": "repro.analysis.budget",
    "diff_profiles": "repro.analysis.budget",
    "diff_summary": "repro.analysis.budget",
    # audit
    "AuditSpec": "repro.analysis.audit",
    "AUDIT_CONFIGS": "repro.analysis.audit",
    "dlrm_audits": "repro.analysis.audit",
    "dlrm_sharded_audits": "repro.analysis.audit",
    "run_audit": "repro.analysis.audit",
    "Report": "repro.analysis.audit",
    # source rules (jax-free)
    "SourceFinding": "repro.analysis.source_rules",
    "run_source_rules": "repro.analysis.source_rules",
    "check_source_file": "repro.analysis.source_rules",
    "SOURCE_RULE_IDS": "repro.analysis.source_rules",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module_name = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.analysis' has no attribute {name!r}"
        ) from None
    import importlib

    module = importlib.import_module(module_name)
    value = getattr(module, name)
    globals()[name] = value  # cache for the next access
    return value


def __dir__():
    return __all__
