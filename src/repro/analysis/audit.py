"""Per-entry-point audit specs + the report the CLI/CI gate consumes.

An ``AuditSpec`` bundles one jitted entry point (built lazily, captured
ABSTRACTLY — ``jax.eval_shape`` + ``ShapeDtypeStruct`` inputs, so the
full Criteo config audits without allocating its 33M-row pointer tables)
with the rule instances that encode its invariants.  ``run_audit`` runs
a named config's whole bundle and returns a ``Report`` that serializes
to ``AUDIT_report.json`` and carries the CI exit code.

The ``dlrm_criteo`` bundle audits the canonical programs:

  * ``fwd``          — DLRM forward: ONE pallas launch, clean dtypes,
                       no callbacks/transfers/large consts.
  * ``grad``         — loss gradient: exactly TWO launches (fwd + the
                       transposed one-hot scatter-add bwd).
  * ``train_step``   — the donated step WITH the in-step sketch counter:
                       still two launches (sketch tracking adds zero
                       dispatches), every TrainState leaf aliased to an
                       output, nothing dead but the transition-only
                       ``epoch`` counters.
  * ``train_step_telemetry`` — the same step with ``repro.obs`` in-step
                       health metrics on: identical launch budget,
                       donation coverage, and no-callback invariants —
                       the gate that proves the instrumentation free.
  * ``serve_lookup`` — the host-translated inference lookup: one launch
                       and ZERO reads of the ptr/hs pointer tables
                       (DESIGN.md §4's pod contract).

The ``*_sharded`` bundles audit the distributed entry points: the CCE
transition (``cluster_sharded`` / ``assign_all_sharded`` over a mesh
spanning every visible device — zero pallas launches, pointer operands
entering id-SHARDED) and the model-parallel train step
(``train_step_sharded``: supertable + moments codebook-sharded, batch
ids routed by all-to-all — see ``launch.steps.build_dlrm_train_step``).
Each carries a ``CollectiveBudget`` naming exactly which ICI collective
kinds it may emit (and pinning DCN traffic to zero) plus
``NoReplicatedParam`` at ERROR severity: since ROADMAP item 1 landed, no
O(vocab) leaf may enter any sharded program replicated.

Cost rules (``spec.cost_rules``) are separate from structural rules:
they AOT-compile the entry point (seconds per program instead of
milliseconds), so ``run_audit`` only runs them — and only then computes
``CostProfile``s — when asked (``with_cost=True`` / ``--budgets``).

ROADMAP items 1–3 (sharded supertable, serve engine, quantized slabs)
should land by ADDING specs here — their invariants become checkable
before the systems are built.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable

from repro.analysis.cost_rules import CollectiveBudget, NoReplicatedParam, cost_profile
from repro.analysis.program import AuditProgram
from repro.analysis.rules import (
    ConstantCapture,
    DeadInput,
    DonationCoverage,
    DtypeHygiene,
    Finding,
    LaunchBudget,
    NoDeviceGatherOf,
    NoHostCallback,
    NoTransfers,
    Rule,
    audit_program,
)
from repro.analysis.walker import primitive_counts

# epoch is the CCE transition counter: it must RIDE the dynamic buffers
# (PR 1 — a static leaf would freeze the transition schedule into the
# program) but no lookup/step program reads it — dead by contract.
_EPOCH_ALLOW = ("epoch",)

_HYGIENE: tuple[Rule, ...] = (
    DtypeHygiene(),
    NoHostCallback(),
    NoTransfers(),
    ConstantCapture(),
)


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """One entry point: a thunk building the captured program (lazy —
    building traces/loads jax) plus the rules that must hold on it.

    ``rules`` run on every audit (jaxpr/lowering only — cheap);
    ``cost_rules`` additionally AOT-compile the program and only run
    under ``run_audit(..., with_cost=True)``."""

    name: str
    build: Callable[[], AuditProgram]
    rules: tuple[Rule, ...]
    cost_rules: tuple[Rule, ...] = ()


def _abstract_dlrm(cfg):
    """(params, buffers) ShapeDtypeStruct trees — zero allocation."""
    import jax

    from repro.models import dlrm

    return jax.eval_shape(lambda: dlrm.init(jax.random.PRNGKey(0), cfg))


def _batch_struct(cfg, batch_size: int, *, label: bool):
    import jax
    import jax.numpy as jnp

    batch = {
        "dense": jax.ShapeDtypeStruct((batch_size, cfg.n_dense), jnp.float32),
        "sparse": jax.ShapeDtypeStruct((batch_size, cfg.n_sparse), jnp.int32),
    }
    if label:
        batch["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
    return batch


def _build_fwd(cfg, batch_size):
    from repro.models import dlrm

    params, buffers = _abstract_dlrm(cfg)
    batch = _batch_struct(cfg, batch_size, label=False)
    return AuditProgram.capture(
        lambda p, b, bt: dlrm.forward(p, b, cfg, bt),
        params, buffers, batch, name="fwd",
    )


def _build_grad(cfg, batch_size):
    import jax

    from repro.models import dlrm

    params, buffers = _abstract_dlrm(cfg)
    batch = _batch_struct(cfg, batch_size, label=True)
    return AuditProgram.capture(
        lambda p, b, bt: jax.grad(
            lambda q: dlrm.bce_loss(q, b, cfg, bt)
        )(p),
        params, buffers, batch, name="grad",
    )


def _build_train_step(cfg, batch_size, stream_cfg, *, telemetry=False):
    import jax

    from repro.models import dlrm
    from repro.optim import sgd
    from repro.stream import make_step_cell_counter
    from repro.train.loop import init_state, make_train_step, split_buffers

    import jax.numpy as jnp

    params, buffers = _abstract_dlrm(cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    sketch_fn = None
    if stream_cfg is not None:
        sketch_fn = make_step_cell_counter(dlrm.make_id_tracker(cfg, stream_cfg))
    tcfg = None
    if telemetry:
        from repro.obs.telemetry import TelemetryConfig

        tcfg = TelemetryConfig()
    step = make_train_step(
        loss_fn, opt, lambda s: jnp.float32(0.05), static,
        sketch_fn=sketch_fn, telemetry=tcfg, donate=True,
    )
    state = jax.eval_shape(lambda: init_state(params, opt, dyn))
    batch = {
        k: jax.ShapeDtypeStruct((1, *v.shape), v.dtype)
        for k, v in _batch_struct(cfg, batch_size, label=True).items()
    }
    return AuditProgram.capture(
        step, state, batch,
        name="train_step_telemetry" if telemetry else "train_step",
        donate_argnums=(0,),
    )


def _build_serve_lookup(cfg, batch_size):
    import jax
    import jax.numpy as jnp

    coll = cfg.collection
    params, buffers = _abstract_dlrm(cfg)
    rows = jax.ShapeDtypeStruct(
        (batch_size, coll.rows_n_cols, coll.rows_n_tables), jnp.int32
    )
    return AuditProgram.capture(
        lambda p, b, r: coll.lookup_all(p, b, None, use_kernel=True, rows=r),
        params["emb"], buffers["emb"], rows, name="serve_lookup",
    )


def _build_serve_dlrm(cfg, batch_size, *, cold: bool, cache_slots: int = 4096):
    """The serve engine's two programs (serve/dlrm.py, DESIGN.md §11).

    ``cold=False`` is the fully-cache-hit batch: every embedding answered
    by the hot-cache gather, the supertable never enters the program —
    LaunchBudget(0) makes "a hit batch skips the launch" structural.
    ``cold=True`` is the mixed batch: cache gather + ONE fused launch over
    the compacted cold sub-batch on host-translated rows; the emb buffers
    ride along so NoDeviceGatherOf has real ptr/hs inputs to clear (a
    vacuous pass is itself a finding)."""
    import jax
    import jax.numpy as jnp

    from repro.serve.dlrm import make_serve_fns

    coll = cfg.collection
    params, buffers = _abstract_dlrm(cfg)
    hit_fn, cold_fn = make_serve_fns(cfg, use_kernel=True)
    cache_tab = jax.ShapeDtypeStruct((cache_slots, cfg.emb_dim), jnp.float32)
    slots = jax.ShapeDtypeStruct((batch_size, cfg.n_sparse), jnp.int32)
    dense = jax.ShapeDtypeStruct((batch_size, cfg.n_dense), jnp.float32)
    if not cold:
        mlp = {"bottom": params["bottom"], "top": params["top"]}
        return AuditProgram.capture(
            hit_fn, mlp, cache_tab, slots, dense, name="serve_dlrm_hit",
        )
    rows = jax.ShapeDtypeStruct(
        (batch_size, coll.rows_n_cols, coll.rows_n_tables), jnp.int32
    )
    cold_idx = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
    return AuditProgram.capture(
        cold_fn, params, buffers["emb"], cache_tab, slots, dense, rows,
        cold_idx, name="serve_dlrm_cold",
    )


def dlrm_audits(cfg, stream_cfg=None, *, batch_size: int = 32):
    """The canonical DLRM audit bundle for any DLRMConfig."""
    # the 1-device contract is ZERO collectives in every compiled module —
    # the default CollectiveBudget allows nothing
    no_collectives = (CollectiveBudget(),)
    return (
        AuditSpec(
            "fwd",
            lambda: _build_fwd(cfg, batch_size),
            (LaunchBudget(1), DeadInput(allow=_EPOCH_ALLOW), *_HYGIENE),
            cost_rules=no_collectives,
        ),
        AuditSpec(
            "grad",
            lambda: _build_grad(cfg, batch_size),
            (LaunchBudget(2), *_HYGIENE),
            cost_rules=no_collectives,
        ),
        AuditSpec(
            "train_step",
            lambda: _build_train_step(cfg, batch_size, stream_cfg),
            (
                LaunchBudget(2),
                DonationCoverage(),
                DeadInput(allow=_EPOCH_ALLOW),
                *_HYGIENE,
            ),
            cost_rules=no_collectives,
        ),
        # the telemetry-enabled step carries the SAME invariants as the
        # bare one — in-step health metrics (repro.obs) are pure jnp
        # reductions that must not add launches, break donation, or
        # smuggle in a host callback.  This spec is what makes "the
        # instrumentation is free" a gated claim rather than a comment.
        AuditSpec(
            "train_step_telemetry",
            lambda: _build_train_step(
                cfg, batch_size, stream_cfg, telemetry=True
            ),
            (
                LaunchBudget(2),
                DonationCoverage(),
                DeadInput(allow=_EPOCH_ALLOW),
                *_HYGIENE,
            ),
            cost_rules=no_collectives,
        ),
        AuditSpec(
            "serve_lookup",
            lambda: _build_serve_lookup(cfg, batch_size),
            (
                LaunchBudget(1),
                NoDeviceGatherOf(("ptr", "hs")),
                DeadInput(allow=("ptr", "hs", *_EPOCH_ALLOW)),
                *_HYGIENE,
            ),
            cost_rules=no_collectives,
        ),
        # the serve engine's cold path: hot-cache gather + ONE fused
        # launch over the compacted cold sub-batch, no ptr/hs gathers
        AuditSpec(
            "serve_dlrm_cold",
            lambda: _build_serve_dlrm(cfg, batch_size, cold=True),
            (
                LaunchBudget(1),
                NoDeviceGatherOf(("ptr", "hs")),
                DeadInput(allow=("ptr", "hs", *_EPOCH_ALLOW)),
                *_HYGIENE,
            ),
            cost_rules=no_collectives,
        ),
        # the fully-cache-hit path: ZERO heavy launches — the supertable
        # is not even an input to the program
        AuditSpec(
            "serve_dlrm_hit",
            lambda: _build_serve_dlrm(cfg, batch_size, cold=False),
            (LaunchBudget(0), DeadInput(), *_HYGIENE),
            cost_rules=no_collectives,
        ),
    )


# --- the sharded CCE-transition bundle ----------------------------------


def _largest_cce(cfg):
    """The config's largest CCE table — the one whose transition cost
    dominates (the full-vocab assignment is O(d1))."""
    from repro.core.cce import CCE

    tables = [
        t for t in (cfg.table(i) for i in range(cfg.n_sparse))
        if isinstance(t, CCE)
    ]
    if not tables:
        raise SystemExit(
            "sharded audit config needs at least one CCE table; "
            f"emb_method={cfg.emb_method!r}"
        )
    return max(tables, key=lambda t: t.d1)


def _abstract_cce_state(table):
    """(params, buffers) ShapeDtypeStructs for one CCE table, built by
    hand: ``init_buffers`` does real numpy work that is O(d1) (~0.5 GB at
    Criteo scale), and the audit must stay allocation-free."""
    import jax
    import jax.numpy as jnp

    params = {
        "tables": jax.ShapeDtypeStruct(
            (table.c, 2, table.k, table.dsub), table.dtype
        ),
    }
    buffers = {
        "ptr": jax.ShapeDtypeStruct((table.c, table.d1), jnp.int32),
        "hs": jax.ShapeDtypeStruct((table.c, 2), jnp.uint32),
        "epoch": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return params, buffers


def _data_mesh():
    """1-axis mesh over every visible device (the multi-device CI lane
    forces 4 host devices via XLA_FLAGS)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.launch.mesh import DATA_AXIS

    return Mesh(np.asarray(jax.devices()), (DATA_AXIS,))


def _cce_shardings(mesh, table):
    """Input shardings for the transition entry points: the (c, d1)
    pointer table enters SHARDED at its at-rest layout
    (``mesh.ptr_partition_spec`` — id axis when the vocab divides, column
    axis for Criteo's ragged vocabs), everything else replicated.
    Pre-jitting the capture with these is what lets ``NoReplicatedParam``
    run at error severity — an audit that handed the programs replicated
    pointers would flag its own harness."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import DATA_AXIS, ptr_partition_spec

    def ns(spec):
        return NamedSharding(mesh, spec)

    nsh = mesh.shape[DATA_AXIS]
    params_sh = {"tables": ns(P())}
    buffers_sh = {
        "ptr": ns(ptr_partition_spec(table.c, table.d1, nsh, DATA_AXIS)),
        "hs": ns(P()),
        "epoch": ns(P()),
    }
    return jax, ns, params_sh, buffers_sh


def _build_cluster_sharded(cfg):
    import jax.numpy as jnp

    table = _largest_cce(cfg)
    mesh = _data_mesh()
    params, buffers = _abstract_cce_state(table)
    jax, ns, params_sh, buffers_sh = _cce_shardings(mesh, table)
    from jax.sharding import PartitionSpec as P

    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    chunk = cfg.emb_cluster_chunk or None
    jitted = jax.jit(
        lambda k, p, b: table.cluster_sharded(
            k, p, b, mesh, chunk_size=chunk, use_kernel=False
        ),
        in_shardings=(ns(P()), params_sh, buffers_sh),
    )
    return AuditProgram.capture(
        jitted, key, params, buffers, name="cluster_sharded",
    )


def _build_assign_all_sharded(cfg):
    import jax.numpy as jnp

    table = _largest_cce(cfg)
    mesh = _data_mesh()
    params, buffers = _abstract_cce_state(table)
    jax, ns, params_sh, buffers_sh = _cce_shardings(mesh, table)
    from jax.sharding import PartitionSpec as P

    centroids = jax.ShapeDtypeStruct(
        (table.c, table.k, table.dsub), jnp.float32
    )
    chunk = cfg.emb_cluster_chunk or None
    jitted = jax.jit(
        lambda p, b, cen: table.assign_all_sharded(
            p, b, cen, mesh, chunk_size=chunk, use_kernel=False
        ),
        in_shardings=(params_sh, buffers_sh, ns(P())),
    )
    return AuditProgram.capture(
        jitted, params, buffers, centroids, name="assign_all_sharded",
    )


def _build_train_step_sharded(cfg, *, telemetry=False):
    """The model-parallel DLRM train step over a (1, n_devices) mesh —
    the slab/moments/ptr enter sharded per ``dlrm_state_specs``, batch
    ids arrive host-translated and pre-bucketed, and the id routing runs
    as in-step all-to-all.  With ``telemetry`` the in-step health metrics
    (including the per-shard routing-occupancy skew read off the
    pre-bucketed rows) ride the same program."""
    import dataclasses as _dc

    import jax

    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_dlrm_train_step
    from repro.optim import sgd

    n = len(jax.devices())
    mesh = make_host_mesh(data=1, model=n)
    cfg = _dc.replace(cfg, emb_k_multiple=n)
    tcfg = None
    if telemetry:
        from repro.obs.telemetry import TelemetryConfig

        tcfg = TelemetryConfig()
    jitted, (state_shape, batch_struct), _ = build_dlrm_train_step(
        cfg, mesh, batch_size=32, accum=1, optimizer=sgd(momentum=0.9),
        telemetry=tcfg,
    )
    return AuditProgram.capture(
        jitted, state_shape, batch_struct,
        name="train_step_sharded_telemetry" if telemetry
        else "train_step_sharded",
        donate_argnums=(0,),
    )


def dlrm_sharded_audits(cfg):
    """Audit bundle for the distributed CCE entry points.

    The byte caps here are deliberately loose (the committed budget file
    supplies the tight, config-specific numbers); what the spec-level
    ``CollectiveBudget`` pins is the *kinds*: all-reduce (the psum'd
    k-means moments), all-gather (the sharded pointer gathered where
    consumed), all-to-all (the step's batch-id routing, and the
    at-rest → id-sharded pointer reshard when a ragged vocab forces
    column-sharded storage — ``mesh.ptr_partition_spec``), plus
    collective-permute (XLA's lowering of halo/reshard moves inside the
    same patterns) — nothing else, and nothing over DCN.
    ``NoReplicatedParam`` runs at ERROR severity: every large slab (the
    supertable, its moments, the pointer table) must enter its program
    sharded, and a replicated copy reappearing anywhere fails the audit
    outright."""
    ici_collectives = CollectiveBudget(
        allow=(
            "all-to-all",
            "all-reduce",
            "all-gather",
            "collective-permute",
        ),
        max_ici_bytes=math.inf,
        max_dcn_bytes=0.0,
    )
    replication_debt = NoReplicatedParam()
    return (
        AuditSpec(
            "cluster_sharded",
            lambda: _build_cluster_sharded(cfg),
            (LaunchBudget(0), DeadInput(allow=_EPOCH_ALLOW), *_HYGIENE),
            cost_rules=(ici_collectives, replication_debt),
        ),
        AuditSpec(
            "assign_all_sharded",
            lambda: _build_assign_all_sharded(cfg),
            (
                LaunchBudget(0),
                DeadInput(allow=_EPOCH_ALLOW),
                *_HYGIENE,
            ),
            cost_rules=(ici_collectives, replication_debt),
        ),
        AuditSpec(
            "train_step_sharded",
            lambda: _build_train_step_sharded(cfg),
            (
                LaunchBudget(2),
                DonationCoverage(),
                NoDeviceGatherOf(("ptr", "hs")),
                DeadInput(allow=("ptr", "hs", *_EPOCH_ALLOW)),
                *_HYGIENE,
            ),
            cost_rules=(ici_collectives, replication_debt),
        ),
        # telemetry-enabled twin: the routing-skew/occupancy metrics must
        # not add launches, collectives kinds, callbacks, or replication
        AuditSpec(
            "train_step_sharded_telemetry",
            lambda: _build_train_step_sharded(cfg, telemetry=True),
            (
                LaunchBudget(2),
                DonationCoverage(),
                NoDeviceGatherOf(("ptr", "hs")),
                DeadInput(allow=("ptr", "hs", *_EPOCH_ALLOW)),
                *_HYGIENE,
            ),
            cost_rules=(ici_collectives, replication_debt),
        ),
    )


def _dlrm_criteo_specs():
    from repro.configs import dlrm_criteo

    return dlrm_audits(dlrm_criteo.CONFIG, dlrm_criteo.STREAM)


def _dlrm_criteo_reduced_specs():
    from repro.configs import dlrm_criteo

    return dlrm_audits(
        dlrm_criteo.reduced(emb_method="cce", cap=512),
        dlrm_criteo.reduced_stream(),
    )


def _dlrm_criteo_sharded_specs():
    from repro.configs import dlrm_criteo

    return dlrm_sharded_audits(dlrm_criteo.CONFIG)


def _dlrm_criteo_reduced_sharded_specs():
    from repro.configs import dlrm_criteo

    return dlrm_sharded_audits(dlrm_criteo.reduced(emb_method="cce", cap=512))


# config name -> thunk returning the spec tuple (thunks: importing a
# config loads jax; the CLI must stay importable without it)
AUDIT_CONFIGS: dict[str, Callable[[], tuple[AuditSpec, ...]]] = {
    "dlrm_criteo": _dlrm_criteo_specs,
    "dlrm_criteo_reduced": _dlrm_criteo_reduced_specs,
    "dlrm_criteo_sharded": _dlrm_criteo_sharded_specs,
    "dlrm_criteo_reduced_sharded": _dlrm_criteo_reduced_sharded_specs,
}


@dataclasses.dataclass
class Report:
    """One audit run: per-program rule coverage + structured findings
    (+ per-program ``CostProfile``s when the run captured cost)."""

    config: str
    programs: list[dict]
    findings: list[Finding]
    profiles: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def to_dict(self) -> dict:
        d = {
            "config": self.config,
            "ok": self.ok,
            "programs": self.programs,
            "findings": [f.to_dict() for f in self.findings],
        }
        if self.profiles:
            d["cost"] = {
                name: prof.to_dict() for name, prof in self.profiles.items()
            }
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, **kw)


def run_audit(config: str, *, with_cost: bool = False, budget=None) -> Report:
    """Build + audit every entry point of a named config.

    ``with_cost=True`` additionally AOT-compiles each entry point, runs
    its ``cost_rules``, and fills ``Report.profiles``.  ``budget`` (a
    ``budget.BudgetFile``) layers the committed budget's rules on top:
    per-metric caps at committed*(1+tol), plus structural findings for
    missing/stale entries and partition-count mismatches.
    """
    try:
        specs = AUDIT_CONFIGS[config]()
    except KeyError:
        raise SystemExit(
            f"unknown audit config {config!r}; have {sorted(AUDIT_CONFIGS)}"
        ) from None
    programs, findings, profiles = [], [], {}
    for spec in specs:
        prog = spec.build()
        rules = spec.rules
        if with_cost:
            rules = rules + spec.cost_rules
            if budget is not None and (
                budget_rules := budget.rules_for(spec.name)
            ):
                rules = rules + budget_rules
        found = audit_program(prog, rules)
        findings.extend(found)
        if with_cost:
            profiles[spec.name] = cost_profile(prog)
        programs.append({
            "name": spec.name,
            "rules": [r.id for r in rules],
            "n_findings": len(found),
            "n_eqns_by_primitive": {
                k: v for k, v in sorted(
                    primitive_counts(prog.closed).items()
                ) if k in ("pallas_call", "scan", "while", "cond", "pjit")
            },
        })
    if with_cost and budget is not None:
        findings.extend(budget.structural_findings(profiles))
    return Report(
        config=config, programs=programs, findings=findings, profiles=profiles
    )
