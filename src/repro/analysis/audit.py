"""Per-entry-point audit specs + the report the CLI/CI gate consumes.

An ``AuditSpec`` bundles one jitted entry point (built lazily, captured
ABSTRACTLY — ``jax.eval_shape`` + ``ShapeDtypeStruct`` inputs, so the
full Criteo config audits without allocating its 33M-row pointer tables)
with the rule instances that encode its invariants.  ``run_audit`` runs
a named config's whole bundle and returns a ``Report`` that serializes
to ``AUDIT_report.json`` and carries the CI exit code.

The ``dlrm_criteo`` bundle audits the four canonical programs:

  * ``fwd``          — DLRM forward: ONE pallas launch, clean dtypes,
                       no callbacks/transfers/large consts.
  * ``grad``         — loss gradient: exactly TWO launches (fwd + the
                       transposed one-hot scatter-add bwd).
  * ``train_step``   — the donated step WITH the in-step sketch counter:
                       still two launches (sketch tracking adds zero
                       dispatches), every TrainState leaf aliased to an
                       output, nothing dead but the transition-only
                       ``epoch`` counters.
  * ``serve_lookup`` — the host-translated inference lookup: one launch
                       and ZERO reads of the ptr/hs pointer tables
                       (DESIGN.md §4's pod contract).

ROADMAP items 1–3 (sharded supertable, serve engine, quantized slabs)
should land by ADDING specs here — their invariants become checkable
before the systems are built.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable

from repro.analysis.program import AuditProgram
from repro.analysis.rules import (
    ConstantCapture,
    DeadInput,
    DonationCoverage,
    DtypeHygiene,
    Finding,
    LaunchBudget,
    NoDeviceGatherOf,
    NoHostCallback,
    NoTransfers,
    Rule,
    audit_program,
)
from repro.analysis.walker import primitive_counts

# epoch is the CCE transition counter: it must RIDE the dynamic buffers
# (PR 1 — a static leaf would freeze the transition schedule into the
# program) but no lookup/step program reads it — dead by contract.
_EPOCH_ALLOW = ("epoch",)

_HYGIENE: tuple[Rule, ...] = (
    DtypeHygiene(),
    NoHostCallback(),
    NoTransfers(),
    ConstantCapture(),
)


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """One entry point: a thunk building the captured program (lazy —
    building traces/loads jax) plus the rules that must hold on it."""

    name: str
    build: Callable[[], AuditProgram]
    rules: tuple[Rule, ...]


def _abstract_dlrm(cfg):
    """(params, buffers) ShapeDtypeStruct trees — zero allocation."""
    import jax

    from repro.models import dlrm

    return jax.eval_shape(lambda: dlrm.init(jax.random.PRNGKey(0), cfg))


def _batch_struct(cfg, batch_size: int, *, label: bool):
    import jax
    import jax.numpy as jnp

    batch = {
        "dense": jax.ShapeDtypeStruct((batch_size, cfg.n_dense), jnp.float32),
        "sparse": jax.ShapeDtypeStruct((batch_size, cfg.n_sparse), jnp.int32),
    }
    if label:
        batch["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
    return batch


def _build_fwd(cfg, batch_size):
    from repro.models import dlrm

    params, buffers = _abstract_dlrm(cfg)
    batch = _batch_struct(cfg, batch_size, label=False)
    return AuditProgram.capture(
        lambda p, b, bt: dlrm.forward(p, b, cfg, bt),
        params, buffers, batch, name="fwd",
    )


def _build_grad(cfg, batch_size):
    import jax

    from repro.models import dlrm

    params, buffers = _abstract_dlrm(cfg)
    batch = _batch_struct(cfg, batch_size, label=True)
    return AuditProgram.capture(
        lambda p, b, bt: jax.grad(
            lambda q: dlrm.bce_loss(q, b, cfg, bt)
        )(p),
        params, buffers, batch, name="grad",
    )


def _build_train_step(cfg, batch_size, stream_cfg):
    import jax

    from repro.models import dlrm
    from repro.optim import sgd
    from repro.stream import make_step_cell_counter
    from repro.train.loop import init_state, make_train_step, split_buffers

    import jax.numpy as jnp

    params, buffers = _abstract_dlrm(cfg)
    dyn, static = split_buffers(buffers)
    opt = sgd(momentum=0.9)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    sketch_fn = None
    if stream_cfg is not None:
        sketch_fn = make_step_cell_counter(dlrm.make_id_tracker(cfg, stream_cfg))
    step = make_train_step(
        loss_fn, opt, lambda s: jnp.float32(0.05), static,
        sketch_fn=sketch_fn, donate=True,
    )
    state = jax.eval_shape(lambda: init_state(params, opt, dyn))
    batch = {
        k: jax.ShapeDtypeStruct((1, *v.shape), v.dtype)
        for k, v in _batch_struct(cfg, batch_size, label=True).items()
    }
    return AuditProgram.capture(
        step, state, batch, name="train_step", donate_argnums=(0,),
    )


def _build_serve_lookup(cfg, batch_size):
    import jax
    import jax.numpy as jnp

    coll = cfg.collection
    params, buffers = _abstract_dlrm(cfg)
    rows = jax.ShapeDtypeStruct(
        (batch_size, coll.rows_n_cols, coll.rows_n_tables), jnp.int32
    )
    return AuditProgram.capture(
        lambda p, b, r: coll.lookup_all(p, b, None, use_kernel=True, rows=r),
        params["emb"], buffers["emb"], rows, name="serve_lookup",
    )


def dlrm_audits(cfg, stream_cfg=None, *, batch_size: int = 32):
    """The canonical DLRM audit bundle for any DLRMConfig."""
    return (
        AuditSpec(
            "fwd",
            lambda: _build_fwd(cfg, batch_size),
            (LaunchBudget(1), DeadInput(allow=_EPOCH_ALLOW), *_HYGIENE),
        ),
        AuditSpec(
            "grad",
            lambda: _build_grad(cfg, batch_size),
            (LaunchBudget(2), *_HYGIENE),
        ),
        AuditSpec(
            "train_step",
            lambda: _build_train_step(cfg, batch_size, stream_cfg),
            (
                LaunchBudget(2),
                DonationCoverage(),
                DeadInput(allow=_EPOCH_ALLOW),
                *_HYGIENE,
            ),
        ),
        AuditSpec(
            "serve_lookup",
            lambda: _build_serve_lookup(cfg, batch_size),
            (
                LaunchBudget(1),
                NoDeviceGatherOf(("ptr", "hs")),
                DeadInput(allow=("ptr", "hs", *_EPOCH_ALLOW)),
                *_HYGIENE,
            ),
        ),
    )


def _dlrm_criteo_specs():
    from repro.configs import dlrm_criteo

    return dlrm_audits(dlrm_criteo.CONFIG, dlrm_criteo.STREAM)


def _dlrm_criteo_reduced_specs():
    from repro.configs import dlrm_criteo

    return dlrm_audits(
        dlrm_criteo.reduced(emb_method="cce", cap=512),
        dlrm_criteo.reduced_stream(),
    )


# config name -> thunk returning the spec tuple (thunks: importing a
# config loads jax; the CLI must stay importable without it)
AUDIT_CONFIGS: dict[str, Callable[[], tuple[AuditSpec, ...]]] = {
    "dlrm_criteo": _dlrm_criteo_specs,
    "dlrm_criteo_reduced": _dlrm_criteo_reduced_specs,
}


@dataclasses.dataclass
class Report:
    """One audit run: per-program rule coverage + structured findings."""

    config: str
    programs: list[dict]
    findings: list[Finding]

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def to_dict(self) -> dict:
        return {
            "config": self.config,
            "ok": self.ok,
            "programs": self.programs,
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=2, **kw)


def run_audit(config: str) -> Report:
    """Build + audit every entry point of a named config."""
    try:
        specs = AUDIT_CONFIGS[config]()
    except KeyError:
        raise SystemExit(
            f"unknown audit config {config!r}; have {sorted(AUDIT_CONFIGS)}"
        ) from None
    programs, findings = [], []
    for spec in specs:
        prog = spec.build()
        found = audit_program(prog, spec.rules)
        findings.extend(found)
        programs.append({
            "name": spec.name,
            "rules": [r.id for r in spec.rules],
            "n_findings": len(found),
            "n_eqns_by_primitive": {
                k: v for k, v in sorted(
                    primitive_counts(prog.closed).items()
                ) if k in ("pallas_call", "scan", "while", "cond", "pjit")
            },
        })
    return Report(config=config, programs=programs, findings=findings)
