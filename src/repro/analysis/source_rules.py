"""AST-level source rules — repo invariants the type system can't state.

Pure stdlib (``ast``): the CI lint job runs these without installing jax.
Three rules, each a bug class this repo actually hit:

  * ``fuse-rows-twin`` — every class defining ``fuse_rows`` must define a
    ``fuse_rows_np`` twin.  Host pointer translation (data/translate.py)
    is bit-exact ONLY because every table's row function has a numpy
    mirror; a method without its twin silently breaks the host path for
    that table type.
  * ``no-int-cast`` — no ``int(...)``/``float(...)`` wrapped directly
    around an array reduction, and no ``.item()`` at all.  The PR-4 bug:
    ``int(counts.sum())`` truncated decayed sub-1 histograms to zero; on
    traced values the same cast is a concretization error at best.  Only
    modules that import jax are checked (a pure-numpy module cannot hold
    a traced value); jax-module host-side uses that are genuinely sound
    carry an explicit waiver comment: ``# audit: allow-int-cast``.
  * ``no-raw-experimental`` — ``jax.experimental`` is imported in exactly
    one place, ``repro/compat.py``.  Everything else imports the shims
    (``shard_map``, ``pallas``, ...) from there, so jax API graduation is
    a one-file change.

Waivers are per-line: end the line with ``# audit: allow-<rule>``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterator

SOURCE_RULE_IDS = ("fuse-rows-twin", "no-int-cast", "no-raw-experimental")

_REDUCTIONS = ("sum", "mean", "max", "min", "prod", "dot")
_COMPAT_BASENAME = "compat.py"


@dataclasses.dataclass(frozen=True)
class SourceFinding:
    rule: str
    severity: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _waived(lines: list[str], lineno: int, rule: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    return f"audit: allow-{rule}" in lines[lineno - 1]


def _check_fuse_rows_twin(path, tree, lines) -> Iterator[SourceFinding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        defined = {
            n.name for n in node.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        if "fuse_rows" in defined and "fuse_rows_np" not in defined:
            if _waived(lines, node.lineno, "fuse-rows-twin"):
                continue
            yield SourceFinding(
                "fuse-rows-twin", "error", path, node.lineno,
                f"class {node.name} defines fuse_rows without a bit-exact "
                "fuse_rows_np twin — the host translator cannot mirror it",
            )


def _is_reduction_call(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in _REDUCTIONS
    )


def _imports_jax(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.split(".")[0] == "jax" for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] == "jax":
                return True
    return False


def _check_int_cast(path, tree, lines) -> Iterator[SourceFinding]:
    if not _imports_jax(tree):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in ("int", "float")
            and len(node.args) == 1
            and _is_reduction_call(node.args[0])
        ):
            if _waived(lines, node.lineno, "int-cast"):
                continue
            yield SourceFinding(
                "no-int-cast", "error", path, node.lineno,
                f"{node.func.id}() wrapped around an array reduction — on "
                "traced values this concretizes; on decayed float counts "
                "it truncates (the PR-4 histogram bug).  If the value is "
                "provably host-side, waive with `# audit: allow-int-cast`",
            )
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "item"
            and not node.args
            and not node.keywords
        ):
            if _waived(lines, node.lineno, "int-cast"):
                continue
            yield SourceFinding(
                "no-int-cast", "error", path, node.lineno,
                ".item() call — concretizes traced values; use jnp ops or "
                "waive with `# audit: allow-int-cast`",
            )


def _check_raw_experimental(path, tree, lines) -> Iterator[SourceFinding]:
    if os.path.basename(path) == _COMPAT_BASENAME:
        return
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith("jax.experimental"):
                hit = f"from {node.module} import ..."
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("jax.experimental"):
                    hit = f"import {alias.name}"
        elif isinstance(node, ast.Attribute):
            if (
                node.attr == "experimental"
                and isinstance(node.value, ast.Name)
                and node.value.id == "jax"
            ):
                hit = "jax.experimental attribute access"
        if hit is None or _waived(lines, node.lineno, "raw-experimental"):
            continue
        yield SourceFinding(
            "no-raw-experimental", "error", path, node.lineno,
            f"{hit} outside compat.py — route the shim through "
            "repro.compat so jax API drift stays a one-file change",
        )


_CHECKS = (
    _check_fuse_rows_twin,
    _check_int_cast,
    _check_raw_experimental,
)


def check_source_file(path: str) -> list[SourceFinding]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return [SourceFinding(
            "syntax", "error", path, e.lineno or 0, f"does not parse: {e.msg}"
        )]
    lines = text.splitlines()
    findings: list[SourceFinding] = []
    for check in _CHECKS:
        findings.extend(check(path, tree, lines))
    return findings


def run_source_rules(root: str = "src/repro") -> list[SourceFinding]:
    """Walk ``root`` and check every ``.py`` file.  Deterministic order."""
    findings: list[SourceFinding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                findings.extend(
                    check_source_file(os.path.join(dirpath, fname))
                )
    return findings
