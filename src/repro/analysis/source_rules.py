"""AST-level source rules — repo invariants the type system can't state.

Pure stdlib (``ast``): the CI lint job runs these without installing jax.
Three rules, each a bug class this repo actually hit:

  * ``fuse-rows-twin`` — every class defining ``fuse_rows`` must define a
    ``fuse_rows_np`` twin.  Host pointer translation (data/translate.py)
    is bit-exact ONLY because every table's row function has a numpy
    mirror; a method without its twin silently breaks the host path for
    that table type.
  * ``no-int-cast`` — no ``int(...)``/``float(...)`` wrapped directly
    around an array reduction, and no ``.item()`` at all.  The PR-4 bug:
    ``int(counts.sum())`` truncated decayed sub-1 histograms to zero; on
    traced values the same cast is a concretization error at best.  Only
    modules that import jax are checked (a pure-numpy module cannot hold
    a traced value); jax-module host-side uses that are genuinely sound
    carry an explicit waiver comment: ``# audit: allow-int-cast``.
  * ``no-raw-experimental`` — ``jax.experimental`` is imported in exactly
    one place, ``repro/compat.py``.  Everything else imports the shims
    (``shard_map``, ``pallas``, ...) from there, so jax API graduation is
    a one-file change.

Waivers are per-line: end the line with ``# audit: allow-<tag>``.  A
waiver is itself audited (``stale-waiver``): a comment that suppresses no
finding — the code it excused was fixed or moved, or the tag is
misspelled — is an error, so the waiver inventory can only shrink to
match reality.  Waivers are recognized in COMMENT tokens only
(``tokenize``), never inside string literals, so prose *about* waivers
(this docstring) neither suppresses nor goes stale.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Iterator

SOURCE_RULE_IDS = (
    "fuse-rows-twin", "no-int-cast", "no-raw-experimental", "stale-waiver",
)

# the tags checks consume (waiver tags name the *bug class*, not the rule
# id — ``no-int-cast`` findings are waived by ``allow-int-cast``)
WAIVER_TAGS = ("fuse-rows-twin", "int-cast", "raw-experimental")

_REDUCTIONS = ("sum", "mean", "max", "min", "prod", "dot")
_COMPAT_BASENAME = "compat.py"
_WAIVER_RE = re.compile(r"audit:\s*allow-([\w-]+)")


@dataclasses.dataclass(frozen=True)
class SourceFinding:
    rule: str
    severity: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Waivers:
    """Per-file waiver ledger: which ``# audit: allow-<tag>`` comments
    exist (COMMENT tokens only) and which of them actually suppressed a
    finding.  Whatever is left over at the end of the file check is
    stale."""

    def __init__(self, text: str):
        self.by_line: dict[int, str] = {}
        self.used: set[int] = set()
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    m = _WAIVER_RE.search(tok.string)
                    if m:
                        self.by_line[tok.start[0]] = m.group(1)
        except (SyntaxError, tokenize.TokenError):
            # the ast.parse error path reports the syntax problem
            pass

    def waived(self, lineno: int, tag: str) -> bool:
        if self.by_line.get(lineno) == tag:
            self.used.add(lineno)
            return True
        return False

    def stale(self) -> Iterator[tuple[int, str]]:
        for lineno, tag in sorted(self.by_line.items()):
            if lineno not in self.used:
                yield lineno, tag


def _check_fuse_rows_twin(path, tree, waivers) -> Iterator[SourceFinding]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        defined = {
            n.name for n in node.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        if "fuse_rows" in defined and "fuse_rows_np" not in defined:
            if waivers.waived(node.lineno, "fuse-rows-twin"):
                continue
            yield SourceFinding(
                "fuse-rows-twin", "error", path, node.lineno,
                f"class {node.name} defines fuse_rows without a bit-exact "
                "fuse_rows_np twin — the host translator cannot mirror it",
            )


def _is_reduction_call(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in _REDUCTIONS
    )


def _imports_jax(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.split(".")[0] == "jax" for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] == "jax":
                return True
    return False


def _check_int_cast(path, tree, waivers) -> Iterator[SourceFinding]:
    if not _imports_jax(tree):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in ("int", "float")
            and len(node.args) == 1
            and _is_reduction_call(node.args[0])
        ):
            if waivers.waived(node.lineno, "int-cast"):
                continue
            yield SourceFinding(
                "no-int-cast", "error", path, node.lineno,
                f"{node.func.id}() wrapped around an array reduction — on "
                "traced values this concretizes; on decayed float counts "
                "it truncates (the PR-4 histogram bug).  If the value is "
                "provably host-side, waive with `# audit: allow-int-cast`",
            )
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "item"
            and not node.args
            and not node.keywords
        ):
            if waivers.waived(node.lineno, "int-cast"):
                continue
            yield SourceFinding(
                "no-int-cast", "error", path, node.lineno,
                ".item() call — concretizes traced values; use jnp ops or "
                "waive with `# audit: allow-int-cast`",
            )


def _check_raw_experimental(path, tree, waivers) -> Iterator[SourceFinding]:
    if os.path.basename(path) == _COMPAT_BASENAME:
        return
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith("jax.experimental"):
                hit = f"from {node.module} import ..."
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("jax.experimental"):
                    hit = f"import {alias.name}"
        elif isinstance(node, ast.Attribute):
            if (
                node.attr == "experimental"
                and isinstance(node.value, ast.Name)
                and node.value.id == "jax"
            ):
                hit = "jax.experimental attribute access"
        if hit is None or waivers.waived(node.lineno, "raw-experimental"):
            continue
        yield SourceFinding(
            "no-raw-experimental", "error", path, node.lineno,
            f"{hit} outside compat.py — route the shim through "
            "repro.compat so jax API drift stays a one-file change",
        )


_CHECKS = (
    _check_fuse_rows_twin,
    _check_int_cast,
    _check_raw_experimental,
)


def check_source_file(path: str) -> list[SourceFinding]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return [SourceFinding(
            "syntax", "error", path, e.lineno or 0, f"does not parse: {e.msg}"
        )]
    waivers = _Waivers(text)
    findings: list[SourceFinding] = []
    for check in _CHECKS:
        findings.extend(check(path, tree, waivers))
    for lineno, tag in waivers.stale():
        known = "" if tag in WAIVER_TAGS else (
            f" (unknown tag; known tags: {', '.join(WAIVER_TAGS)})"
        )
        findings.append(SourceFinding(
            "stale-waiver", "error", path, lineno,
            f"`# audit: allow-{tag}` suppresses no finding{known} — the "
            "code it excused is gone; remove the waiver",
        ))
    return findings


def run_source_rules(root: str = "src/repro") -> list[SourceFinding]:
    """Walk ``root`` and check every ``.py`` file.  Deterministic order."""
    findings: list[SourceFinding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                findings.extend(
                    check_source_file(os.path.join(dirpath, fname))
                )
    return findings
