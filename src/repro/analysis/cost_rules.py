"""Quantitative cost rules over AOT-compiled entry points.

PR 6's rules pin program *structure* (launch counts, donation, dtype
hygiene); these pin program *cost*.  ``cost_profile(program)`` AOT-
compiles the captured entry point abstractly (``AuditProgram.compiled_
text`` — ShapeDtypeStructs in, optimized per-device HLO out, zero
allocation) and feeds the text through the trip-count-aware walker in
``launch/hlo_cost.py``, producing one ``CostProfile`` per entry point:

  * ``flops``       — matmul FLOPs (trip-count-corrected)
  * ``hbm_bytes``   — bytes moved across post-fusion instruction
                      boundaries (the HBM round-trips)
  * ``peak_bytes``  — peak-live-buffer estimate from HLO liveness
                      (``hlo_cost.liveness``) — the fits-on-a-device
                      number; un-donated upper bound, see DESIGN.md §8
  * ``ici/dcn_bytes`` + per-kind ``collectives`` counts
  * ``num_partitions`` — the SPMD partition count the module was
                      compiled for (budgets refuse to compare across
                      partition counts)

The rules register alongside the structural ones (same registry, same
``Finding`` report):

  * ``FlopBudget`` / ``BytesBudget`` / ``PeakMemoryBudget`` — hard caps,
    instantiated either directly in an audit spec or from a committed
    budget file (``analysis/budget.py``) with its per-metric tolerance.
  * ``CollectiveBudget`` — which collective kinds may appear at all and
    how many ICI/DCN bytes they may move.  The default instance allows
    NOTHING: the 1-device step must stay collective-free.
  * ``NoReplicatedParam`` — under a >1-partition mesh, a large param
    leaf whose per-device buffer equals its global size is replicated:
    every device pays full price for it.  The guard ROADMAP item 1
    needs before the supertable is sharded (today it *documents* the
    deliberately-replicated pointer tables at warning severity).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

from repro.analysis.program import AuditProgram, label_matches
from repro.analysis.rules import Rule, register
from repro.launch import hlo_cost
from repro.launch.dtypes import JNP_TO_HLO, shape_bytes

METRICS = ("flops", "hbm_bytes", "peak_bytes", "ici_bytes", "dcn_bytes")

_NUM_PARTITIONS = re.compile(r"num_partitions=(\d+)")
_ENTRY_PARAM = re.compile(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]")


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """Per-entry-point quantitative profile, all numbers per device."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    peak_bytes: float = 0.0
    param_bytes: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    num_partitions: int = 1

    def metric(self, name: str) -> float:
        if name not in METRICS:
            raise KeyError(f"unknown cost metric {name!r}; have {METRICS}")
        return float(getattr(self, name))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["collectives"] = {k: float(v) for k, v in sorted(self.collectives.items())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CostProfile":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_hlo_text(cls, text: str) -> "CostProfile":
        cost = hlo_cost.analyze(text)
        live = hlo_cost.liveness(text)
        m = _NUM_PARTITIONS.search(text)
        return cls(
            flops=float(cost.flops),
            hbm_bytes=float(cost.bytes),
            peak_bytes=float(live.peak_bytes),
            param_bytes=float(live.param_bytes),
            ici_bytes=float(cost.ici_bytes),
            dcn_bytes=float(cost.dcn_bytes),
            collectives={k: float(v) for k, v in cost.coll.items()},
            num_partitions=int(m.group(1)) if m else 1,
        )


def cost_profile(program: AuditProgram) -> CostProfile:
    """The program's ``CostProfile``, computed once (AOT compile + HLO
    walk) and cached on the program."""
    if program._cost_profile is None:
        program._cost_profile = CostProfile.from_hlo_text(program.compiled_text)
    return program._cost_profile


def _fmt(x: float) -> str:
    return f"{x:,.0f}"


def _over(current: float, budget: float) -> str:
    if budget <= 0:
        return f"{_fmt(current)} > budget 0"
    return (
        f"{_fmt(current)} exceeds budget {_fmt(budget)} "
        f"(+{(current / budget - 1.0) * 100.0:.1f}%)"
    )


@register
@dataclasses.dataclass(frozen=True)
class FlopBudget(Rule):
    """Matmul FLOPs per call must not exceed ``max_flops``."""

    max_flops: float = math.inf
    baseline: float | None = None  # the committed number, for the message

    id = "flop-budget"

    def check(self, program):
        cur = cost_profile(program).metric("flops")
        if cur <= self.max_flops:
            return []
        base = "" if self.baseline is None else (
            f"; committed baseline {_fmt(self.baseline)}"
        )
        return [self.finding(
            program, "", f"flops {_over(cur, self.max_flops)}{base}",
        )]


@register
@dataclasses.dataclass(frozen=True)
class BytesBudget(Rule):
    """HBM bytes moved per call must not exceed ``max_bytes``."""

    max_bytes: float = math.inf
    baseline: float | None = None

    id = "bytes-budget"

    def check(self, program):
        cur = cost_profile(program).metric("hbm_bytes")
        if cur <= self.max_bytes:
            return []
        base = "" if self.baseline is None else (
            f"; committed baseline {_fmt(self.baseline)}"
        )
        return [self.finding(
            program, "", f"hbm_bytes {_over(cur, self.max_bytes)}{base}",
        )]


@register
@dataclasses.dataclass(frozen=True)
class PeakMemoryBudget(Rule):
    """Estimated peak live bytes must not exceed ``max_bytes`` — the
    budget that decides whether the config still fits a device."""

    max_bytes: float = math.inf
    baseline: float | None = None

    id = "peak-memory-budget"

    def check(self, program):
        cur = cost_profile(program).metric("peak_bytes")
        if cur <= self.max_bytes:
            return []
        base = "" if self.baseline is None else (
            f"; committed baseline {_fmt(self.baseline)}"
        )
        return [self.finding(
            program, "", f"peak_bytes {_over(cur, self.max_bytes)}{base}",
        )]


@register
@dataclasses.dataclass(frozen=True)
class CollectiveBudget(Rule):
    """Only collective kinds in ``allow`` may appear, and their traffic
    must stay within the ICI/DCN byte caps.  The default allows NOTHING
    — the 1-device step's contract is zero collectives."""

    allow: tuple[str, ...] = ()
    max_ici_bytes: float = 0.0
    max_dcn_bytes: float = 0.0

    id = "collective-budget"

    def check(self, program):
        prof = cost_profile(program)
        findings = []
        for kind in sorted(prof.collectives):
            if prof.collectives[kind] > 0 and kind not in self.allow:
                allowed = f"allowed kinds: {list(self.allow)}" if self.allow \
                    else "no collectives allowed"
                findings.append(self.finding(
                    program, "",
                    f"collective {kind} x{prof.collectives[kind]:g} in the "
                    f"compiled module; {allowed}",
                ))
        if prof.ici_bytes > self.max_ici_bytes:
            findings.append(self.finding(
                program, "", f"ici_bytes {_over(prof.ici_bytes, self.max_ici_bytes)}",
            ))
        if prof.dcn_bytes > self.max_dcn_bytes:
            findings.append(self.finding(
                program, "", f"dcn_bytes {_over(prof.dcn_bytes, self.max_dcn_bytes)}",
            ))
        return findings


def _entry_param_shapes(text: str) -> list[tuple[str, str]]:
    """(dtype, dims) of every entry-computation parameter in the compiled
    module — per-device shapes, post-SPMD-partitioning."""
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            head = line.split("->")[0]
            return [
                (m.group(2), m.group(3)) for m in _ENTRY_PARAM.finditer(head)
            ]
    return []


@register
@dataclasses.dataclass(frozen=True)
class NoReplicatedParam(Rule):
    """Under a >1-partition compile, a large input leaf whose per-device
    entry-parameter buffer equals its GLOBAL size is replicated — every
    device holds the whole array.  ``allow`` names leaves replicated by
    contract; ``severity="warning"`` documents known replication without
    failing the gate (how the sharded-transition specs ride until
    ROADMAP item 1 shards the supertable).  Matching is by (dtype, byte
    size): exact per-device metadata is not in the HLO text, so a leaf
    is only flagged when SOME entry param still has its full global
    footprint — fail-open, never a false sharded-pass."""

    min_bytes: int = 1 << 20
    allow: tuple[str, ...] = ()
    severity: str = "error"

    id = "no-replicated-param"

    def check(self, program):
        labeled = program.labeled_invars()
        if not labeled:
            return [self.finding(
                program, "",
                "inputs could not be labeled (flat invars != arg leaves); "
                "cannot attribute replicated params",
            )]
        prof = cost_profile(program)
        if prof.num_partitions <= 1:
            return [self.finding(
                program, "",
                "compiled for a single partition — nothing to prove; run "
                "this spec under a multi-device mesh (check the audit "
                "config's lane)",
            )]
        params = _entry_param_shapes(program.compiled_text)
        if not params:
            return [self.finding(
                program, "",
                "no entry parameters parsed from the compiled module; "
                "cannot check replication",
            )]
        param_sizes = {(dt, shape_bytes(dt, dims)) for dt, dims in params}
        findings = []
        for lbl, var in labeled:
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            dtype = getattr(aval, "dtype", None)
            if shape is None or dtype is None:
                continue
            nbytes = int(math.prod(shape)) * int(
                getattr(dtype, "itemsize", 1) or 1
            )
            if nbytes < self.min_bytes:
                continue
            if self.allow and label_matches(lbl, self.allow):
                continue
            hlo_dt = JNP_TO_HLO.get(str(dtype))
            if hlo_dt is not None and (hlo_dt, nbytes) in param_sizes:
                findings.append(self.finding(
                    program, lbl,
                    f"input {lbl} ({_fmt(nbytes)} bytes) appears at full "
                    f"global size in the {prof.num_partitions}-partition "
                    "module — replicated on every device; shard it or "
                    "allowlist it",
                ))
        return findings
