"""``python -m repro.analysis`` — the audit gate CI runs.

Two layers, selectable independently:

  * jaxpr/HLO audit (``--config``, default ``dlrm_criteo``): trace the
    config's entry points abstractly and run their rule bundles.
  * AST source rules (always on unless ``--jaxpr-only``): stdlib-only,
    so ``--source-only`` works in an environment without jax — that is
    what the lint CI job runs.

Exit status 1 iff any error-severity finding; ``--json PATH`` writes the
structured report (CI uploads it as ``AUDIT_report.json``).
"""
from __future__ import annotations

import argparse
import json
import sys


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over traced jaxprs, lowerings, and source",
    )
    p.add_argument("--config", default="dlrm_criteo",
                   help="audit config name (default: dlrm_criteo)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the JSON report here ('-' for stdout)")
    p.add_argument("--source-only", action="store_true",
                   help="run only the AST source rules (no jax import)")
    p.add_argument("--jaxpr-only", action="store_true",
                   help="skip the AST source rules")
    p.add_argument("--source-root", default="src/repro",
                   help="directory the source rules walk")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rule ids and exit")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)

    if args.list_rules:
        from repro.analysis.rules import RULES
        from repro.analysis.source_rules import SOURCE_RULE_IDS

        for rid in sorted(RULES):
            print(f"jaxpr   {rid}")
        for rid in SOURCE_RULE_IDS:
            print(f"source  {rid}")
        return 0

    report_dict: dict = {"ok": True}
    n_errors = 0

    if not args.jaxpr_only:
        from repro.analysis.source_rules import run_source_rules

        src_findings = run_source_rules(args.source_root)
        report_dict["source_findings"] = [f.to_dict() for f in src_findings]
        for f in src_findings:
            if f.severity == "error":
                n_errors += 1
            print(f"[{f.rule}] {f.path}:{f.line}: {f.message}",
                  file=sys.stderr)

    if not args.source_only:
        from repro.analysis.audit import run_audit  # imports jax

        report = run_audit(args.config)
        report_dict.update(report.to_dict())
        for f in report.findings:
            if f.severity == "error":
                n_errors += 1
            where = f" at {f.where}" if f.where else ""
            print(f"[{f.rule}] {f.program}{where}: {f.message}",
                  file=sys.stderr)

    report_dict["ok"] = n_errors == 0
    text = json.dumps(report_dict, indent=2)
    if args.json == "-":
        print(text)
    elif args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")

    label = "AUDIT PASS" if n_errors == 0 else f"AUDIT FAIL ({n_errors} errors)"
    print(label, file=sys.stderr)
    return 0 if n_errors == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
