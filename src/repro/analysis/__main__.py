"""``python -m repro.analysis`` — the audit gate CI runs.

Two layers, selectable independently:

  * jaxpr/HLO audit (``--config``, default ``dlrm_criteo``): trace the
    config's entry points abstractly and run their rule bundles.
  * AST source rules (always on unless ``--jaxpr-only``): stdlib-only,
    so ``--source-only`` works in an environment without jax — that is
    what the lint CI job runs.
  * cost budgets (``--budgets [PATH]``): AOT-compile each entry point
    abstractly, compute its ``CostProfile``, and gate it against the
    committed budget file (default ``budgets/<config>.json``).  Exits
    non-zero on any metric regression; ``--cost-report PATH`` writes the
    full current-vs-committed diff (CI uploads it as
    ``COST_report.json``); ``--update-budgets`` regenerates the file and
    prints the old→new diff for review (DESIGN.md §8).

Exit status 1 iff any error-severity finding; ``--json PATH`` writes the
structured report (CI uploads it as ``AUDIT_report.json``).
"""
from __future__ import annotations

import argparse
import json
import sys


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over traced jaxprs, lowerings, and source",
    )
    p.add_argument("--config", default="dlrm_criteo",
                   help="audit config name (default: dlrm_criteo)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the JSON report here ('-' for stdout)")
    p.add_argument("--source-only", action="store_true",
                   help="run only the AST source rules (no jax import)")
    p.add_argument("--jaxpr-only", action="store_true",
                   help="skip the AST source rules")
    p.add_argument("--source-root", default="src/repro",
                   help="directory the source rules walk")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rule ids and exit")
    p.add_argument("--budgets", metavar="PATH", nargs="?", const="auto",
                   default=None,
                   help="gate cost profiles against a committed budget file "
                        "(default path: budgets/<config>.json)")
    p.add_argument("--update-budgets", action="store_true",
                   help="regenerate the budget file from current profiles "
                        "and print the diff (implies --budgets)")
    p.add_argument("--cost-report", metavar="PATH", default=None,
                   help="write the current-vs-committed metric diff here")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)

    if args.list_rules:
        import repro.analysis.cost_rules  # noqa: F401 — registers cost rules
        from repro.analysis.rules import RULES
        from repro.analysis.source_rules import SOURCE_RULE_IDS

        for rid in sorted(RULES):
            print(f"jaxpr   {rid}")
        for rid in SOURCE_RULE_IDS:
            print(f"source  {rid}")
        return 0

    report_dict: dict = {"ok": True}
    n_errors = 0

    if not args.jaxpr_only:
        from repro.analysis.source_rules import run_source_rules

        src_findings = run_source_rules(args.source_root)
        report_dict["source_findings"] = [f.to_dict() for f in src_findings]
        for f in src_findings:
            if f.severity == "error":
                n_errors += 1
            print(f"[{f.rule}] {f.path}:{f.line}: {f.message}",
                  file=sys.stderr)

    if not args.source_only:
        import os

        from repro.analysis.audit import run_audit  # imports jax

        want_cost = args.budgets is not None or args.update_budgets
        budget = None
        budget_path = None
        if want_cost:
            from repro.analysis.budget import (
                BudgetFile,
                diff_profiles,
                diff_summary,
            )

            budget_path = (
                args.budgets if args.budgets not in (None, "auto")
                else os.path.join("budgets", f"{args.config}.json")
            )
            if os.path.exists(budget_path):
                budget = BudgetFile.load(budget_path)
            elif not args.update_budgets:
                print(
                    f"no budget file at {budget_path}; run --update-budgets "
                    "to create it", file=sys.stderr,
                )
                return 2

        # when regenerating, the old budget is a diff baseline, not a gate
        report = run_audit(
            args.config, with_cost=want_cost,
            budget=None if args.update_budgets else budget,
        )
        report_dict.update(report.to_dict())
        for f in report.findings:
            if f.severity == "error":
                n_errors += 1
            where = f" at {f.where}" if f.where else ""
            print(f"[{f.rule}] {f.program}{where}: {f.message}",
                  file=sys.stderr)

        if want_cost:
            diffs = (
                diff_profiles(budget, report.profiles) if budget is not None
                else []
            )
            if args.cost_report:
                payload = {
                    "config": args.config,
                    "budget_file": budget_path,
                    "updated": bool(args.update_budgets),
                    "diffs": [d.to_dict() for d in diffs],
                    "profiles": {
                        k: p.to_dict() for k, p in report.profiles.items()
                    },
                }
                with open(args.cost_report, "w", encoding="utf-8") as fh:
                    json.dump(payload, fh, indent=2)
                    fh.write("\n")
            if args.update_budgets:
                os.makedirs(os.path.dirname(budget_path) or ".", exist_ok=True)
                new = BudgetFile.from_profiles(
                    args.config, report.profiles,
                    tolerances=budget.tolerances if budget else None,
                )
                new.save(budget_path)
                print(f"budget file written: {budget_path}", file=sys.stderr)
                if diffs:
                    print("diff vs previous:\n" + diff_summary(diffs),
                          file=sys.stderr)
            elif budget is not None:
                print("budget diff vs committed:\n" + diff_summary(diffs),
                      file=sys.stderr)

    report_dict["ok"] = n_errors == 0
    text = json.dumps(report_dict, indent=2)
    if args.json == "-":
        print(text)
    elif args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")

    label = "AUDIT PASS" if n_errors == 0 else f"AUDIT FAIL ({n_errors} errors)"
    print(label, file=sys.stderr)
    return 0 if n_errors == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
