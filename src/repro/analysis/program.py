"""Capture a jitted entry point as an auditable program.

``AuditProgram.capture`` traces a callable to a closed jaxpr (abstract —
``jax.ShapeDtypeStruct`` args work, so the FULL Criteo config audits with
zero array allocation) and labels every flattened input variable with its
pytree path (``[1]['emb'][0][2]['ptr']``).  Rules then talk about inputs
by *name* — "the ptr buffers", "the donated state leaves" — instead of by
flat position, which is what makes audit specs declarative.

Lowering (for donation/aliasing rules) and AOT compilation (for the
quantitative cost rules — the compiled module is what ``launch/hlo_cost``
walks) are lazy and cached: tracing is milliseconds, lowering the full
train step is seconds, compiling it is tens of seconds, and most rules
only need the jaxpr.  Compilation is abstract end to end (AOT: lower +
compile on ShapeDtypeStructs) — no buffer is ever allocated.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax


def _tree_labels(args) -> tuple[str, ...]:
    flat = jax.tree_util.tree_leaves_with_path(args)
    return tuple(jax.tree_util.keystr(path) for path, _ in flat)


def label_matches(label: str, names: tuple[str, ...]) -> bool:
    """True when the pytree path ``label`` passes through a dict key in
    ``names`` (``[0]['emb'][1]['ptr']`` matches name ``ptr``)."""
    return any(re.search(rf"\['{re.escape(n)}'\]", label) for n in names)


def _unwrap_trivial_pjit(closed):
    """``make_jaxpr`` of an already-jitted fn yields a one-equation
    wrapper — every invar feeds a single pjit call — which defeats
    use/def analysis: every input looks consumed.  When the wrapper is
    exactly that trivial (one pjit eqn fed the outer invars in order),
    hand rules the body jaxpr instead; positional labeling still holds
    because pjit binds its operands 1:1."""
    jaxpr = closed.jaxpr
    if (
        len(jaxpr.eqns) == 1
        and jaxpr.eqns[0].primitive.name == "pjit"
        and tuple(map(id, jaxpr.eqns[0].invars)) == tuple(map(id, jaxpr.invars))
    ):
        inner = jaxpr.eqns[0].params.get("jaxpr")
        if inner is not None and len(inner.jaxpr.invars) == len(jaxpr.invars):
            return inner
    return closed


@dataclasses.dataclass
class AuditProgram:
    """One traced entry point: the closed jaxpr, a label per flat input
    variable, and (lazily) the lowered StableHLO text."""

    name: str
    closed: Any
    invar_labels: tuple[str, ...]
    n_donated: int = 0
    _lower_thunk: Callable[[], str] | None = None
    _lowered_text: str | None = None
    _compile_thunk: Callable[[], str] | None = None
    _compiled_text: str | None = None
    _cost_profile: Any = None  # cost_rules.cost_profile caches here

    @classmethod
    def capture(
        cls,
        fn: Callable,
        *args,
        name: str = "program",
        donate_argnums: tuple[int, ...] = (),
    ) -> "AuditProgram":
        """Trace ``fn(*args)``; args may be arrays or ShapeDtypeStructs.

        ``donate_argnums`` drives the donation-coverage accounting AND the
        lowering: if ``fn`` is already jitted (has ``.lower``) its own
        donation settings are used, otherwise the capture jits it with
        exactly these argnums.
        """
        closed = _unwrap_trivial_pjit(jax.make_jaxpr(fn)(*args))
        labels = _tree_labels(args)
        if len(labels) != len(closed.jaxpr.invars):
            # tracing didn't flatten 1:1 (static args, captured trees):
            # label-based rules will refuse rather than silently misbind
            labels = ()
        n_donated = sum(
            len(jax.tree_util.tree_leaves(args[i])) for i in donate_argnums
        )

        def jitted():
            return fn if hasattr(fn, "lower") else jax.jit(
                fn, donate_argnums=donate_argnums
            )

        def lower() -> str:
            return jitted().lower(*args).as_text()

        def compile_() -> str:
            # AOT: abstract args in, optimized per-device HLO text out —
            # compiles the executable without allocating any buffer
            return jitted().lower(*args).compile().as_text()

        return cls(
            name=name,
            closed=closed,
            invar_labels=labels,
            n_donated=n_donated,
            _lower_thunk=lower,
            _compile_thunk=compile_,
        )

    @property
    def lowered_text(self) -> str:
        if self._lowered_text is None:
            if self._lower_thunk is None:
                raise RuntimeError(
                    f"program {self.name!r} was built without a lowering"
                )
            self._lowered_text = self._lower_thunk()
        return self._lowered_text

    @property
    def compiled_text(self) -> str:
        """Optimized (post-fusion, SPMD-partitioned) HLO of the AOT-compiled
        entry point — the text the quantitative cost analysis walks."""
        if self._compiled_text is None:
            if self._compile_thunk is None:
                raise RuntimeError(
                    f"program {self.name!r} was built without a compilation"
                )
            self._compiled_text = self._compile_thunk()
        return self._compiled_text

    def labeled_invars(self) -> tuple[tuple[str, Any], ...]:
        """(label, invar) pairs; empty labels mean capture couldn't match
        flat inputs to tree paths (rules that need names must complain)."""
        if not self.invar_labels:
            return ()
        return tuple(zip(self.invar_labels, self.closed.jaxpr.invars))
