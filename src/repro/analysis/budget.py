"""Committed per-config cost budgets and the regression diff.

A budget file (``budgets/<config>.json``) freezes one ``CostProfile``
per audited entry point plus a per-metric relative tolerance.  The CLI
(``python -m repro.analysis --config C --budgets budgets/C.json``)
recomputes the profiles abstractly, instantiates the cost rules from the
committed numbers (``rules_for``), and fails the build on any metric
exceeding ``committed * (1 + tol)`` — quantitative drift becomes a red X
exactly like a planted ptr-gather does.

Semantics:

  * regression  — current > committed * (1 + tol) (+ a small absolute
    slack so near-zero baselines don't flag on noise; ici/dcn get NO
    slack: zero collectives must stay zero).  Error finding, exit 1.
  * improvement — current < committed * (1 - tol).  Warning in the diff
    report only: run ``--update-budgets`` to ratchet the budget down so
    the win is locked in.
  * structural  — entry point missing from the budget file, a committed
    entry whose program vanished, or a partition-count mismatch (numbers
    compiled for different SPMD meshes are not comparable).  Error.

``--update-budgets`` regenerates the file and prints the old→new diff
for review; the intentional-regression workflow is DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
import json

from repro.analysis.cost_rules import (
    METRICS,
    BytesBudget,
    CollectiveBudget,
    CostProfile,
    FlopBudget,
    PeakMemoryBudget,
)
from repro.analysis.rules import Finding, Rule

FORMAT_VERSION = 1

DEFAULT_TOLERANCES = {
    "flops": 0.10,
    "hbm_bytes": 0.25,
    "peak_bytes": 0.25,
    "ici_bytes": 0.25,
    "dcn_bytes": 0.25,
}

# absolute slack: a 1 MFLOP / 64 KiB wobble on a near-zero baseline is
# compiler noise, not a regression; collective bytes get NONE — the
# 1-device step's zero must stay an exact zero
_ABS_SLACK = {
    "flops": 1e6,
    "hbm_bytes": float(1 << 16),
    "peak_bytes": float(1 << 16),
    "ici_bytes": 0.0,
    "dcn_bytes": 0.0,
}


def allowed_max(committed: float, metric: str, tolerances: dict) -> float:
    tol = float(tolerances.get(metric, 0.0))
    return max(committed * (1.0 + tol), committed + _ABS_SLACK[metric])


def _structural(program: str, message: str) -> Finding:
    return Finding(
        rule="budget-file", severity="error", program=program,
        where="", message=message,
    )


@dataclasses.dataclass
class BudgetFile:
    """One committed budget: per-program metric values + tolerances."""

    config: str
    programs: dict[str, dict]
    tolerances: dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_TOLERANCES)
    )

    @classmethod
    def from_profiles(
        cls,
        config: str,
        profiles: dict[str, CostProfile],
        tolerances: dict[str, float] | None = None,
    ) -> "BudgetFile":
        return cls(
            config=config,
            programs={name: prof.to_dict() for name, prof in profiles.items()},
            tolerances=dict(tolerances or DEFAULT_TOLERANCES),
        )

    def to_dict(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "config": self.config,
            "command": (
                f"python -m repro.analysis --config {self.config} "
                "--update-budgets"
            ),
            "tolerances": self.tolerances,
            "programs": self.programs,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "BudgetFile":
        return cls(
            config=d["config"],
            programs=d["programs"],
            tolerances=d.get("tolerances", dict(DEFAULT_TOLERANCES)),
        )

    @classmethod
    def load(cls, path: str) -> "BudgetFile":
        with open(path, encoding="utf-8") as fh:
            return cls.from_dict(json.load(fh))

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")

    # --- the gate --------------------------------------------------------

    def rules_for(self, name: str) -> tuple[Rule, ...] | None:
        """The cost-rule instances encoding this budget for one entry
        point (None when the program has no committed entry)."""
        entry = self.programs.get(name)
        if entry is None:
            return None
        t = self.tolerances
        coll = entry.get("collectives", {})
        return (
            FlopBudget(
                max_flops=allowed_max(entry["flops"], "flops", t),
                baseline=entry["flops"],
            ),
            BytesBudget(
                max_bytes=allowed_max(entry["hbm_bytes"], "hbm_bytes", t),
                baseline=entry["hbm_bytes"],
            ),
            PeakMemoryBudget(
                max_bytes=allowed_max(entry["peak_bytes"], "peak_bytes", t),
                baseline=entry["peak_bytes"],
            ),
            CollectiveBudget(
                allow=tuple(sorted(k for k, v in coll.items() if v > 0)),
                max_ici_bytes=allowed_max(entry["ici_bytes"], "ici_bytes", t),
                max_dcn_bytes=allowed_max(entry["dcn_bytes"], "dcn_bytes", t),
            ),
        )

    def structural_findings(
        self, profiles: dict[str, CostProfile]
    ) -> list[Finding]:
        """Coverage + comparability: every audited program budgeted, every
        budgeted program still audited, partition counts equal."""
        findings = []
        for name, prof in profiles.items():
            entry = self.programs.get(name)
            if entry is None:
                findings.append(_structural(
                    name,
                    f"entry point {name!r} has no committed budget — run "
                    "--update-budgets and review the diff",
                ))
                continue
            committed_parts = int(entry.get("num_partitions", 1))
            if committed_parts != prof.num_partitions:
                findings.append(_structural(
                    name,
                    f"budget was committed at num_partitions="
                    f"{committed_parts} but the module compiled for "
                    f"{prof.num_partitions} — run the matching lane or "
                    "regenerate the budget",
                ))
        for name in sorted(set(self.programs) - set(profiles)):
            findings.append(_structural(
                name,
                f"committed budget entry {name!r} matches no audited entry "
                "point — stale budget file, run --update-budgets",
            ))
        return findings


@dataclasses.dataclass(frozen=True)
class MetricDiff:
    """One (program, metric) row of the budget diff report."""

    program: str
    metric: str
    committed: float
    current: float
    status: str  # ok | regression | improvement

    @property
    def rel_change(self) -> float:
        if self.committed == 0:
            return 0.0 if self.current == 0 else float("inf")
        return self.current / self.committed - 1.0

    def to_dict(self) -> dict:
        return {
            "program": self.program,
            "metric": self.metric,
            "committed": self.committed,
            "current": self.current,
            "rel_change": self.rel_change,
            "status": self.status,
        }


def diff_profiles(
    budget: BudgetFile, profiles: dict[str, CostProfile]
) -> list[MetricDiff]:
    """Full current-vs-committed diff, every metric of every program —
    the COST_report.json payload.  Informational: pass/fail comes from
    the rules ``rules_for`` builds, which share ``allowed_max``."""
    diffs = []
    for name in sorted(profiles):
        entry = budget.programs.get(name)
        if entry is None:
            continue
        prof = profiles[name]
        for metric in METRICS:
            committed = float(entry[metric])
            current = prof.metric(metric)
            if current > allowed_max(committed, metric, budget.tolerances):
                status = "regression"
            elif current < committed * (
                1.0 - budget.tolerances.get(metric, 0.0)
            ):
                status = "improvement"
            else:
                status = "ok"
            diffs.append(MetricDiff(
                program=name, metric=metric,
                committed=committed, current=current, status=status,
            ))
    return diffs


def diff_summary(diffs: list[MetricDiff], *, changed_only: bool = True) -> str:
    """Human-readable diff table (printed by --budgets/--update-budgets)."""
    lines = []
    for d in diffs:
        if changed_only and d.status == "ok":
            continue
        rel = "inf" if d.rel_change == float("inf") else f"{d.rel_change:+.1%}"
        lines.append(
            f"  {d.program}.{d.metric}: {d.committed:,.0f} -> "
            f"{d.current:,.0f} ({rel}) [{d.status}]"
        )
    return "\n".join(lines) if lines else "  (all metrics within tolerance)"
