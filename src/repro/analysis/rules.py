"""Declarative rule registry over traced/lowered programs.

Each rule is a small dataclass with a stable ``id`` and a
``check(program) -> [Finding]`` method; ``audit_program`` runs a rule
list against one ``AuditProgram`` and concatenates the structured
findings (rule id, severity, program, eqn path / input label, human
message).  The registry exists so the CLI can enumerate shipped rules
and so audit specs (analysis/audit.py) stay data: a list of rule
instances per entry point.

Shipped rules encode the invariants PRs 3–5 fought for:

  * ``LaunchBudget``      — pallas_call count per program (26 → 3 → 1)
  * ``NoDeviceGatherOf``  — host-translated rows mean the device program
                            must never consume the ptr/hs tables
  * ``DonationCoverage``  — every donated leaf carries an input-output
                            alias in the lowering (in-place TrainState)
  * ``DtypeHygiene``      — no f64/complex leaks on the hot path
  * ``NoHostCallback``    — no pure/io/debug callbacks inside the step
  * ``NoTransfers``       — no device_put inside the traced program
  * ``ConstantCapture``   — no large arrays baked in as jaxpr consts
                            (the PR-1 closed-over-hash-coefficients bug
                            class: stale AND resident in every program)
  * ``DeadInput``         — invars threaded but never consumed

Adding a rule: subclass ``Rule`` as a (frozen) dataclass, give it a
unique ``id``, decorate with ``@register``, and emit findings via
``self.finding(...)``.  See DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

from repro.analysis.program import AuditProgram, label_matches
from repro.analysis.walker import iter_consts, used_var_ids, walk

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured violation: machine-stable ids/paths plus a human
    message — the JSON report is a list of these."""

    rule: str
    severity: str
    program: str
    where: str  # eqn path, invar label, or "" for program-level findings
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


RULES: dict[str, type] = {}


def register(cls):
    """Add a Rule subclass to the registry (keyed by its stable id)."""
    if not getattr(cls, "id", None):
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in RULES:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULES[cls.id] = cls
    return cls


@dataclasses.dataclass(frozen=True)
class Rule:
    """Base: parameters live on the (frozen) dataclass, state does not —
    a rule instance is reusable across programs."""

    id = ""  # class attribute, overridden per subclass
    severity = "error"

    def check(self, program: AuditProgram) -> list[Finding]:
        raise NotImplementedError

    def finding(self, program: AuditProgram, where: str, message: str,
                *, severity: str | None = None) -> Finding:
        return Finding(
            rule=self.id,
            severity=severity or self.severity,
            program=program.name,
            where=where,
            message=message,
        )


def audit_program(program: AuditProgram, rules) -> list[Finding]:
    """Run every rule against one captured program."""
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(rule.check(program))
    return findings


# --- the shipped rules --------------------------------------------------------


@register
@dataclasses.dataclass(frozen=True)
class LaunchBudget(Rule):
    """The compiled program issues at most (``exact=False``) or exactly
    (default) ``budget`` launches of ``primitive`` — the 26 → 3 → 1
    trajectory, frozen as a gate."""

    budget: int = 1
    primitive: str = "pallas_call"
    exact: bool = True

    id = "launch-budget"

    def check(self, program):
        sites = [s for s in walk(program.closed) if s.primitive == self.primitive]
        n = len(sites)
        bad = n != self.budget if self.exact else n > self.budget
        if not bad:
            return []
        rel = "exactly" if self.exact else "at most"
        where = sites[self.budget].path if n > self.budget else ""
        return [self.finding(
            program, where,
            f"{n} {self.primitive} launches; budget is {rel} {self.budget}",
        )]


@register
@dataclasses.dataclass(frozen=True)
class NoDeviceGatherOf(Rule):
    """Inputs whose pytree path passes through one of ``names`` (e.g. the
    CCE ``ptr``/``hs`` buffers) must appear in NO equation: with
    host-translated rows the device program never touches the pointer
    tables (DESIGN.md §4).  Vacuous passes are themselves findings — if
    no input matches, the audit spec is mislabeled."""

    names: tuple[str, ...] = ("ptr", "hs")

    id = "no-device-gather"

    def check(self, program):
        labeled = program.labeled_invars()
        if not labeled:
            return [self.finding(
                program, "",
                "inputs could not be labeled (flat invars != arg leaves); "
                "cannot prove the pointer tables are unread",
            )]
        matched = [(lbl, v) for lbl, v in labeled
                   if label_matches(lbl, self.names)]
        if not matched:
            return [self.finding(
                program, "",
                f"no input matches {self.names} — vacuously true, check "
                "the audit spec",
            )]
        used = used_var_ids(program.closed, include_outputs=False)
        return [
            self.finding(
                program, lbl,
                f"input {lbl} (one of {self.names}) is consumed by the "
                "device program; host translation must keep it unread",
            )
            for lbl, v in matched if id(v) in used
        ]


@register
@dataclasses.dataclass(frozen=True)
class DonationCoverage(Rule):
    """Every donated input leaf must carry an input-output alias in the
    lowering (``tf.aliasing_output`` is how StableHLO records jit
    donation).  A donated leaf without an alias means XLA will copy —
    the in-place TrainState contract silently broke."""

    id = "donation-coverage"

    def check(self, program):
        if program.n_donated == 0:
            return [self.finding(
                program, "",
                "program donates nothing; DonationCoverage has nothing to "
                "prove — check the audit spec's donate_argnums",
            )]
        n_aliased = program.lowered_text.count("tf.aliasing_output")
        if n_aliased >= program.n_donated:
            return []
        return [self.finding(
            program, "",
            f"{program.n_donated} leaves donated but only {n_aliased} "
            "input-output aliases in the lowering — the rest will be "
            "copied, not updated in place",
        )]


@register
@dataclasses.dataclass(frozen=True)
class DtypeHygiene(Rule):
    """No equation output (anywhere, including sub-jaxprs) may carry a
    forbidden dtype — f64 leaks and silent complex promotions double the
    hot path's bytes and never belong in this codebase's programs."""

    forbid: tuple[str, ...] = ("float64", "complex64", "complex128")

    id = "dtype-hygiene"

    def check(self, program):
        findings = []
        for site in walk(program.closed):
            for var in site.eqn.outvars:
                dtype = getattr(getattr(var, "aval", None), "dtype", None)
                if dtype is not None and str(dtype) in self.forbid:
                    findings.append(self.finding(
                        program, site.path,
                        f"{site.primitive} produces {dtype} "
                        f"(forbidden: {self.forbid})",
                    ))
        return findings


@register
@dataclasses.dataclass(frozen=True)
class NoHostCallback(Rule):
    """The step must not round-trip through the host: no
    pure/io/debug callbacks anywhere in the program."""

    primitives: tuple[str, ...] = (
        "pure_callback", "io_callback", "debug_callback",
    )

    id = "no-host-callback"

    def check(self, program):
        return [
            self.finding(
                program, site.path,
                f"host callback primitive {site.primitive} inside the "
                "program — the step must stay on device",
            )
            for site in walk(program.closed)
            if site.primitive in self.primitives
        ]


def _is_real_transfer(eqn) -> bool:
    """jax lowers some pure-aliasing internals (scalar promotion paths)
    to ``device_put`` with no target device and ALIAS copy semantics —
    XLA elides those.  A REAL transfer names a device/sharding or forces
    a copy; unknown param shapes fail closed (flagged)."""
    devices = eqn.params.get("devices", None)
    semantics = eqn.params.get("copy_semantics", None)
    if devices is None or semantics is None:
        return True
    return any(d is not None for d in devices) or any(
        "ALIAS" not in str(s) for s in semantics
    )


@register
@dataclasses.dataclass(frozen=True)
class NoTransfers(Rule):
    """No explicit transfers inside the traced program (``device_put``
    to a concrete device/sharding, or one forcing a copy, in a jitted
    step is a placement XLA cannot fuse away)."""

    primitives: tuple[str, ...] = ("device_put",)

    id = "no-transfers"

    def check(self, program):
        return [
            self.finding(
                program, site.path,
                f"transfer primitive {site.primitive} with a concrete "
                "placement or copy inside the program",
            )
            for site in walk(program.closed)
            if site.primitive in self.primitives
            and (site.primitive != "device_put" or _is_real_transfer(site.eqn))
        ]


def _nbytes(const: Any) -> int:
    shape = getattr(const, "shape", None)
    dtype = getattr(const, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(math.prod(shape)) * int(getattr(dtype, "itemsize", 1) or 1)


@register
@dataclasses.dataclass(frozen=True)
class ConstantCapture(Rule):
    """No large arrays baked into the jaxpr as constants.  A big const is
    (a) resident in EVERY executable built from the program and (b) the
    signature of accidentally closing over state the program should take
    as an argument — the exact bug class PR 1 fixed when the CCE helper
    hashes were closed over statically and went stale across transitions."""

    max_bytes: int = 1 << 16

    id = "constant-capture"

    def check(self, program):
        findings = []
        for path, const in iter_consts(program.closed):
            nbytes = _nbytes(const)
            if nbytes > self.max_bytes:
                shape = getattr(const, "shape", ())
                dtype = getattr(const, "dtype", "?")
                findings.append(self.finding(
                    program, path,
                    f"captured constant {shape} {dtype} ({nbytes} bytes > "
                    f"{self.max_bytes}) — pass it as an argument instead",
                ))
        return findings


@register
@dataclasses.dataclass(frozen=True)
class DeadInput(Rule):
    """Inputs threaded through the signature but never consumed.  Dead
    inputs hide stale plumbing — except the ones that are dead BY
    CONTRACT (the ptr/hs buffers on the host-translated path), which the
    audit spec allowlists by name."""

    allow: tuple[str, ...] = ()

    id = "dead-input"

    def check(self, program):
        labeled = program.labeled_invars()
        if not labeled:
            return [self.finding(
                program, "",
                "inputs could not be labeled (flat invars != arg leaves); "
                "cannot attribute dead inputs",
            )]
        used = used_var_ids(program.closed, include_outputs=True)
        return [
            self.finding(
                program, lbl,
                f"input {lbl} is never consumed by the program",
            )
            for lbl, var in labeled
            if id(var) not in used
            and not (self.allow and label_matches(lbl, self.allow))
        ]
