"""Streaming frequency statistics for the clustering transition.

The layer between the data stream and the transition (DESIGN.md §5):
sketch-based per-feature frequency tracking at vocab-independent memory
(``sketch``), the tracker + windowing/decay semantics (``tracker``), the
entropy/drift-triggered adaptive transition schedule (``trigger``), the
device-side async update path (``device``), and the k-means point-set
construction both the dense and sketched trackers share (``points``).
"""
from repro.stream.points import (  # noqa: F401
    points_from_counts,
    sample_from_counts,
    stratified_points,
)
from repro.stream.sketch import (  # noqa: F401
    CountMinSketch,
    FeatureSketch,
    SpaceSaving,
)
from repro.stream.tracker import (  # noqa: F401
    IdFrequencyTracker,
    SketchFrequencyTracker,
    StreamConfig,
)
from repro.stream.device import make_step_cell_counter  # noqa: F401
from repro.stream.trigger import ClusterTrigger, TriggerEvent  # noqa: F401
