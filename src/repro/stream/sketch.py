"""Sketch-based frequency statistics: count-min + SpaceSaving + recent ring.

One ``FeatureSketch`` replaces one dense per-vocab histogram row of the
old ``IdFrequencyTracker`` at O(width·depth + heavy + ring) memory —
independent of the vocabulary size, which is the whole point at the
multi-hundred-million-row scale the ROADMAP targets (CAFE, Zhang et al.
2023, is the production precedent for exactly this split):

  * ``CountMinSketch`` — (depth, width) float counters, multiply-shift
    hashing (width a power of two so the row hash is one uint32 multiply
    + shift, expressible identically in numpy AND jnp — the device-side
    batch counter in stream/device.py must land in the same cells).
    ``add`` is the CONSERVATIVE update (only raise a cell to the new
    minimum-estimate, vectorized over a batch of unique ids);
    ``add_cells`` folds a device-computed (depth, width) delta (plain
    CMS add — conservativeness needs per-id estimates the segment-sum
    path deliberately avoids).  ``estimate`` is the classic min-row
    upper bound; ``estimate_unbiased`` the count-mean correction
    (subtract each row's expected collision noise, take the median) —
    what the k-means tail weights use so collisions don't systematically
    inflate the tail.
  * ``SpaceSaving`` — fixed-capacity exact counters for the head.  An
    id's increments go to its counter while it is resident; a non-
    resident id whose sketch estimate exceeds the minimum resident count
    evicts it (the classic SpaceSaving overestimate guarantee, with the
    sketch playing the count-of-evicted role).  Evicted counts are
    pushed back into the sketch (``raise_to``) so the min-row invariant
    `estimate >= true count` survives residency round-trips.
  * a recent-id RING — the last ``ring`` observed ids verbatim.  The
    sketch cannot enumerate the ids it has seen, so the ring supplies
    the tail candidates for the k-means point set and the tail-support
    estimate for the entropy signal.  It is also what makes the
    statistics *windowed*: ring contents always reflect the recent
    stream regardless of decay.

Decay: ``decay(gamma)`` scales sketch counters, resident counts and the
total mass — applied once per window by the tracker, giving the
exponential forgetting the trigger policy needs to see distribution
shift instead of an ever-growing prefix sum.
"""
from __future__ import annotations

import numpy as np

_MASS_DTYPE = np.float64  # exact for integer counts < 2**53 (bit-for-bit
#                           dense-checkpoint migration relies on this)


def _hash_coeffs(rng: np.random.Generator, depth: int):
    """Per-row multiply-shift coefficients: odd multiplier + offset."""
    a = rng.integers(0, 2**32, depth, dtype=np.uint32) | np.uint32(1)
    b = rng.integers(0, 2**32, depth, dtype=np.uint32)
    return a, b


class CountMinSketch:
    """Conservative-update count-min sketch over non-negative float mass."""

    def __init__(self, width: int, depth: int = 4, seed: int = 0):
        if width & (width - 1) or width <= 0:
            raise ValueError(f"width must be a power of two, got {width}")
        self.width = width
        self.depth = depth
        self.shift = np.uint32(32 - int(width).bit_length() + 1)
        self.a, self.b = _hash_coeffs(np.random.default_rng(seed), depth)
        self.counters = np.zeros((depth, width), _MASS_DTYPE)
        # mass absorbed by THIS sketch (diagnostics; rides the state so
        # it resumes).  NOT the stream mass — FeatureSketch.mass is that:
        # on the sync path resident head ids bypass the sketch entirely,
        # on the async fold the whole batch lands here.
        self.total = 0.0
        self._rows = np.arange(depth)[:, None]

    def cells(self, ids: np.ndarray) -> np.ndarray:
        """(depth, n) uint32 cell index per hash row — multiply-shift on
        uint32 (wraps mod 2^32), top bits select the cell."""
        x = np.asarray(ids).astype(np.uint32)[None, :]
        return (self.a[:, None] * x + self.b[:, None]) >> self.shift

    def add(self, ids: np.ndarray, counts: np.ndarray) -> None:
        """Conservative update for a batch of UNIQUE ids: raise each id's
        cells to (min-estimate + its count).  Per-id the invariant
        `every cell >= the id's true mass` is preserved even batched —
        colliding ids max into the cell, and max of overestimates is an
        overestimate."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return
        counts = np.asarray(counts, _MASS_DTYPE)
        cells = self.cells(ids)
        new = self.counters[self._rows, cells].min(axis=0) + counts
        for r in range(self.depth):
            np.maximum.at(self.counters[r], cells[r], new)
        self.total += float(counts.sum())

    def add_cells(self, delta: np.ndarray) -> None:
        """Fold a device-computed (depth, width) increment (plain CMS add;
        each row received the full batch, so total rises by one row's
        mass)."""
        self.counters += delta
        self.total += float(np.asarray(delta)[0].sum())

    def raise_to(self, ids: np.ndarray, counts: np.ndarray) -> None:
        """Raise each id's cells to at least ``counts`` — re-absorbs a
        SpaceSaving eviction without double-adding mass."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return
        cells = self.cells(ids)
        for r in range(self.depth):
            np.maximum.at(self.counters[r], cells[r], np.asarray(counts, _MASS_DTYPE))

    def estimate(self, ids: np.ndarray) -> np.ndarray:
        """Min-row estimate: an upper bound on each id's true mass."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return np.zeros(0, _MASS_DTYPE)
        return self.counters[self._rows, self.cells(ids)].min(axis=0)

    def estimate_unbiased(self, ids: np.ndarray) -> np.ndarray:
        """Count-mean(-min) estimate: subtract each row's expected
        collision noise ``(row_mass - cell) / (width - 1)`` (the row's
        ACTUAL counter mass, not the stream total — under conservative
        update rows hold less than the total and a total-based correction
        over-subtracts), average the corrected rows, clip into
        [0, min-estimate].  Not exactly unbiased — the clip and the
        shared-cell correlations leave a small centered-ish residual —
        but on tail ids its error is a fraction of the min-estimate's
        upward collision bias, which is what matters when the estimates
        become k-means tail WEIGHTS: collisions must not masquerade as
        frequency."""
        ids = np.asarray(ids)
        if ids.size == 0:
            return np.zeros(0, _MASS_DTYPE)
        raw = self.counters[self._rows, self.cells(ids)]
        row_mass = self.counters.sum(axis=1, keepdims=True)
        noise = (row_mass - raw) / max(self.width - 1, 1)
        est = (raw - noise).mean(axis=0)
        return np.clip(est, 0.0, raw.min(axis=0))

    def decay(self, gamma: float) -> None:
        self.counters *= gamma
        self.total *= gamma

    @property
    def nbytes(self) -> int:
        return self.counters.nbytes + self.a.nbytes + self.b.nbytes

    def state_tree(self) -> list[np.ndarray]:
        return [self.counters.copy(), np.float64(self.total)]

    def load_state_tree(self, tree) -> None:
        counters, total = tree
        self.counters = np.asarray(counters, _MASS_DTYPE).reshape(
            self.depth, self.width
        ).copy()
        self.total = float(total)


class SpaceSaving:
    """Fixed-capacity exact head counters (SpaceSaving with the sketch as
    the evicted-mass oracle).  Resident ids live in parallel arrays —
    slots [0, n) filled contiguously — so decay/state are vectorized and
    checkpoint leaves are fixed-shape.  Residency lookup is a lazily
    rebuilt sorted index (searchsorted per batch, O(u·log H)): admissions
    become rare once the head stabilizes, so the rebuild amortizes away
    and the hot path stays free of per-id python work."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.ids = np.full(capacity, -1, np.int64)
        self.counts = np.zeros(capacity, _MASS_DTYPE)
        self.n = 0
        self._dirty = True
        self._sorted_ids: np.ndarray | None = None
        self._sorted_slots: np.ndarray | None = None

    def _index(self):
        if self._dirty:
            order = np.argsort(self.ids[: self.n], kind="stable")
            self._sorted_ids = self.ids[: self.n][order]
            self._sorted_slots = order
            self._dirty = False

    def split_resident(self, ids: np.ndarray):
        """-> (slot index per id, resident mask) for a batch of ids."""
        ids = np.asarray(ids, np.int64)
        if self.n == 0:
            return np.full(ids.shape, -1, np.int64), np.zeros(ids.shape, bool)
        self._index()
        pos = np.clip(np.searchsorted(self._sorted_ids, ids), 0, self.n - 1)
        hit = self._sorted_ids[pos] == ids
        return np.where(hit, self._sorted_slots[pos], -1), hit

    def bump(self, slots: np.ndarray, counts: np.ndarray) -> None:
        """Add exact counts to resident slots (slots unique per batch —
        callers pass unique ids)."""
        self.counts[slots] += np.asarray(counts, _MASS_DTYPE)

    def offer(self, ids: np.ndarray, ests: np.ndarray, sketch: CountMinSketch):
        """SpaceSaving admission for NON-resident ids with sketch-estimate
        ``ests``: fill free slots first, then evict the minimum-count
        resident when the candidate's estimate exceeds it (pushing the
        evictee's count back into the sketch).  Candidates descend by
        estimate, so the first non-admitting one ends the batch."""
        order = np.argsort(np.asarray(ests), kind="stable")[::-1]
        evicted_ids: list[int] = []
        evicted_cnt: list[float] = []
        for j in order.tolist():
            i, est = int(ids[j]), float(ests[j])
            if self.n < self.capacity:
                self.ids[self.n], self.counts[self.n] = i, est
                self.n += 1
                self._dirty = True
                continue
            s = int(np.argmin(self.counts))
            if est <= self.counts[s]:
                break  # candidates are descending: nothing else admits
            evicted_ids.append(int(self.ids[s]))
            evicted_cnt.append(float(self.counts[s]))
            self.ids[s], self.counts[s] = i, est
            self._dirty = True
        if evicted_ids:  # one vectorized sketch push for the whole batch
            sketch.raise_to(np.asarray(evicted_ids), np.asarray(evicted_cnt))

    def head(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, counts) of resident entries, descending by count."""
        n = self.n
        order = np.argsort(self.counts[:n], kind="stable")[::-1]
        return self.ids[:n][order].copy(), self.counts[:n][order].copy()

    def decay(self, gamma: float) -> None:
        self.counts[: self.n] *= gamma

    @property
    def nbytes(self) -> int:
        return self.ids.nbytes + self.counts.nbytes

    def state_tree(self) -> list[np.ndarray]:
        return [self.ids.copy(), self.counts.copy()]

    def load_state_tree(self, tree) -> None:
        ids, counts = tree
        self.ids = np.asarray(ids, np.int64).reshape(self.capacity).copy()
        self.counts = np.asarray(counts, _MASS_DTYPE).reshape(self.capacity).copy()
        self.n = int((self.ids >= 0).sum())
        self._dirty = True


class FeatureSketch:
    """One feature's complete streaming state: sketch + head + ring + mass.

    This object IS the transition's count provider — it exposes
    ``points(n, seed)`` (the k-means point set) and ``id_weights(d1)``
    (dense per-id weights for the moment remap, a TRANSITION-TIME
    transient of the same order as the pointer table, never tracker
    state), so ``id_counts[i]`` entries duck-type against dense arrays
    in ``train/transition.py``.
    """

    def __init__(self, width: int, depth: int, heavy: int, ring: int,
                 seed: int = 0):
        self.cms = CountMinSketch(width, depth, seed=seed)
        self.hh = SpaceSaving(heavy)
        self.ring = np.full(ring, -1, np.int64)
        self.ring_pos = 0
        self.mass = 0.0  # total (decayed) observed mass, heavy + tail

    # --- updates ---------------------------------------------------------

    def _push_ring(self, raw_ids: np.ndarray) -> None:
        r = self.ring.shape[0]
        ids = np.asarray(raw_ids, np.int64).reshape(-1)[-r:]
        pos = self.ring_pos % r
        k = min(ids.size, r - pos)
        self.ring[pos : pos + k] = ids[:k]
        if k < ids.size:
            self.ring[: ids.size - k] = ids[k:]
        self.ring_pos = (pos + ids.size) % r

    def observe(self, raw_ids: np.ndarray) -> None:
        """Host (synchronous, conservative) update with one batch of raw
        (with-multiplicity) ids."""
        self._ingest(raw_ids, into_sketch=True)

    def fold_cells(self, delta: np.ndarray, raw_ids: np.ndarray) -> None:
        """Async path: fold a device-computed (depth, width) cell delta
        (the sketch update never touched the host hot path) and run the
        id-level head/ring bookkeeping from the host batch copy.  Resident
        ids' mass lands in the sketch too (their cells go stale-HIGH,
        which the min/offer invariants tolerate); their exact counters
        still get the increments."""
        self.cms.add_cells(delta)
        self._ingest(raw_ids, into_sketch=False)

    def _ingest(self, raw_ids: np.ndarray, *, into_sketch: bool) -> None:
        """The id-level bookkeeping BOTH update paths share (so they
        cannot drift apart — restart-exactness depends on sync and async
        computing identical head/ring/mass state): resident head ids take
        exact increments, absent ids go through SpaceSaving admission,
        the ring and mass advance.  ``into_sketch`` adds the absent mass
        to the CMS too (the async path already folded it as cells)."""
        raw_ids = np.asarray(raw_ids).reshape(-1)
        if raw_ids.size == 0:
            return
        uids, ucnt = np.unique(raw_ids, return_counts=True)
        ucnt = ucnt.astype(_MASS_DTYPE)
        slots, resident = self.hh.split_resident(uids)
        self.hh.bump(slots[resident], ucnt[resident])
        absent_ids, absent_cnt = uids[~resident], ucnt[~resident]
        if into_sketch:
            self.cms.add(absent_ids, absent_cnt)
        self.hh.offer(absent_ids, self.cms.estimate(absent_ids), self.cms)
        self.mass += float(ucnt.sum())
        self._push_ring(raw_ids)

    def decay(self, gamma: float) -> None:
        self.cms.decay(gamma)
        self.hh.decay(gamma)
        self.mass *= gamma

    # --- queries ----------------------------------------------------------

    def estimate(self, ids: np.ndarray) -> np.ndarray:
        """Best per-id estimate: exact for resident head ids, min-row
        sketch upper bound for the rest."""
        ids = np.asarray(ids)
        slots, resident = self.hh.split_resident(ids)
        out = self.cms.estimate(ids)
        out[resident] = self.hh.counts[slots[resident]]
        return out

    def tail_candidates(self) -> np.ndarray:
        """Distinct recently-seen ids that are NOT resident in the head —
        the only enumerable view of the tail a sketch-based tracker has."""
        seen = np.unique(self.ring)
        seen = seen[seen >= 0]
        if seen.size == 0:
            return seen
        _, resident = self.hh.split_resident(seen)
        return seen[~resident]

    def points(self, n: int, seed: int) -> tuple[np.ndarray, np.ndarray] | None:
        """K-means point set: exact head counts + unbiased tail estimates
        over ring candidates, capped at ``n`` by the same stratified-HT
        subsampling the dense tracker uses.  None before any mass."""
        from repro.stream.points import stratified_points

        if self.mass <= 0.0:
            return None
        head_ids, head_cnt = self.hh.head()
        tail_ids = self.tail_candidates()
        # ring membership PROVES one recent occurrence — floor the
        # collision-corrected estimate there so a zeroed-out tail id
        # still enters the point set with its minimum honest weight
        tail_w = np.maximum(self.cms.estimate_unbiased(tail_ids), 1.0)
        ids = np.concatenate([head_ids, tail_ids])
        w = np.concatenate([head_cnt, tail_w])
        if ids.size == 0:
            return None
        return stratified_points(ids, w, n, seed)

    def id_weights(self, d1: int, chunk: int = 1 << 20) -> np.ndarray:
        """Dense (d1,) float32 weight estimate for the moment remap:
        unbiased sketch estimates streamed in chunks, exact head counts
        spliced over the top.  O(d1) TRANSIENT work at transition time
        (the transition's assign_all pass is already O(d1)); tracker
        state stays O(sketch)."""
        w = np.empty(d1, np.float32)
        for lo in range(0, d1, chunk):
            hi = min(lo + chunk, d1)
            w[lo:hi] = self.cms.estimate_unbiased(np.arange(lo, hi))
        head_ids, head_cnt = self.hh.head()
        ok = head_ids < d1
        w[head_ids[ok]] = head_cnt[ok]
        return w

    def summary(self) -> dict | None:
        """Window statistics for the trigger policy: observed-entropy
        estimate (exact head distribution + tail mass spread uniformly
        over the ring's distinct tail support) and the head snapshot the
        drift signal compares across windows.  None before any mass."""
        if self.mass <= 0.0:
            return None
        head_ids, head_cnt = self.hh.head()
        p = head_cnt[head_cnt > 0] / self.mass
        ent = float(-(p * np.log(p)).sum()) if p.size else 0.0
        tail_mass = max(self.mass - float(head_cnt.sum()), 0.0)
        support = int(self.tail_candidates().size)
        if tail_mass > 0.0 and support > 0:
            q = tail_mass / self.mass
            ent += float(-q * np.log(q / support))
        return {
            "entropy": ent,
            "mass": self.mass,
            "head_ids": head_ids,
            "head_probs": head_cnt / self.mass,
        }

    # --- memory / checkpoint ----------------------------------------------

    @property
    def nbytes(self) -> int:
        return self.cms.nbytes + self.hh.nbytes + self.ring.nbytes

    def state_tree(self) -> list[np.ndarray]:
        return (
            self.cms.state_tree()
            + self.hh.state_tree()
            + [self.ring.copy(), np.int64(self.ring_pos), np.float64(self.mass)]
        )

    def load_state_tree(self, tree) -> None:
        tree = list(tree)
        self.cms.load_state_tree(tree[0:2])
        self.hh.load_state_tree(tree[2:4])
        self.ring = np.asarray(tree[4], np.int64).reshape(self.ring.shape).copy()
        self.ring_pos = int(tree[5])
        self.mass = float(tree[6])

    def ingest_dense(self, counts: np.ndarray) -> None:
        """Absorb a dense histogram (legacy-checkpoint migration): the
        top-``heavy`` ids become resident with their EXACT counts
        (bit-for-bit — float64 is exact for int64 counts < 2^53), the
        rest conservative-update into the sketch, and the highest-count
        tail ids seed the ring so tail candidates survive the migration."""
        counts = np.asarray(counts)
        nz = np.flatnonzero(counts > 0)
        if nz.size == 0:
            return
        order = nz[np.argsort(counts[nz], kind="stable")[::-1]]
        head = order[: self.hh.capacity]
        self.hh.ids[: head.size] = head
        self.hh.counts[: head.size] = counts[head].astype(_MASS_DTYPE)
        self.hh.n = int(head.size)
        self.hh._dirty = True
        tail = order[self.hh.capacity :]
        self.cms.add(tail, counts[tail].astype(_MASS_DTYPE))
        self.mass = float(counts[nz].astype(_MASS_DTYPE).sum())
        if tail.size:
            self._push_ring(tail[: self.ring.shape[0]])
