"""K-means point sets from frequency statistics (dense or sketched).

The transition's k-means wants (unique ids, frequency weights) — the
paper's epoch-boundary sample in its zero-variance weighted form.  Two
sources produce it:

  * ``points_from_counts`` — a DENSE histogram (the reference
    ``IdFrequencyTracker``); kept exactly as PR 3 shipped it, but now
    float-clean: decayed histograms are float arrays whose total can be
    < 1, and the old ``int(counts.sum())`` truncation silently turned a
    small-but-nonzero histogram into "nothing observed".
  * ``FeatureSketch.points`` (stream/sketch.py) — the sketch-backed
    tracker: exact counts for the heavy-hitter head, unbiased sketch
    estimates for ring-sampled tail candidates.

Both funnel through ``stratified_points``: when the candidate set
exceeds the FAISS-style cap, the n/2 highest-count ids enter
deterministically with their exact counts (inclusion probability 1) and
the tail is sampled uniformly without replacement with counts inflated
by the inverse sampling fraction (Horvitz-Thompson).  Sampling the tail
∝ counts and ALSO weighting by counts would double-count frequency
(head mass ~count²); uniform-only sampling risks dropping the head
entirely.  The estimator is unbiased for the weighted k-means objective
— E[total weight] equals the total observed (possibly decayed, float)
mass — at low variance where the mass actually is.
"""
from __future__ import annotations

import numpy as np


def sample_from_counts(counts: np.ndarray, n: int, seed: int) -> np.ndarray | None:
    """Draw ``n`` ids ~ ``counts`` (with replacement — duplicates ARE the
    frequency weighting, exactly what an epoch-boundary sample would
    contain).  None when nothing has been counted yet (callers fall back
    to uniform).  Kept for diagnostics/ablation; the transition uses
    ``points_from_counts`` (the zero-variance weighted form).  Counts may
    be float (decayed histograms): any strictly positive total counts."""
    counts = np.asarray(counts)
    total = float(counts.sum())
    if total <= 0.0:
        return None
    rng = np.random.default_rng(seed)
    return rng.choice(counts.shape[0], size=n, replace=True, p=counts / total)


def stratified_points(
    ids: np.ndarray, counts: np.ndarray, n: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Cap a sparse (ids, counts) candidate set at ``n`` points, unbiased.

    ``ids``/``counts`` are parallel arrays of observed ids with strictly
    positive (float) counts.  At or under the cap: every candidate with
    its exact count.  Over the cap: deterministic top-``n//2`` head plus
    a uniform without-replacement tail draw, Horvitz-Thompson-inflated by
    ``|rest| / n_tail`` so the tail's expected weight mass is preserved.
    Returns (ids, weights-float32) sorted by id."""
    ids = np.asarray(ids)
    counts = np.asarray(counts)
    if ids.size <= n:
        order = np.argsort(ids, kind="stable")
        return ids[order], counts[order].astype(np.float32)
    n_head = n // 2
    order = np.argsort(counts, kind="stable")[::-1]
    head = ids[order[:n_head]]
    head_w = counts[order[:n_head]]
    rest = ids[order[n_head:]]
    rest_w = counts[order[n_head:]]
    rng = np.random.default_rng(seed)
    n_tail = n - n_head
    pick = rng.choice(rest.size, size=n_tail, replace=False)
    w = np.concatenate(
        [head_w, rest_w[pick] * (rest.size / n_tail)]
    ).astype(np.float32)
    out = np.concatenate([head, rest[pick]])
    order = np.argsort(out, kind="stable")
    return out[order], w[order]


def points_from_counts(
    counts: np.ndarray, n: int, seed: int
) -> tuple[np.ndarray, np.ndarray] | None:
    """(ids, weights) for COUNT-WEIGHTED k-means from a DENSE histogram:
    every observed id exactly once, weighted by its observed frequency.
    None when nothing has been counted yet (uniform fallback).  Counts
    may be float — exponential decay scales every weight by the same
    factor, which leaves the weighted k-means objective (and the HT
    subsampling) invariant."""
    counts = np.asarray(counts)
    nz = np.flatnonzero(counts > 0)
    if nz.size == 0:
        return None
    return stratified_points(nz, counts[nz], n, seed)
