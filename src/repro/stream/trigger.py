"""Entropy/drift-triggered clustering — the adaptive transition schedule.

A fixed ``cluster_every`` re-clusters on a wall-clock-ish cadence that
has nothing to do with what the stream is doing: it fires when nothing
changed (wasted full-vocab passes, churned optimizer moments) and sleeps
through a distribution shift (the k-means sample goes stale exactly when
re-clustering would pay).  ``ClusterTrigger`` replaces the fixed cadence
with two signals computed from the sketch tracker's window statistics:

  * **entropy collapse** — the observed-entropy estimate dropping by
    ``entropy_drop`` (relative) below the highest entropy seen since the
    last firing.  Concentration rising means the head ids now carry more
    of the mass than the centroids were fit for.  The reference ratchets
    UP with the stream and resets to the current entropy on firing, so a
    collapse fires exactly ONCE — staying low never re-fires; only a
    fresh collapse from a recovered reference does.
  * **drift** — mean total-variation distance between consecutive
    windows' head distributions.  A shifted head with unchanged entropy
    (new ids replacing old at similar frequencies) is invisible to the
    entropy signal but exactly the case where the old centroids and the
    old k-means sample are both wrong.

All trigger state is fixed-shape (scalars + padded head snapshots) so it
rides checkpoints and resume replays the schedule exactly — the
transition schedule is training state, not host-process state, same as
``clusters_done``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TriggerEvent:
    """One trigger evaluation (one closed window)."""

    step: int
    entropy: float
    drift: float
    fire: bool
    reason: str = ""  # "entropy-collapse" | "drift" | ""

    def as_dict(self) -> dict:
        """Plain-python fields for the run log's ``trigger`` event
        (numpy scalars coerced so the record is json-clean)."""
        return {
            "step": int(self.step),
            "entropy": float(self.entropy),
            "drift": float(self.drift),
            "fire": bool(self.fire),
            "reason": self.reason,
        }


def _tv_distance(prev_ids, prev_p, ids, p) -> float:
    """Total-variation distance between two truncated head distributions
    (mass outside both heads is not comparable and is ignored)."""
    union = np.union1d(prev_ids[prev_ids >= 0], ids[ids >= 0])
    if union.size == 0:
        return 0.0

    def dense(u_ids, u_p):
        out = np.zeros(union.size)
        keep = u_ids >= 0
        out[np.searchsorted(union, u_ids[keep])] = u_p[keep]
        return out

    return 0.5 * float(np.abs(dense(prev_ids, prev_p) - dense(ids, p)).sum())


def head_churn(prev_ids, ids) -> float:
    """Jaccard distance between two head id SETS (order/count agnostic,
    negatives = empty slots ignored).  0.0 = identical membership,
    1.0 = disjoint.  The serve cache refresh policy (serve/dlrm.py)
    compares its cached head against a fresh tracker export with this —
    membership is what decides cache coverage, so it is the right churn
    signal there (the trigger's ``_tv_distance`` weighs probability
    mass instead)."""
    prev_ids = np.unique(np.asarray(prev_ids)[np.asarray(prev_ids) >= 0])
    ids = np.unique(np.asarray(ids)[np.asarray(ids) >= 0])
    union = np.union1d(prev_ids, ids)
    if union.size == 0:
        return 0.0
    inter = np.intersect1d(prev_ids, ids)
    return 1.0 - inter.size / union.size


class ClusterTrigger:
    """Stateful trigger policy over the tracker's window summaries.

    ``update(stats, step)`` consumes one closed-window summary (the dict
    ``SketchFrequencyTracker.poll_window`` returns) and decides whether
    the transition fires this window.  ``warmup`` windows establish the
    entropy reference before anything may fire; ``min_windows_between``
    spaces firings.  An empty window (no mass → stats None) is a no-op:
    callers simply don't call update, or pass None and get a non-firing
    event.
    """

    def __init__(
        self,
        *,
        entropy_drop: float = 0.15,
        drift_threshold: float = 0.35,
        warmup: int = 2,
        min_windows_between: int = 1,
        head_cap: int = 256,
    ):
        self.entropy_drop = entropy_drop
        self.drift_threshold = drift_threshold
        self.warmup = warmup
        self.min_windows_between = min_windows_between
        self.head_cap = head_cap
        self.windows = 0
        self.windows_since_fire = np.inf
        self.fired = 0
        self.peak_entropy = 0.0
        # previous-window head snapshot, fixed (n_heads, cap) for checkpoints
        self._prev_ids: np.ndarray | None = None
        self._prev_p: np.ndarray | None = None
        self.events: list[TriggerEvent] = []  # observability, not state

    # --- the decision -----------------------------------------------------

    def _pad_heads(self, heads):
        n = len(heads)
        ids = np.full((n, self.head_cap), -1, np.int64)
        p = np.zeros((n, self.head_cap))
        for j, h in enumerate(heads):
            if h is None:
                continue
            hi, hp = h
            k = min(len(hi), self.head_cap)
            ids[j, :k] = hi[:k]
            p[j, :k] = hp[:k]
        return ids, p

    def update(self, stats: dict | None, step: int,
               *, can_fire: bool = True) -> TriggerEvent:
        """``can_fire=False`` evaluates the window (reference/drift
        baselines advance as usual) but suppresses firing — the caller's
        transition is unavailable (no cluster_fn, or cluster_max
        exhausted), and committing fire-state for a transition that never
        runs would reset the entropy reference against nothing."""
        if stats is None:  # empty window: nothing observed, nothing to do
            ev = TriggerEvent(step, float("nan"), 0.0, False, "")
            self.events.append(ev)
            return ev
        self.windows += 1
        self.windows_since_fire += 1
        ent = float(stats["entropy"])
        ids, p = self._pad_heads(stats["heads"])
        drift = 0.0
        if (
            self._prev_ids is not None
            and self._prev_ids.shape[0] != ids.shape[0]
        ):
            # tracked-feature count changed under us (config change across
            # a restore — the wildcard restore template deliberately
            # accepts any stored row count): feature-wise TV would pair
            # mismatched features, so treat this window as having no
            # baseline
            self._prev_ids = self._prev_p = None
        if self._prev_ids is not None:
            per = [
                _tv_distance(self._prev_ids[j], self._prev_p[j], ids[j], p[j])
                for j in range(ids.shape[0])
            ]
            drift = float(np.mean(per)) if per else 0.0
        self._prev_ids, self._prev_p = ids, p

        fire, reason = False, ""
        armed = (
            can_fire
            and self.windows > self.warmup
            and self.windows_since_fire >= self.min_windows_between
        )
        # strict: a stream that STARTS concentrated (single-id: entropy 0
        # from the first window) never "collapses" — the reference must
        # have been meaningfully higher first
        if armed and self.peak_entropy > 0.0 and ent < self.peak_entropy * (
            1.0 - self.entropy_drop
        ):
            fire, reason = True, "entropy-collapse"
        elif armed and drift >= self.drift_threshold:
            fire, reason = True, "drift"
        if fire:
            self.fired += 1
            self.windows_since_fire = 0
            self.peak_entropy = ent  # re-arm only on a FRESH collapse
        else:
            self.peak_entropy = max(self.peak_entropy, ent)
        ev = TriggerEvent(step, ent, drift, fire, reason)
        self.events.append(ev)
        return ev

    # --- checkpoint integration -------------------------------------------

    def state_template(self) -> list[np.ndarray]:
        """Restore-template form of the state, FRESH-valued: the
        previous-head snapshot leaves are (0, head_cap) — zero-size
        WILDCARDS to the checkpoint layout matcher — because their stored
        row count depends on how many windows had closed when the writer
        saved (a template built from the live ``state_tree`` would
        hard-require the live shape and reject a pre-first-window
        checkpoint).  The scalars are a fresh trigger's, not the live
        one's: when a sectioned checkpoint has NO trigger section, the
        template value IS what gets restored, and a deterministic fresh
        start beats a stale live-state mix."""
        return [
            np.int64(0),
            np.float64(-1.0),  # windows_since_fire: inf sentinel
            np.int64(0),
            np.float64(0.0),
            np.full((0, self.head_cap), -1, np.int64),
            np.zeros((0, self.head_cap)),
        ]

    def state_tree(self) -> list[np.ndarray]:
        if self._prev_ids is None:
            prev_ids = np.full((0, self.head_cap), -1, np.int64)
            prev_p = np.zeros((0, self.head_cap))
        else:
            prev_ids, prev_p = self._prev_ids, self._prev_p
        return [
            np.int64(self.windows),
            np.float64(
                -1.0 if np.isinf(self.windows_since_fire)
                else self.windows_since_fire
            ),
            np.int64(self.fired),
            np.float64(self.peak_entropy),
            prev_ids.copy(),
            prev_p.copy(),
        ]

    def load_state_tree(self, tree) -> None:
        tree = list(tree)
        self.windows = int(tree[0])
        wsf = float(tree[1])
        self.windows_since_fire = np.inf if wsf < 0 else wsf
        self.fired = int(tree[2])
        self.peak_entropy = float(tree[3])
        prev_ids = np.asarray(tree[4], np.int64)
        if prev_ids.shape[0] == 0:
            self._prev_ids = self._prev_p = None
        else:
            self._prev_ids = prev_ids.copy()
            self._prev_p = np.asarray(tree[5], np.float64).copy()
