"""Device-side batch sketch updates + asynchronous host fold.

The host conservative-update path costs O(batch · depth) numpy work per
step IN the training loop; at pod batch sizes that serializes against
the jitted step.  This module removes tracking from the critical path:

  * ``cell_count_fn`` builds ONE pure function for all tracked features:
    hash every id of the (B, F_tracked) sparse block with each feature's
    multiply-shift coefficients (the SAME coefficients the host sketch
    uses, so device cells == host cells) and segment-sum the hits into an
    (F_tracked, depth, width) increment tensor.  ``make_step_cell_counter``
    EMBEDS it into the jitted train step (``make_train_step(sketch_fn=)``)
    so the delta rides the step's single launch — tracking adds zero
    extra device dispatches; ``make_cell_counter`` is the standalone
    jitted dispatcher (one extra async dispatch per batch) for trackers
    running outside a train step.
  * ``AsyncFolder`` drains (device_delta, host_ids) pairs on a single
    background thread: the ``device_get`` of the delta and the
    O(unique-ids) head/ring bookkeeping block the FOLD thread, never the
    step.  ``flush()`` is the barrier the tracker takes before sampling,
    statistics, or checkpointing — fold order is FIFO, so flushed state
    is a pure function of the observed batch sequence and restart-exact
    resume holds with the async path enabled.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def cell_count_fn(sketches):
    """PURE (B, F) int32 -> (F, depth, width) int32 cell-increment counter
    over ``sketches`` (the tracked features' ``CountMinSketch`` objects,
    which must share width/depth — one ``StreamConfig`` builds them, so
    they do).  Not jitted: the caller either wraps it (the standalone
    dispatcher below) or INLINES it into an already-jitted program — the
    train step embeds it via ``make_step_cell_counter`` so sketch tracking
    adds ZERO extra device dispatches (DESIGN.md §6)."""
    widths = {s.width for s in sketches}
    depths = {s.depth for s in sketches}
    if len(widths) != 1 or len(depths) != 1:
        raise ValueError("tracked sketches must share width/depth")
    (width,), (depth,) = widths, depths
    n_feat = len(sketches)
    a = jnp.asarray(np.stack([s.a for s in sketches]))  # (F, depth) uint32
    b = jnp.asarray(np.stack([s.b for s in sketches]))
    shift = int(sketches[0].shift)

    def count_cells(sparse):  # (B, F) int32
        x = sparse.T.astype(jnp.uint32)  # (F, B)
        cells = (a[:, :, None] * x[:, None, :] + b[:, :, None]) >> shift
        # one flat scatter-add across every (feature, row) plane
        base = jnp.arange(n_feat * depth, dtype=jnp.uint32)[:, None] * width
        flat = (cells.reshape(n_feat * depth, -1) + base).reshape(-1)
        delta = jnp.zeros(n_feat * depth * width, jnp.int32).at[
            flat.astype(jnp.int32)
        ].add(1)
        return delta.reshape(n_feat, depth, width)

    return count_cells


def make_cell_counter(sketches):
    """Standalone jitted dispatcher around ``cell_count_fn`` — the
    tracker's own fallback path when the train step does not embed the
    counter (one extra dispatch per batch)."""
    return jax.jit(cell_count_fn(sketches))


def make_step_cell_counter(tracker):
    """The ``sketch_fn`` a ``SketchFrequencyTracker`` contributes to
    ``train.loop.make_train_step``: microbatch dict -> (F_tracked, depth,
    width) int32 cell delta, computed INSIDE the jitted step (selecting
    the tracked sparse columns with the same hash coefficients the host
    sketch uses, so in-step cells == host cells bit for bit).  Returns
    None when the tracker has no sketch-backed features (dense tracker,
    or nothing tracked) — the step then carries no delta."""
    tracked = getattr(tracker, "tracked", None)
    if not tracked:
        return None
    fn = cell_count_fn([tracker.features[f].cms for f in tracked])
    cols = np.asarray(tracked)
    key = tracker.key

    def count(microbatch):
        sparse = jnp.take(microbatch[key], jnp.asarray(cols), axis=1)
        return fn(sparse.astype(jnp.int32))

    return count


class AsyncFolder:
    """FIFO background folder with error propagation on the barrier."""

    def __init__(self, fold_fn, maxsize: int = 64):
        self._fold = fold_fn
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if self._error is None:  # after an error, drain without work
                    self._fold(item)
            except BaseException as e:  # surfaced on the next flush()
                self._error = e
            finally:
                self._q.task_done()

    def submit(self, item) -> None:
        if self._error is not None:
            self.flush()  # raises
        self._q.put(item)  # bounded: backpressure instead of unbounded lag

    def flush(self) -> None:
        self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err
