"""`SketchFrequencyTracker` — the drop-in, vocab-independent replacement
for the dense ``IdFrequencyTracker``.

Same Trainer surface (``observe`` / ``state_tree`` / ``load_state_tree``
/ a ``counts`` view the cluster callbacks index per feature), but each
tracked feature's state is a ``FeatureSketch`` (count-min + SpaceSaving
head + recent-id ring — O(width·depth + heavy + ring) memory regardless
of vocabulary), the ``counts`` entries are the sketches themselves
(``train/transition.py`` duck-types providers against dense arrays), and
three streaming behaviours the dense tracker never had:

  * windowing/decay — every ``window`` observed batches the tracker
    multiplies all counters by ``decay`` and snapshots window statistics
    (entropy estimate + head distributions), so the histogram tracks the
    RECENT stream and the trigger policy can see shift;
  * async device-side updates — with ``async_fold`` the per-batch sketch
    increment is a jitted segment-sum on device (stream/device.py) folded
    into the host sketch on a background thread: the train step never
    waits on tracking;
  * tracked-feature selection — only features that actually transition
    (the collection's CCE groups) carry sketches; the rest report None
    and the transition's uniform fallback applies (they never cluster
    anyway).

The dense reference implementation lives here too (moved from
``train/freq.py``, which is now a compat shim) so every frequency-
statistics implementation sits behind one module boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.stream.points import sample_from_counts
from repro.stream.sketch import FeatureSketch


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Sketch-tracker shape + streaming semantics (per feature)."""

    width: int = 1 << 12   # CMS cells per hash row (power of two)
    depth: int = 4         # hash rows
    heavy: int = 256       # SpaceSaving head capacity
    ring: int = 4096       # recent-id ring (tail candidates / tail support)
    decay: float = 1.0     # per-window counter multiplier (1 = never forget)
    window: int = 0        # batches per window; 0 = no windowing
    async_fold: bool = False  # device segment-sum + background host fold
    seed: int = 0


class IdFrequencyTracker:
    """Per-feature DENSE id histograms from the training stream — the
    exact reference the sketch tracker approximates (one int64 per vocab
    row; fine for small vocabs and for tests, defeats CCE's memory point
    at production vocab sizes)."""

    def __init__(self, vocab_sizes: Sequence[int], key: str = "sparse"):
        self.key = key
        self.counts = [np.zeros(v, np.int64) for v in vocab_sizes]

    def observe(self, batch: dict) -> None:
        """Accumulate one (un-reshaped) batch: ``batch[self.key]`` is
        (B, n_features) int.  Runs on the training hot path, so the
        update is O(batch) — never O(vocab) (a full-vocab bincount per
        step would dwarf the step itself on 100M-row tables)."""
        sparse = np.asarray(batch[self.key]).reshape(-1, len(self.counts))
        for f, c in enumerate(self.counts):
            np.add.at(c, sparse[:, f], 1)

    def sample_ids(self, seed: int, feature: int, n: int) -> np.ndarray | None:
        """Draw ``n`` ids ~ the observed frequency of ``feature``."""
        return sample_from_counts(self.counts[feature], n, seed)

    # --- checkpoint integration (host state must resume too) ---------------

    def state_tree(self) -> list[np.ndarray]:
        return [c.copy() for c in self.counts]

    def state_template(self) -> list[np.ndarray]:
        """Restore-template form: FRESH (zero) histograms.  When a
        sectioned checkpoint has no ``id_counts`` section, the template
        value IS what gets restored — a deterministic empty tracker, not
        whatever the live tracker happened to hold at restore time."""
        return [np.zeros_like(c) for c in self.counts]

    def load_state_tree(self, tree: Sequence[np.ndarray]) -> None:
        self.counts = [np.asarray(c).astype(np.int64).copy() for c in tree]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.counts)


class SketchFrequencyTracker:
    """Sketch-backed per-feature frequency tracking with decay/windowing."""

    def __init__(
        self,
        vocab_sizes: Sequence[int],
        config: StreamConfig = StreamConfig(),
        *,
        tracked: Sequence[int] | None = None,
        key: str = "sparse",
    ):
        self.key = key
        self.config = config
        self.vocab_sizes = tuple(int(v) for v in vocab_sizes)
        n = len(self.vocab_sizes)
        self.tracked = tuple(sorted(tracked)) if tracked is not None else tuple(range(n))
        self.features: list[FeatureSketch | None] = [None] * n
        for f in self.tracked:
            self.features[f] = FeatureSketch(
                config.width, config.depth, config.heavy, config.ring,
                seed=config.seed * 1_000_003 + f,
            )
        self.batches_seen = 0
        self._pending_summary: dict | None = None
        self._folder = None
        self._cell_counter = None
        if config.async_fold and self.tracked:  # nothing tracked: no-op tracker
            from repro.stream.device import AsyncFolder, make_cell_counter

            self._cell_counter = make_cell_counter(
                [self.features[f].cms for f in self.tracked]
            )
            self._folder = AsyncFolder(self._fold)

    # --- updates ----------------------------------------------------------

    @property
    def counts(self) -> list:
        """Per-feature count providers, indexed by GLOBAL feature index —
        the sketches themselves (``.points`` / ``.id_weights`` duck-typed
        by the transition), None for untracked features (uniform
        fallback; those tables never transition)."""
        return list(self.features)

    def observe(self, batch: dict, *, delta=None) -> None:
        """Accumulate one (un-reshaped) batch.

        ``delta`` — an (F_tracked, depth, width) cell-increment tensor the
        TRAIN STEP already computed (``stream.device.make_step_cell_counter``
        embedded in ``make_train_step(sketch_fn=)``): the sketch update
        then costs zero extra device dispatches; only the O(unique-ids)
        head/ring bookkeeping runs on host (off-thread with ``async_fold``,
        synchronously otherwise — same FIFO per-batch fold either way, so
        flushed state stays a pure function of the batch sequence and
        restart-exactness is preserved)."""
        sparse = np.asarray(batch[self.key]).reshape(-1, len(self.features))
        if delta is not None and self.tracked:
            cols = np.ascontiguousarray(sparse[:, list(self.tracked)])
            if self._folder is not None:
                self._folder.submit((delta, cols))  # device_get off-thread
            else:
                self._fold((delta, cols))
        elif self._folder is not None:
            import jax.numpy as jnp

            cols = np.ascontiguousarray(sparse[:, list(self.tracked)])
            delta = self._cell_counter(jnp.asarray(cols, jnp.int32))
            self._folder.submit((delta, cols))  # device_get happens off-thread
        else:
            for f in self.tracked:
                self.features[f].observe(sparse[:, f])
        self.batches_seen += 1
        w = self.config.window
        if w and self.batches_seen % w == 0:
            self._close_window()

    def _fold(self, item) -> None:
        delta, cols = item
        delta = np.asarray(delta)  # blocks the FOLD thread, not the step
        for j, f in enumerate(self.tracked):
            self.features[f].fold_cells(delta[j], cols[:, j])

    def _close_window(self) -> None:
        """Window boundary: snapshot trigger statistics, then decay."""
        self.flush()
        self._pending_summary = self._summarize()
        if self.config.decay != 1.0:
            for f in self.tracked:
                self.features[f].decay(self.config.decay)

    def flush(self) -> None:
        """Barrier for the async fold path (no-op otherwise) — call before
        sampling, checkpointing, or reading statistics."""
        if self._folder is not None:
            self._folder.flush()

    # --- trigger-facing statistics ----------------------------------------

    def _summarize(self) -> dict | None:
        per = [self.features[f].summary() for f in self.tracked]
        live = [s for s in per if s is not None]
        if not live:
            return None
        mass = sum(s["mass"] for s in live)
        entropy = sum(s["mass"] * s["entropy"] for s in live) / mass
        return {
            "entropy": float(entropy),
            "mass": float(mass),
            "heads": [
                (s["head_ids"], s["head_probs"]) if s is not None else None
                for s in per
            ],
            "batches_seen": self.batches_seen,
        }

    def export_heads(self, n: int | None = None) -> dict[int, np.ndarray]:
        """Current SpaceSaving head ids per tracked feature (descending
        estimated count, at most ``n`` each) — the hot-id set a serve
        cache materializes (serve/dlrm.py).  Flushes the async fold so
        the export reflects every observed batch."""
        self.flush()
        out: dict[int, np.ndarray] = {}
        for f in self.tracked:
            ids, _ = self.features[f].hh.head()
            out[f] = ids[:n] if n is not None else ids
        return out

    def poll_window(self) -> dict | None:
        """The statistics snapshot of the most recently CLOSED window, once
        (cleared on read) — the Trainer feeds it to the trigger policy."""
        s, self._pending_summary = self._pending_summary, None
        return s

    # --- memory / checkpoint ----------------------------------------------

    @property
    def nbytes(self) -> int:
        """Tracker state memory: O(width·depth + heavy + ring) per tracked
        feature — NO term scales with the vocabulary."""
        return sum(self.features[f].nbytes for f in self.tracked)

    def state_tree(self) -> list[np.ndarray]:
        self.flush()
        leaves: list[np.ndarray] = [np.int64(self.batches_seen)]
        for f in self.tracked:
            leaves.extend(self.features[f].state_tree())
        return leaves

    def state_template(self) -> list[np.ndarray]:
        """Restore-template form: a FRESH tracker's state (same fixed
        shapes as the live one).  When a sectioned checkpoint has no
        ``id_counts`` section, the template value IS what gets restored —
        a deterministic empty tracker beats a stale live-state mix (same
        reasoning as ``ClusterTrigger.state_template``)."""
        fresh = SketchFrequencyTracker(
            self.vocab_sizes,
            dataclasses.replace(self.config, async_fold=False),
            tracked=self.tracked, key=self.key,
        )
        return fresh.state_tree()

    def load_state_tree(self, tree: Sequence[np.ndarray]) -> None:
        self.flush()
        tree = list(tree)
        self.batches_seen = int(tree[0])
        per = len(self.features[self.tracked[0]].state_tree()) if self.tracked else 0
        off = 1
        for f in self.tracked:
            self.features[f].load_state_tree(tree[off : off + per])
            off += per
        self._pending_summary = None

    # --- legacy dense-checkpoint migration --------------------------------

    def state_from_dense(self, counts: Sequence[np.ndarray]) -> list[np.ndarray]:
        """The state tree a fresh sketch tracker holds after ingesting a
        dense per-feature histogram list (``IdFrequencyTracker`` layout):
        exact top-``heavy`` head per feature (bit-for-bit), tail folded
        into the sketch, ring seeded with the highest-count tail ids."""
        # scratch tracker is read once for its state: no async machinery
        # (a folder thread + jitted counter would be spawned and leaked)
        fresh = SketchFrequencyTracker(
            self.vocab_sizes, dataclasses.replace(self.config, async_fold=False),
            tracked=self.tracked, key=self.key,
        )
        for f in fresh.tracked:
            fresh.features[f].ingest_dense(np.asarray(counts[f]))
        # batches_seen restarts at 0: dense-era checkpoints carried no
        # batch count, and seeding it from the LIVE tracker would make
        # the window phase (and thus the trigger schedule) depend on
        # whether the restore ran in-process or in a fresh process
        return fresh.state_tree()

    def checkpoint_migrations(self):
        """``Trainer(migrations=...)``-shaped (to_old, to_new) pair: a
        checkpoint whose ``id_counts`` is the legacy dense layout restores
        into sketch state via ``state_from_dense``."""

        def to_old(template):
            if not (isinstance(template, dict) and "id_counts" in template):
                return template
            # zero-size WILDCARD per feature, not np.zeros(vocab): the
            # template only has to match the legacy layout's leaf COUNT
            # (one per feature — the sketch layout has a different count),
            # and materializing full-vocab zeros for every restore
            # candidate would reintroduce the very O(vocab) transients
            # this tracker exists to avoid
            return dict(
                template,
                id_counts=[np.zeros(0, np.int64) for _ in self.vocab_sizes],
            )

        def to_new(tree):
            if isinstance(tree, dict) and "id_counts" in tree:
                tree = dict(tree, id_counts=self.state_from_dense(tree["id_counts"]))
            return tree

        return [(to_old, to_new)]
