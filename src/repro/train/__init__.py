from repro.train.freq import IdFrequencyTracker  # noqa: F401
from repro.train.loop import (  # noqa: F401
    TrainState,
    make_train_step,
    split_buffers,
    merge_buffers,
    StragglerMonitor,
    Trainer,
)
