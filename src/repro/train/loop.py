"""The distributed training loop.

Pieces:
  * ``make_train_step`` — builds the jitted step: microbatch gradient
    accumulation (lax.scan), optional int8 gradient compression with error
    feedback, global-norm clipping, optimizer update.  Pure function of
    (state, batch) so it lowers/compiles for any mesh.
  * buffer split — embedding-table buffers mix arrays with static python
    ints.  Arrays ride the train state; ints are closed over statically.
    EVERYTHING the clustering transition rewrites (CCE ptr/hs/epoch) is
    therefore an array — a static leaf would leave the jitted step
    training against pre-transition hash functions.  Only buffers of the
    non-transitioning tables (embeddings.py hash coefficients) stay
    static.
  * ``Trainer`` — host-side orchestration: data feed, CCE clustering
    callback every ``cluster_every`` steps (the paper's Algorithm 3 line
    10 interleaving), async checkpointing, straggler monitor, failure
    injection for fault-tolerance tests, restart-exact resume.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
import warnings
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.obs.pump import MetricsPump
from repro.obs.trace import ProfileWindow, span
from repro.optim import Optimizer, clip_by_global_norm
from repro.optim.compression import compressed_grad_transform, init_error_feedback

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: Pytree
    ebuf: Pytree  # dynamic (array) part of the embedding buffers
    step: jax.Array
    err: Pytree | None = None  # int8-compression error feedback


# --- buffer split -------------------------------------------------------------


def _is_arr(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray)) or hasattr(x, "shape")


def split_buffers(buffers: Pytree):
    """-> (dynamic, static).  ``dynamic`` has the same structure with None
    at static positions (a valid pytree arg); ``static`` is an opaque token
    to close over."""
    leaves, treedef = jax.tree.flatten(buffers)
    dynamic = jax.tree.unflatten(
        treedef, [leaf if _is_arr(leaf) else None for leaf in leaves]
    )
    static = (treedef, tuple((i, leaf) for i, leaf in enumerate(leaves) if not _is_arr(leaf)))
    return dynamic, static


def merge_buffers(dynamic: Pytree, static) -> Pytree:
    treedef, items = static
    n = treedef.num_leaves
    leaves: list = list(jax.tree.leaves(dynamic))
    # re-insert static leaves at their original flat positions
    out: list = []
    it = iter(leaves)
    static_at = dict(items)
    for i in range(n):
        out.append(static_at[i] if i in static_at else next(it))
    return jax.tree.unflatten(treedef, out)


# --- the step -----------------------------------------------------------------


def make_train_step(
    loss_fn: Callable[[Pytree, Pytree, Pytree], tuple[jax.Array, dict]],
    optimizer: Optimizer,
    lr_fn: Callable[[jax.Array], jax.Array],
    static_buffers,
    *,
    accum: int = 1,
    clip_norm: float = 1.0,
    compress_grads: bool = False,
    grad_specs: Pytree | None = None,
    sketch_fn: Callable[[Pytree], jax.Array] | None = None,
    telemetry=None,
    donate: bool = False,
):
    """loss_fn(params, buffers, microbatch) -> (loss, metrics dict).

    The returned step expects batch leaves shaped (accum, micro, ...).
    ``grad_specs`` (optional PartitionSpec tree) shards the gradient
    accumulators over the data axis (ZeRO-2-style): each microbatch's
    cross-data reduction then lowers to a reduce-scatter instead of a full
    all-reduce — half the per-chip collective bytes on the dominant train
    collective (§Perf).

    ``sketch_fn(microbatch) -> (F, depth, width) int32`` (see
    ``stream.device.make_step_cell_counter``) embeds the frequency
    tracker's cell counter IN the step: the per-microbatch deltas
    accumulate across the gradient-accumulation scan and the summed delta
    rides out in ``metrics["sketch_delta"]`` — sketch tracking then adds
    ZERO extra device dispatches (the Trainer hands the delta to
    ``tracker.observe(batch, delta=...)``).

    ``telemetry`` (a ``repro.obs.TelemetryConfig``) rides the same
    protocol: in-step health metrics (per-emb-group grad/slab norms,
    per-leaf nonfinite counts, lookup occupancy / routing skew) computed
    from the averaged pre-clip grads and returned under
    ``metrics["telemetry"]`` — pure jnp reductions fused into the step's
    single program, so the launch count is unchanged (the
    ``train_step_telemetry`` audit spec asserts it).

    ``donate=True`` returns the step already jitted with
    ``donate_argnums=(0,)``: the TrainState's buffers (params, optimizer
    moments, embedding buffers, error feedback) are donated and the update
    happens in place — asserted via a lowering/donation check in
    tests/test_train_loop.py.
    """

    def _constrain_grads(g):
        if grad_specs is None:
            return g
        # map over the SPEC tree with is_leaf: PartitionSpec is tuple-like
        # and would otherwise be flattened as a sequence
        from jax.sharding import PartitionSpec as _P

        return jax.tree.map(
            lambda s, t: jax.lax.with_sharding_constraint(t, s),
            grad_specs, g, is_leaf=lambda x: isinstance(x, _P),
        )

    def train_step(state: TrainState, batch: Pytree):
        buffers = merge_buffers(state.ebuf, static_buffers)

        def micro(carry, mb):
            gsum, loss_sum = carry
            (loss, _m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, buffers, mb
            )
            gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads)
            gsum = _constrain_grads(gsum)
            # the sketch cell delta is a scan OUTPUT (summed below), not
            # an extra dispatch: it lowers into the same program
            delta = sketch_fn(mb) if sketch_fn is not None else None
            return (gsum, loss_sum + loss), delta

        gzero = _constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        )
        if accum == 1:
            mb0 = jax.tree.map(lambda x: x[0], batch)
            (grads, loss_sum), delta = micro((gzero, jnp.float32(0)), mb0)
        else:
            (grads, loss_sum), deltas = jax.lax.scan(
                micro, (gzero, jnp.float32(0)), batch
            )
            delta = None if deltas is None else deltas.sum(axis=0)
        grads = jax.tree.map(lambda g: g / accum, grads)
        loss = loss_sum / accum

        health = None
        if telemetry is not None:
            from repro.obs.telemetry import telemetry_metrics

            # measured on the TRUE averaged gradient, before int8
            # compression and clipping rewrite it
            with jax.named_scope("telemetry"):
                health = telemetry_metrics(telemetry, grads, state.params, batch)

        err = state.err
        if compress_grads:
            grads, err = compressed_grad_transform(grads, err)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params, lr)
        new_state = TrainState(
            params=new_params, opt=new_opt, ebuf=state.ebuf,
            step=state.step + 1, err=err,
        )
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        if delta is not None:
            metrics["sketch_delta"] = delta
        if health is not None:
            metrics["telemetry"] = health
        return new_state, metrics

    if donate:
        return jax.jit(train_step, donate_argnums=(0,))
    return train_step


def init_state(params, optimizer: Optimizer, dynamic_buffers, *, compress_grads=False):
    return TrainState(
        params=params,
        opt=optimizer.init(params),
        ebuf=dynamic_buffers,
        step=jnp.zeros((), jnp.int32),
        err=init_error_feedback(params) if compress_grads else None,
    )


# --- host-side orchestration ----------------------------------------------------


class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than mean + k·std.

    On a pod, per-host step times feed this via the metrics channel; the
    flagged host ids drive the re-shard/evict decision.  Here it watches
    the single-process step and is unit-tested with injected delays.

    SEMANTIC NOTE (the async-pump change): ``Trainer.run`` used to feed
    this dispatch+sync wall time (it forced ``block_until_ready`` every
    step).  It now feeds DISPATCH-TO-DISPATCH wall time: dispatch stays
    pipelined, and once the dispatch queue applies backpressure the
    interval converges to true per-step throughput — which is what a
    straggler threshold should watch.  Early-run intervals (queue still
    filling) are shorter than device step time; the ``warmup`` window
    absorbs them.  Thresholds tuned against the old synced numbers read
    slightly high against the new ones.
    """

    def __init__(self, alpha: float = 0.1, k: float = 4.0, warmup: int = 5):
        self.alpha, self.k, self.warmup = alpha, k, warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (self.mean + dt) / 2 if self.n == 2 else self.mean + self.alpha * (dt - self.mean)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        is_straggler = dt > self.mean + self.k * max(self.var, 1e-12) ** 0.5
        if is_straggler:
            self.flagged.append((step, dt))
        else:  # stragglers don't poison the EMA
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault injection for restart tests: raises RuntimeError
    at the given steps (once each)."""

    at_steps: tuple[int, ...] = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def _cluster_fn_takes_opt(fn) -> bool:
    """The transition callback comes in two arities:
    ``(key, params, buffers)`` (legacy) and
    ``(key, params, buffers, opt) -> (params, buffers, opt)`` — the
    optimizer-state-aware form that remaps/resets per-row moments through
    the new cluster assignments (see ``repro.optim.remap``).

    Detection: an explicit ``fn.cluster_takes_opt`` attribute wins (set it
    on wrapped/partial callables where the signature lies); otherwise the
    4-arg form requires a parameter literally named ``opt``, or four
    REQUIRED positional parameters — a legacy callback with trailing
    optional extras (``def f(key, p, b, verbose=False)``) stays legacy."""
    explicit = getattr(fn, "cluster_takes_opt", None)
    if explicit is not None:
        return bool(explicit)
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    ps = [
        p for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
    ]
    # opt is passed POSITIONALLY, so only positional kinds count — a
    # keyword-only `*, opt=None` stays on the legacy 3-arg call
    if any(p.name == "opt" for p in ps):
        return True
    return len([p for p in ps if p.default is p.empty]) >= 4


class Trainer:
    """data -> step -> [cluster] -> [checkpoint], restart-exact.

    ``cluster_fn`` is the CCE transition (Alg. 3); it runs OUTSIDE the
    jitted step every ``cluster_every`` steps, like the paper's per-epoch
    clustering.  The 4-arg form additionally receives (and returns) the
    optimizer state so per-row moments survive the transition; both the
    params and the remapped optimizer state land back in ``TrainState``,
    which is what the checkpoint saves — resume after a transition is
    exact."""

    def __init__(
        self,
        train_step,
        state: TrainState,
        static_buffers,
        data_iter,
        *,
        ckpt_dir: str | None = None,
        ckpt_every: int = 0,
        keep_last: int = 3,
        cluster_fn=None,
        cluster_every: int = 0,
        cluster_max: int = 0,
        id_tracker=None,
        trigger=None,
        translator=None,
        accum: int = 1,
        monitor: StragglerMonitor | None = None,
        failures: FailureInjector | None = None,
        seed: int = 0,
        migrations=(),
        state_shardings=None,
        runlog=None,
        pump: MetricsPump | None = None,
        pump_lag: int = 8,
        history_max: int | None = 10_000,
        sync_every: int = 0,
        profile_steps: tuple[int, int] | None = None,
        profile_dir: str | None = None,
    ):
        self.train_step = train_step
        self.state = state
        self.static_buffers = static_buffers
        self.data_iter = data_iter
        self.ckpt = CheckpointManager(ckpt_dir, keep_last) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.cluster_fn = cluster_fn
        self._cluster_takes_opt = (
            cluster_fn is not None and _cluster_fn_takes_opt(cluster_fn)
        )
        self.cluster_every = cluster_every
        self.cluster_max = cluster_max
        self.id_tracker = id_tracker  # feeds the transition's k-means sample
        # adaptive schedule: a repro.stream.ClusterTrigger evaluated on
        # every closed tracker window — fires the SAME transition the
        # periodic schedule does (both can be active; cluster_max caps
        # their union).  Requires a windowed tracker (poll_window).
        self.trigger = trigger
        if trigger is not None:
            windowed = getattr(id_tracker, "poll_window", None) is not None
            window = getattr(getattr(id_tracker, "config", None), "window", None)
            if not windowed or window == 0:
                warnings.warn(
                    "Trainer(trigger=...) needs a windowed tracker "
                    "(SketchFrequencyTracker with StreamConfig(window>0)); "
                    "the adaptive schedule will never evaluate"
                )
        # host-translating pipelines (data.translate.HostTranslator
        # wrapped around data_iter) mirror the pointer buffers — the
        # mirrors go stale the moment a transition rewrites ptr/hs, so
        # the Trainer re-syncs the translator after every transition and
        # after a checkpoint restore (translate_batches is lazy: the
        # next batch already uses the fresh mirrors)
        self.translator = translator
        self.clusters_done = 0
        self.accum = accum
        self.monitor = monitor or StragglerMonitor()
        self.failures = failures
        self.seed = seed
        # (to_old, to_new) template/convert pairs for checkpoints written
        # under older state layouts (e.g. dlrm.checkpoint_migrations for
        # pre-collection per-feature emb trees).  Trackers contribute
        # their own (the sketch tracker restores legacy DENSE id_counts
        # by ingesting the histograms — exact on the head ids).
        tracker_migrations = getattr(id_tracker, "checkpoint_migrations", None)
        self.migrations = tuple(migrations) + (
            tuple(tracker_migrations()) if tracker_migrations else ()
        )
        # a TrainState-shaped tree of jax.sharding.Sharding for the
        # sharded trainer (launch.steps.dlrm_state_shardings): state
        # produced OUTSIDE the donated jitted step — the eager clustering
        # transition, a checkpoint restore — is device_put back onto the
        # step's layout before the next step runs, so donation never has
        # to reshard and no replica silently ends up with the full slab
        self.state_shardings = state_shardings
        # observability (DESIGN.md §10): metrics leave the device through
        # the async pump — a ring drained ``pump_lag`` steps behind the
        # dispatch front, so reading a metric never syncs the pipeline.
        # ``history`` is the pump's bounded record deque (``history_max``
        # caps a long run's host memory); it is EXACT after run() returns
        # (final flush) and, mid-run, after every ``sync_every`` steps
        # when that is set — tests that read history mid-run set
        # sync_every=1 and see the old always-synced behavior.
        self.runlog = runlog
        self.pump = pump or MetricsPump(
            lag=pump_lag, maxlen=history_max,
            sink=runlog.log_step if runlog is not None else None,
        )
        self.sync_every = sync_every
        self.profile = (
            ProfileWindow(*profile_steps, log_dir=profile_dir or "profile")
            if profile_steps is not None else None
        )
        self._last_dispatch: float | None = None

    @property
    def history(self):
        return self.pump.history

    def _place(self, state: TrainState) -> TrainState:
        if self.state_shardings is None:
            return state
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, self.state_shardings
        )

    def _reshape_accum(self, batch):
        def r(x):
            x = np.asarray(x)
            if self.accum == 1:
                return x[None]
            return x.reshape(self.accum, x.shape[0] // self.accum, *x.shape[1:])
        return {k: r(v) for k, v in batch.items() if k != "step"}

    def run(self, n_steps: int):
        # ONE sync to seed the host step mirror (blocking on state.step
        # every iteration — like the old loop did — waits for the whole
        # previous step and kills async dispatch; the mirror is exact
        # because the step increments by 1 and transitions/restores only
        # happen between run() calls or below, where we track them)
        step = int(self.state.step)
        try:
            for _ in range(n_steps):
                if self.profile is not None:
                    self.profile.observe(step)
                if self.failures is not None:
                    try:
                        self.failures.maybe_fail(step)
                    except Exception as e:
                        # make the records of the completed steps durable
                        # before the crash propagates, and log the fire
                        # (dedupe off: a from-scratch restart re-fires at
                        # the same step and both fires are real)
                        self.pump.flush()
                        if self.runlog is not None:
                            self.runlog.append(
                                "fault", step=step, dedupe=False, error=str(e),
                            )
                        raise
                raw = next(self.data_iter)
                batch = self._reshape_accum(raw)
                with span("dispatch"):
                    self.state, metrics = self.train_step(self.state, batch)
                # dispatch-to-dispatch wall time (see StragglerMonitor's
                # semantic note): attributed to this step, first step of a
                # run() has no previous dispatch to measure from
                t1 = time.perf_counter()
                dt = None
                if self._last_dispatch is not None:
                    dt = t1 - self._last_dispatch
                    self.monitor.observe(step, dt)
                self._last_dispatch = t1
                # a step built with sketch_fn= already computed the tracker's
                # cell delta inside its single launch — hand it over so the
                # tracker skips its own counter dispatch (zero extra
                # dispatches; the host head/ring bookkeeping is unchanged)
                delta = metrics.pop("sketch_delta", None)
                if self.id_tracker is not None:
                    with span("sketch-fold"):
                        if delta is not None:
                            self.id_tracker.observe(raw, delta=delta)
                        else:
                            self.id_tracker.observe(raw)
                self.pump.push(step, metrics, extra={"dt": dt})

                new_step = step + 1
                # adaptive schedule: a windowed tracker snapshots statistics
                # at window close; the trigger turns them into a fire/hold
                # decision.  Deterministic given the batch stream + restored
                # trigger state, so resume replays the schedule exactly.
                can_cluster = self.cluster_fn is not None and (
                    not self.cluster_max or self.clusters_done < self.cluster_max
                )
                triggered = False
                if self.id_tracker is not None and self.trigger is not None:
                    poll = getattr(self.id_tracker, "poll_window", None)
                    stats = poll() if poll is not None else None
                    if stats is not None:
                        # the availability gate rides INTO the trigger: a fire
                        # that cannot run a transition must not commit
                        # fire-state (reference reset, spacing counter)
                        ev = self.trigger.update(
                            stats, step=new_step, can_fire=can_cluster
                        )
                        triggered = ev.fire
                        if self.runlog is not None:
                            # replayed evaluations after a resume dedupe on
                            # (event, step) — same policy restore_latest
                            # applies to trigger.events
                            self.runlog.append("trigger", **ev.as_dict())
                periodic = bool(
                    self.cluster_every and new_step % self.cluster_every == 0
                )
                if can_cluster and (periodic or triggered):
                    with span("transition"):
                        if self.id_tracker is not None:  # async folds must land
                            getattr(self.id_tracker, "flush", lambda: None)()
                        key = jax.random.fold_in(
                            jax.random.PRNGKey(self.seed), new_step
                        )
                        buffers = merge_buffers(self.state.ebuf, self.static_buffers)
                        if self._cluster_takes_opt:
                            params, buffers, opt = self.cluster_fn(
                                key, self.state.params, buffers, self.state.opt
                            )
                        else:
                            params, buffers = self.cluster_fn(
                                key, self.state.params, buffers
                            )
                            opt = self.state.opt
                        dyn, self.static_buffers = split_buffers(buffers)
                        # int8-EF residuals are per-row state like the moments:
                        # the rewritten rows make them meaningless, and (unlike
                        # moments) zeroing them is always sound — EF only
                        # corrects future quantization, it carries no required
                        # state
                        err = (
                            init_error_feedback(params)
                            if self.state.err is not None else None
                        )
                        self.state = self._place(self.state._replace(
                            params=params, ebuf=dyn, opt=opt, err=err
                        ))
                        self.clusters_done += 1
                        if self.translator is not None:  # mirrors went stale
                            self.translator.update(buffers["emb"])
                    if self.runlog is not None:
                        self.runlog.append(
                            "transition", step=new_step,
                            reason="trigger" if triggered else "periodic",
                            clusters_done=self.clusters_done,
                        )

                if self.ckpt and self.ckpt_every and new_step % self.ckpt_every == 0:
                    # flush first: every step record at or before the
                    # checkpointed step is durable before the save event —
                    # resume-time replays then dedupe against a complete
                    # prefix of the log
                    self.pump.flush()
                    with span("checkpoint"):
                        self.ckpt.save_async(new_step, self._ckpt_tree())
                    if self.runlog is not None:
                        self.runlog.append("checkpoint_save", step=new_step)
                elif self.sync_every and new_step % self.sync_every == 0:
                    self.pump.flush()
                step = new_step
        finally:
            self.pump.flush()
            if self.profile is not None:
                self.profile.close()
        if self.ckpt:
            self.ckpt.wait()
        return list(self.history)

    def _ckpt_tree(self):
        # clusters_done and the id histograms ride the checkpoint so a
        # restart cannot re-run (or skip) transitions against cluster_max,
        # and the k-means sampling distribution resumes exactly — the
        # transition schedule is part of the training state, not of the
        # host process.
        tree = {"state": self.state, "clusters_done": np.int32(self.clusters_done)}
        if self.id_tracker is not None:
            tree["id_counts"] = self.id_tracker.state_tree()
        if self.trigger is not None:
            # trigger state is training state too: resuming without it
            # would re-arm the entropy reference and replay fires
            tree["trigger"] = self.trigger.state_tree()
        return tree

    def _stored_n_leaves(self):
        """Leaf count of the latest committed checkpoint (None if none) —
        sizes the id_counts wildcard placeholders."""
        from repro.checkpoint.store import list_checkpoints
        import json
        import os

        ckpts = list_checkpoints(self.ckpt.directory)
        if not ckpts:
            return None
        with open(os.path.join(ckpts[-1][1], "manifest.json")) as f:
            return int(json.load(f)["n_leaves"])

    def _with_id_counts_placeholder(self, template):
        """When the WRITER had a tracker this Trainer doesn't, absorb the
        saved id_counts leaves via zero-size wildcard placeholders sized
        against THIS template's leaf count (the histograms are dropped).
        Must be applied per candidate layout — legacy layouts have
        different leaf counts, so one global placeholder cannot fit all."""
        if self.id_tracker is not None or "id_counts" in template:
            return None
        n_stored = self._stored_n_leaves()
        if n_stored is None:
            return None
        extra = n_stored - len(jax.tree.leaves(template))
        if extra <= 0:
            return None
        return dict(template, id_counts=[np.zeros(0)] * extra)

    def _restore_templates(self):
        """Candidate checkpoint layouts, most- to least-informative: the
        current config's layout, then the layouts a differently-configured
        writer could have produced (tracker-less: no id_counts; pre-
        transition-subsystem: state only)."""
        # template forms, not live state (no _ckpt_tree: that would copy
        # and flush the full live tracker only to be overwritten here):
        # a sectioned checkpoint MISSING one of these sections restores
        # the template value, so templates must be deterministic fresh
        # state (and the trigger's prev-head leaves become zero-size
        # wildcards — the stored row count depends on whether the WRITER
        # had closed a window yet)
        cur = {"state": self.state, "clusters_done": np.int32(self.clusters_done)}
        if self.id_tracker is not None:
            tmpl = getattr(self.id_tracker, "state_template", None)
            cur["id_counts"] = tmpl() if tmpl else self.id_tracker.state_tree()
        if self.trigger is not None:
            cur["trigger"] = self.trigger.state_template()
        templates = [cur]
        if self.trigger is not None:
            # writer predates the trigger (sectioned checkpoints align
            # this by name; the variant covers pre-section writers)
            templates.append(
                {k: v for k, v in cur.items() if k != "trigger"}
            )
        base = {"state": self.state, "clusters_done": np.int32(0)}
        if self.id_tracker is not None:
            templates.append(base)  # writer had no tracker
        else:
            with_counts = self._with_id_counts_placeholder(base)
            if with_counts is not None:  # writer-side id_counts, dropped
                templates.append(with_counts)
        templates.append({"state": self.state})  # pre-transition layout
        return templates

    def restore_latest(self):
        self.ckpt.wait()  # an async save may still be in flight post-crash
        templates = self._restore_templates()
        candidates = [(t, None) for t in templates]
        # legacy layouts: derive each old-layout template from the current
        # one and restore through its converter (checkpoint.load_checkpoint
        # picks the first candidate whose leaves match).  The id_counts
        # placeholder is re-sized against each CONVERTED template — legacy
        # layouts have different leaf counts.  Migrations also COMPOSE
        # pairwise: a checkpoint can be old along two independent axes at
        # once (pre-collection emb layout AND dense id_counts) — each
        # to_old chains on the other's template, converts apply in
        # reverse, so the combined-legacy layout restores too.
        pairs = list(self.migrations)
        for a_old, a_new in self.migrations:
            for b_old, b_new in self.migrations:
                if b_old is a_old:
                    continue

                def chained_old(t, ao=a_old, bo=b_old):
                    return bo(ao(t))

                def chained_new(tree, an=a_new, bn=b_new):
                    tree = bn(tree) if bn is not None else tree
                    return an(tree) if an is not None else tree

                pairs.append((chained_old, chained_new))
        for to_old, to_new in pairs:
            for t in templates:
                try:
                    old_t = to_old(t)
                except (KeyError, IndexError, TypeError, ValueError):
                    # two migrations along the SAME axis (e.g. two emb
                    # layout converters) don't compose — the structural
                    # mismatch is expected and the chain is simply not a
                    # candidate layout.  Anything else (AttributeError
                    # from a buggy migration, MemoryError, ...) is a real
                    # defect and propagates.
                    continue
                candidates.append((old_t, to_new))
                with_counts = self._with_id_counts_placeholder(old_t)
                if with_counts is not None:
                    candidates.append((with_counts, to_new))
        step, tree, _ = load_checkpoint(self.ckpt.directory, migrations=candidates)
        self.state = self._place(tree["state"])
        self.clusters_done = int(tree.get("clusters_done", 0))
        if self.id_tracker is not None:
            if "id_counts" in tree:
                self.id_tracker.load_state_tree(tree["id_counts"])
            else:
                # the matched layout had no usable histogram section (old
                # writer, or a StreamConfig change made the shapes
                # unmatchable): restore the deterministic fresh state the
                # sectioned path would have installed, and surface it —
                # leaving the live tracker's POST-checkpoint observations
                # in place would silently diverge in in-process recovery
                template = getattr(self.id_tracker, "state_template", None)
                if template is not None:
                    self.id_tracker.load_state_tree(template())
                warnings.warn(
                    "checkpoint had no usable id_counts section; tracker "
                    "restarted fresh from the restored step"
                )
        if self.trigger is not None:
            if "trigger" in tree:
                self.trigger.load_state_tree(tree["trigger"])
                # windows evaluated between this checkpoint and the crash
                # will be re-evaluated on replay — drop their events so
                # the log shows each closed window once
                self.trigger.events = [
                    e for e in self.trigger.events if e.step <= step
                ]
            else:
                # same deterministic semantics as the sectioned path
                # (missing section restores the fresh template)
                self.trigger.load_state_tree(self.trigger.state_template())
                self.trigger.events = [
                    e for e in self.trigger.events if e.step <= step
                ]
                warnings.warn(
                    "checkpoint had no trigger section; trigger restarted "
                    "fresh from the restored step"
                )
        if self.translator is not None:  # mirrors must match restored ptr/hs
            self.translator.update(
                merge_buffers(self.state.ebuf, self.static_buffers)["emb"]
            )
        # the restore gap is not a step interval; don't let it poison the
        # monitor's dispatch-to-dispatch EMA
        self._last_dispatch = None
        if self.runlog is not None:
            self.runlog.append("checkpoint_restore", step=step, dedupe=False)
        return step
