"""The clustering transition, shared by every model (DESIGN.md §2).

``transition_table`` is one CCE table's complete transition:  derive a
sampling seed from the transition key, build the k-means point set from
observed id frequencies when a histogram exists (count-WEIGHTED — every
observed id once, weighted by frequency), cluster, and build the
moment-update function that ``remap_opt_state`` applies to each optimizer
slot (computing the per-cluster counts once so Adam's m AND v reuse them).

``transition_collection`` runs it across an ``EmbeddingCollection``:
per-feature slices come out of the grouped supertables, transition
independently (each with its own key/histogram), and re-stack — so the
training loop keeps carrying ONE stacked slab per group through the jitted
step while the transition stays a per-table algorithm.  The LM launcher
uses ``transition_table`` directly (one vocab table); centralizing both
here keeps the paths from drifting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.remap import collection_moment_updater, zeros_like_moments
from repro.stream.points import points_from_counts


def _draw_points(counts, n: int, seed: int):
    """(ids, weights) from a per-feature count source: a DENSE histogram
    array, or a sketch-backed provider (``repro.stream.FeatureSketch``)
    exposing ``points(n, seed)`` — exact head + unbiased tail at
    vocab-independent tracker memory."""
    if hasattr(counts, "points"):
        return counts.points(n, seed)
    return points_from_counts(counts, n, seed)


def _dense_weights(counts, d1: int) -> np.ndarray:
    """Per-id weights for the count-weighted moment remap.  Dense
    histograms are used verbatim; a sketch provider streams an O(d1)
    TRANSIENT estimate (same order as the transition's assign_all pass —
    tracker state stays O(sketch))."""
    if hasattr(counts, "id_weights"):
        return counts.id_weights(d1)
    return np.asarray(counts)


def transition_table(
    table,
    key,
    params,
    buffers,
    *,
    counts=None,
    policy: str = "remap",
    chunk_size: int | None = None,
    use_kernel: bool | None = None,
    max_points_per_centroid: int = 256,
    mesh=None,
    shard_axis: str | None = None,
):
    """Returns ``(new_params, new_buffers, update_moments)`` for one CCE
    table.  ``counts`` is the table's observed id histogram — a dense
    array OR a sketch provider with ``points``/``id_weights`` (see
    ``repro.stream``); when present the k-means runs count-WEIGHTED on
    the observed ids (the paper's epoch-boundary distribution, exactly —
    not a with-replacement approximation of it) and the moment remap
    averages with the same weights.  None or all-zero falls back to
    uniform subsampling.  ``update_moments(moment_subtree)`` remaps/
    resets/keeps that table's per-row optimizer moments per ``policy``.

    ``mesh``/``shard_axis`` route every O(d1) phase through the sharded
    implementations (``cluster_sharded`` / ``remap_moments_sharded``) —
    id ranges and pointer tables shard over ``shard_axis``, so the
    transition never assembles a full (c, d1) ptr on one device.  On a
    1-device axis the sharded paths are bit-identical to the serial
    ones (same key schedule), so the clustering trajectory does not
    depend on the mesh."""
    sample_ids = sample_weights = id_weights = None
    if counts is not None:
        seed = int(
            jax.random.randint(jax.random.fold_in(key, 10_007), (), 0, 2**31 - 1)
        )
        drawn = _draw_points(
            counts, min(table.d1, max_points_per_centroid * table.k), seed
        )
        if drawn is not None:
            sample_ids = jnp.asarray(drawn[0])
            sample_weights = jnp.asarray(drawn[1], jnp.float32)
            id_weights = jnp.asarray(_dense_weights(counts, table.d1), jnp.float32)
    sharded = mesh is not None and shard_axis is not None
    if sharded:
        new_params, new_buffers = table.cluster_sharded(
            key, params, buffers, mesh, axis_name=shard_axis,
            sample_ids=sample_ids, sample_weights=sample_weights,
            chunk_size=chunk_size, use_kernel=use_kernel,
            max_points_per_centroid=max_points_per_centroid,
        )
    else:
        new_params, new_buffers = table.cluster(
            key, params, buffers,
            sample_ids=sample_ids, sample_weights=sample_weights,
            chunk_size=chunk_size, use_kernel=use_kernel,
            max_points_per_centroid=max_points_per_centroid,
        )
    cluster_counts = (
        table.assignment_counts(new_buffers)
        if policy == "remap" and not sharded else None
    )

    def update_moments(moments):
        if policy == "keep":
            return moments
        if policy == "reset":
            return zeros_like_moments(moments)
        if sharded:
            # counts accumulate inside the sharded pass (masked ones) —
            # no full-ptr bincount on one device
            return table.remap_moments_sharded(
                moments, buffers, new_buffers, mesh, axis_name=shard_axis,
                chunk_size=chunk_size, id_weights=id_weights,
            )
        return table.remap_moments(
            moments, buffers, new_buffers,
            chunk_size=chunk_size, counts=cluster_counts, id_weights=id_weights,
        )

    return new_params, new_buffers, update_moments


def transition_collection(
    coll,
    key,
    emb_params,
    emb_buffers,
    *,
    id_counts=None,
    policy: str = "remap",
    chunk_size: int | None = None,
    use_kernel: bool | None = None,
    max_points_per_centroid: int = 256,
    mesh=None,
    shard_axis: str | None = None,
):
    """Transition every CCE table behind an ``EmbeddingCollection``.

    ``emb_params``/``emb_buffers`` are the GROUPED layout; each CCE
    feature's (c, 2, k, dsub) block is sliced out of its (possibly
    method-mixed universal) group, transitioned with
    ``jax.random.fold_in(key, feature_index)`` (the same key schedule as
    the legacy per-table loop, so transitions replay identically from a
    checkpoint), and re-stacked; a group's non-CCE members (full/hash/ce
    tables sharing the supertable launch) pass through untouched.
    Returns ``(new_params, new_buffers, update_emb)`` where ``update_emb``
    transforms a grouped moments["emb"] list group-wise (see
    ``optim.remap.collection_moment_updater``).  ``id_counts`` indexes
    per-feature histograms by GLOBAL feature index.
    """
    from repro.core.cce import CCE

    new_p, new_b = list(emb_params), list(emb_buffers)
    group_updates: dict[int, dict[int, object]] = {}
    for g, grp in enumerate(coll.groups):
        cce_locals = [
            f_local for f_local, t in enumerate(grp.tables) if isinstance(t, CCE)
        ]
        if not cce_locals:
            continue
        per_p = coll.unstack_group_params(grp, emb_params[g])
        per_b = list(emb_buffers[g])
        fns = {}
        for f_local in cce_locals:
            i = grp.features[f_local]
            per_p[f_local], per_b[f_local], fns[f_local] = transition_table(
                grp.tables[f_local], jax.random.fold_in(key, i),
                per_p[f_local], per_b[f_local],
                counts=id_counts[i] if id_counts is not None else None,
                policy=policy, chunk_size=chunk_size, use_kernel=use_kernel,
                max_points_per_centroid=max_points_per_centroid,
                mesh=mesh, shard_axis=shard_axis,
            )
        new_p[g] = coll.stack_group_params(grp, per_p)
        new_b[g] = per_b
        group_updates[g] = fns
    return new_p, new_b, collection_moment_updater(coll, group_updates)
