"""One CCE table's complete clustering transition, shared by every model.

``dlrm.cluster_tables`` (26 tables, per-table configs) and the LM
launcher (one vocab table) need identical plumbing around
``CCE.cluster``: derive a sampling seed from the transition key, draw the
k-means sample from observed id frequencies when a histogram exists,
cluster, and build the moment-update function that ``remap_opt_state``
applies to each optimizer slot (computing the per-cluster counts once so
Adam's m AND v reuse them).  Centralizing it here keeps the two paths
from drifting — policy and chunking knobs reach both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.remap import zeros_like_moments
from repro.train.freq import sample_from_counts


def transition_table(
    table,
    key,
    params,
    buffers,
    *,
    counts=None,
    policy: str = "remap",
    chunk_size: int | None = None,
    use_kernel: bool | None = None,
    max_points_per_centroid: int = 256,
):
    """Returns ``(new_params, new_buffers, update_moments)`` for one CCE
    table.  ``counts`` is the table's observed id histogram (frequency-
    weighted k-means sample — the paper's epoch-boundary distribution);
    None or all-zero falls back to uniform subsampling.
    ``update_moments(moment_subtree)`` remaps/resets/keeps that table's
    per-row optimizer moments per ``policy``."""
    sample_ids = None
    if counts is not None:
        seed = int(
            jax.random.randint(jax.random.fold_in(key, 10_007), (), 0, 2**31 - 1)
        )
        drawn = sample_from_counts(
            counts, min(table.d1, max_points_per_centroid * table.k), seed
        )
        if drawn is not None:
            sample_ids = jnp.asarray(drawn)
    new_params, new_buffers = table.cluster(
        key, params, buffers,
        sample_ids=sample_ids, chunk_size=chunk_size, use_kernel=use_kernel,
        max_points_per_centroid=max_points_per_centroid,
    )
    cluster_counts = (
        table.assignment_counts(new_buffers) if policy == "remap" else None
    )

    def update_moments(moments):
        if policy == "keep":
            return moments
        if policy == "reset":
            return zeros_like_moments(moments)
        return table.remap_moments(
            moments, buffers, new_buffers,
            chunk_size=chunk_size, counts=cluster_counts,
        )

    return new_params, new_buffers, update_moments
