"""Observed-id frequency tracking for the clustering transition.

The paper clusters at epoch boundaries, so its k-means sample is drawn
from the *data stream* — ids appear proportionally to their frequency.
A uniform sample over the vocabulary (the seed behavior) is a different
algorithm on Zipf-distributed data: the never-seen tail dominates the
sample, k-means spends its centroids separating untrained init noise,
and the transition destroys more signal than it frees — measurably
turning Algorithm 3's gain into a regression on the system test.

``IdFrequencyTracker`` restores the paper's sampling distribution for
streaming (epoch-less) pipelines: the Trainer feeds it every batch, the
transition draws its k-means sample from the empirical histogram.  Counts
are plain numpy (host-side, like the pointer tables on a pod) and ride
the checkpoint so resume keeps the same sampling distribution.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def sample_from_counts(counts: np.ndarray, n: int, seed: int) -> np.ndarray | None:
    """Draw ``n`` ids ~ ``counts`` (with replacement — duplicates ARE the
    frequency weighting, exactly what an epoch-boundary sample would
    contain).  None when nothing has been counted yet (callers fall back
    to uniform).  Kept for diagnostics/ablation; the transition now uses
    ``points_from_counts`` (the zero-variance weighted form)."""
    counts = np.asarray(counts)
    total = int(counts.sum())
    if total == 0:
        return None
    rng = np.random.default_rng(seed)
    return rng.choice(counts.shape[0], size=n, replace=True, p=counts / total)


def points_from_counts(
    counts: np.ndarray, n: int, seed: int
) -> tuple[np.ndarray, np.ndarray] | None:
    """(ids, weights) for COUNT-WEIGHTED k-means: every observed id exactly
    once, weighted by its observed frequency.

    The with-replacement draw in ``sample_from_counts`` is an unbiased but
    noisy estimate of this — a weighted Lloyd iteration on unique points
    IS the iteration on the epoch-boundary multiset, with no sampling
    variance and no duplicated materialization work.  None when nothing
    has been counted yet (uniform fallback).

    When more than ``n`` distinct ids were observed (the FAISS-style cap
    still bounds the k-means cost), the subsample is STRATIFIED and
    unbiased: the n/2 highest-count ids enter deterministically with their
    exact counts (inclusion probability 1), and the tail is sampled
    uniformly without replacement with counts inflated by the inverse
    sampling fraction (Horvitz-Thompson).  Sampling the tail ∝ counts and
    ALSO weighting by counts would double-count frequency (head mass
    ~count²); uniform-only sampling risks dropping the head entirely —
    this keeps the estimator unbiased for the weighted objective at low
    variance where the mass actually is.
    """
    counts = np.asarray(counts)
    nz = np.flatnonzero(counts)
    if nz.size == 0:
        return None
    if nz.size <= n:
        return nz, counts[nz].astype(np.float32)
    n_head = n // 2
    order = np.argsort(counts[nz], kind="stable")[::-1]
    head = nz[order[:n_head]]
    rest = nz[order[n_head:]]
    rng = np.random.default_rng(seed)
    n_tail = n - n_head
    tail = rng.choice(rest, size=n_tail, replace=False)
    w = np.concatenate(
        [counts[head], counts[tail] * (rest.size / n_tail)]
    ).astype(np.float32)
    ids = np.concatenate([head, tail])
    order = np.argsort(ids, kind="stable")
    return ids[order], w[order]


class IdFrequencyTracker:
    """Per-feature id histograms from the training stream."""

    def __init__(self, vocab_sizes: Sequence[int], key: str = "sparse"):
        self.key = key
        self.counts = [np.zeros(v, np.int64) for v in vocab_sizes]

    def observe(self, batch: dict) -> None:
        """Accumulate one (un-reshaped) batch: ``batch[self.key]`` is
        (B, n_features) int.  Runs on the training hot path, so the
        update is O(batch) — never O(vocab) (a full-vocab bincount per
        step would dwarf the step itself on 100M-row tables)."""
        sparse = np.asarray(batch[self.key]).reshape(-1, len(self.counts))
        for f, c in enumerate(self.counts):
            np.add.at(c, sparse[:, f], 1)

    def sample_ids(self, seed: int, feature: int, n: int) -> np.ndarray | None:
        """Draw ``n`` ids ~ the observed frequency of ``feature``."""
        return sample_from_counts(self.counts[feature], n, seed)

    # --- checkpoint integration (host state must resume too) -----------------

    def state_tree(self) -> list[np.ndarray]:
        return [c.copy() for c in self.counts]

    def load_state_tree(self, tree: Sequence[np.ndarray]) -> None:
        self.counts = [np.asarray(c).astype(np.int64).copy() for c in tree]
