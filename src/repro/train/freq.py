"""Compat shim — frequency tracking moved to ``repro.stream``.

The dense ``IdFrequencyTracker`` and the point-set helpers now live in
the streaming-statistics subsystem (``repro/stream/``, DESIGN.md §5)
alongside the sketch-backed tracker that replaces the dense histograms
at production vocab sizes.  Import from ``repro.stream``; this module
keeps the historical import path working.
"""
from repro.stream import (  # noqa: F401
    IdFrequencyTracker,
    points_from_counts,
    sample_from_counts,
)
