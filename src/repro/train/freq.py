"""Observed-id frequency tracking for the clustering transition.

The paper clusters at epoch boundaries, so its k-means sample is drawn
from the *data stream* — ids appear proportionally to their frequency.
A uniform sample over the vocabulary (the seed behavior) is a different
algorithm on Zipf-distributed data: the never-seen tail dominates the
sample, k-means spends its centroids separating untrained init noise,
and the transition destroys more signal than it frees — measurably
turning Algorithm 3's gain into a regression on the system test.

``IdFrequencyTracker`` restores the paper's sampling distribution for
streaming (epoch-less) pipelines: the Trainer feeds it every batch, the
transition draws its k-means sample from the empirical histogram.  Counts
are plain numpy (host-side, like the pointer tables on a pod) and ride
the checkpoint so resume keeps the same sampling distribution.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def sample_from_counts(counts: np.ndarray, n: int, seed: int) -> np.ndarray | None:
    """Draw ``n`` ids ~ ``counts`` (with replacement — duplicates ARE the
    frequency weighting, exactly what an epoch-boundary sample would
    contain).  None when nothing has been counted yet (callers fall back
    to uniform).  THE sampling primitive for the transition: tracker and
    ``dlrm.cluster_tables`` both route through it."""
    counts = np.asarray(counts)
    total = int(counts.sum())
    if total == 0:
        return None
    rng = np.random.default_rng(seed)
    return rng.choice(counts.shape[0], size=n, replace=True, p=counts / total)


class IdFrequencyTracker:
    """Per-feature id histograms from the training stream."""

    def __init__(self, vocab_sizes: Sequence[int], key: str = "sparse"):
        self.key = key
        self.counts = [np.zeros(v, np.int64) for v in vocab_sizes]

    def observe(self, batch: dict) -> None:
        """Accumulate one (un-reshaped) batch: ``batch[self.key]`` is
        (B, n_features) int.  Runs on the training hot path, so the
        update is O(batch) — never O(vocab) (a full-vocab bincount per
        step would dwarf the step itself on 100M-row tables)."""
        sparse = np.asarray(batch[self.key]).reshape(-1, len(self.counts))
        for f, c in enumerate(self.counts):
            np.add.at(c, sparse[:, f], 1)

    def sample_ids(self, seed: int, feature: int, n: int) -> np.ndarray | None:
        """Draw ``n`` ids ~ the observed frequency of ``feature``."""
        return sample_from_counts(self.counts[feature], n, seed)

    # --- checkpoint integration (host state must resume too) -----------------

    def state_tree(self) -> list[np.ndarray]:
        return [c.copy() for c in self.counts]

    def load_state_tree(self, tree: Sequence[np.ndarray]) -> None:
        self.counts = [np.asarray(c).astype(np.int64).copy() for c in tree]
