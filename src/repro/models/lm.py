"""Unified decoder LM covering every assigned architecture family.

One ``init``/``forward``/``decode_step`` triple handles:
  dense   — qwen3-14b/4b, qwen2-1.5b, command-r-35b (parallel_block)
  moe     — qwen3-moe-235b-a22b, phi3.5-moe-42b
  hybrid  — hymba (parallel GQA-attention + mamba heads per layer)
  xlstm   — xlstm-1.3b (mLSTM/sLSTM superblocks, no attention at all)
  vlm     — paligemma backbone (precomputed patch embeddings prepended)
  audio   — musicgen backbone (4 EnCodec codebooks summed at input,
            4 output heads)

The input embedding and the output head are the paper's integration
points: ``cfg.emb_method`` selects any table from the unified sketching
framework (full/hash/hemb/ce/robe/dhe/tt/**cce**), and for linear sketches
the output head uses the factored form (k-sized matmuls + integer gathers
instead of a vocab × d matmul) — see core/embeddings.py.

Layer stacks are scanned (stacked params) for O(1) HLO size; remat policy
is configurable per config.  Sharding is pure GSPMD: `param_specs` returns
a PartitionSpec pytree, `forward` places sharding constraints on the
residual stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import embeddings as emb_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.config import ModelConfig


# --- embedding table construction -------------------------------------------


def make_emb(cfg: ModelConfig):
    vocab = cfg.vocab * cfg.n_codebooks if cfg.n_codebooks else cfg.vocab
    return emb_lib.make_table(
        cfg.emb_method,
        vocab,
        cfg.d_model,
        budget=cfg.emb_budget or None,
        c=cfg.emb_c,
        dtype=cfg.param_dtype,
    )


# --- per-layer init ----------------------------------------------------------


def _init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg)}
    if cfg.family == "xlstm":
        raise AssertionError("xlstm uses _init_xlstm_stack")
    p["attn"] = L.init_attention(ks[0], cfg)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg)
        # per-branch output norms (hymba averages normed branch outputs)
        p["attn_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        p["ssm_norm"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    if not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg)
    if cfg.family == "moe":
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def init(key, cfg: ModelConfig):
    """Returns (params, buffers).  buffers = non-trainable (hash coeffs,
    CCE pointer arrays) — kept separate so the optimizer never sees them."""
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    emb = make_emb(cfg)
    emb_params, emb_buffers = emb.init(k_emb)
    params: dict[str, Any] = {"emb": emb_params}
    buffers: dict[str, Any] = {"emb": emb_buffers}

    if cfg.family == "xlstm":
        params["blocks"] = _init_xlstm_stack(k_layers, cfg)
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: _init_layer(k, cfg))(keys)

    params["ln_f"] = L.init_norm(cfg)
    n_heads_out = cfg.n_codebooks if cfg.n_codebooks else 1
    if cfg.tie_embeddings:
        pass  # head reuses emb params
    elif cfg.emb_method in ("full",):
        params["head"] = L.truncated_normal(
            k_head,
            (n_heads_out * cfg.vocab, cfg.d_model),
            1.0 / math.sqrt(cfg.d_model),
            cfg.param_dtype,
        )
    else:
        # compressed factored head: a second table instance (own seed)
        head = dataclasses.replace(make_emb(cfg), seed_salt=1)
        hp, hb = head.init(k_head)
        params["head"] = hp
        buffers["head"] = hb
    if cfg.family == "vlm":
        # stub adapter for precomputed SigLIP patch embeddings
        params["patch_proj"] = L.truncated_normal(
            k_extra, (cfg.d_model, cfg.d_model), 1.0 / math.sqrt(cfg.d_model), cfg.param_dtype
        )
    return params, buffers


def init_buffers(cfg: ModelConfig):
    """Only the embedding buffers (hash coeffs + pointer arrays) — pure
    numpy, no device allocation, no mesh interaction.  Identical values to
    init()'s buffer output (both derive from seed_salt)."""
    emb = make_emb(cfg)
    buffers: dict[str, Any] = {"emb": emb.init_buffers()}
    if not cfg.tie_embeddings and cfg.emb_method != "full":
        head = dataclasses.replace(make_emb(cfg), seed_salt=1)
        buffers["head"] = head.init_buffers()
    return buffers


def _init_xlstm_stack(key, cfg: ModelConfig):
    d = cfg.d_model

    def stacked_norm(*lead):
        return {"scale": jnp.ones((*lead, d), cfg.param_dtype)}

    if cfg.slstm_every:
        n_super = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        km, ks = jax.random.split(key)
        mkeys = jax.random.split(km, n_super * n_m).reshape(n_super, n_m, -1)
        ml = jax.vmap(jax.vmap(lambda k: xlstm_lib.init_mlstm(k, cfg)))(mkeys)
        skeys = jax.random.split(ks, n_super)
        sl = jax.vmap(lambda k: xlstm_lib.init_slstm(k, cfg))(skeys)
        norms = {"m": stacked_norm(n_super, n_m), "s": stacked_norm(n_super)}
        return {"mlstm": ml, "slstm": sl, "norms": norms}
    keys = jax.random.split(key, cfg.n_layers)
    ml = jax.vmap(lambda k: xlstm_lib.init_mlstm(k, cfg))(keys)
    return {"mlstm": ml, "norms": stacked_norm(cfg.n_layers)}


# --- sharding specs -----------------------------------------------------------


def param_specs(cfg: ModelConfig, *, dp: Any = "data", tp: str = "model", ep: str | None = "data"):
    """PartitionSpec pytree matching init()'s params.

    Strategy (TP = megatron, EP = experts over the data axis, FSDP-style
    extra sharding of big replicated tensors over data where free):
      * embeddings / head: d_model column sharded over TP (gathers partition
        trivially on the non-gathered dim — no vocab-dim collectives).
      * attention: head dim over TP;  MLP: ff dim over TP.
      * MoE experts: expert dim over EP, ff dim over TP.
      * norms / small vectors: replicated.
    """
    def attn_spec():
        s = {
            "wq": P(None, tp), "wk": P(None, tp), "wv": P(None, tp),
            "wo": P(tp, None),
        }
        if cfg.qkv_bias:
            s |= {"bq": P(tp), "bk": P(tp), "bv": P(tp)}
        if cfg.qk_norm:
            s |= {"q_norm": P(None), "k_norm": P(None)}
        return s

    def norm_spec():
        return {"scale": P(None)} | ({"bias": P(None)} if cfg.norm == "layernorm" else {})

    def mlp_spec():
        if cfg.act == "swiglu":
            return {"wi": P(None, tp), "wg": P(None, tp), "wo": P(tp, None)}
        return {"wi": P(None, tp), "bi": P(tp), "wo": P(tp, None), "bo": P(None)}

    def emb_spec():
        m = cfg.emb_method
        if m == "full":
            return {"table": P(None, tp)}
        if m == "cce":
            return {"tables": P(None, None, None, tp)}  # (c,2,k,dsub): dsub/TP
        if m in ("hash", "hemb"):
            return {"M": P(None, tp)}
        if m == "ce":
            return {"tables": P(None, None, tp)}
        if m == "robe":
            return {"flat": P(None)}
        if m == "dhe":
            return {"w1": P(None, tp), "b1": P(tp), "w2": P(tp, None), "b2": P(None),
                    "w3": P(None, tp), "b3": P(tp)}
        if m == "tt":
            return {"g1": P(None, None, None), "g2": P(None, None, tp, None), "g3": P(None, None, None)}
        raise ValueError(m)

    specs: dict[str, Any] = {"emb": emb_spec(), "ln_f": norm_spec()}

    if cfg.family == "xlstm":
        # heads are few (4) — shard the wide di / head_dim axes over TP
        m = {
            "up": P(None, tp), "wq": P(None, tp), "wk": P(None, tp),
            "wv": P(None, tp), "wi": P(tp, None), "wf": P(tp, None),
            "bf": P(None), "bi": P(None), "ln_scale": P(tp), "down": P(tp, None),
        }
        s = {
            "wx": P(None, tp), "wr": P(None, None, None), "b": P(tp),
            "ln_scale": P(None), "up": P(None, tp), "down": P(tp, None),
        }
        def add1(spec):
            return jax.tree.map(lambda ps: P(None, *ps), spec,
                                is_leaf=lambda x: isinstance(x, P))

        def add2(spec):
            return jax.tree.map(lambda ps: P(None, None, *ps), spec,
                                is_leaf=lambda x: isinstance(x, P))
        if cfg.slstm_every:
            specs["blocks"] = {
                "mlstm": add2(m), "slstm": add1(s),
                "norms": {"m": add2(norm_spec()), "s": add1(norm_spec())},
            }
        else:
            specs["blocks"] = {"mlstm": add1(m), "norms": add1(norm_spec())}
    else:
        layer: dict[str, Any] = {"ln1": norm_spec(), "attn": attn_spec()}
        if not cfg.parallel_block:
            layer["ln2"] = norm_spec()
        if cfg.family == "hybrid":
            layer["ssm"] = {
                "in_proj": P(None, tp), "conv": P(None, tp), "x_proj": P(tp, None),
                "dt_bias": P(tp), "A_log": P(tp, None), "D": P(tp),
                "out_proj": P(tp, None),
            }
            layer["attn_norm"] = P(None)
            layer["ssm_norm"] = P(None)
        if cfg.family == "moe":
            layer["moe"] = {
                "router": P(None, None),
                "wi": P(ep, None, tp), "wg": P(ep, None, tp), "wo": P(ep, tp, None),
            }
        elif cfg.d_ff:
            layer["mlp"] = mlp_spec()
        # prepend the stacked-layer axis
        specs["blocks"] = jax.tree.map(
            lambda ps: P(None, *ps), layer, is_leaf=lambda x: isinstance(x, P)
        )

    if cfg.tie_embeddings:
        pass
    elif cfg.emb_method == "full":
        specs["head"] = P(tp, None)
    else:
        specs["head"] = emb_spec()
    if cfg.family == "vlm":
        specs["patch_proj"] = P(None, tp)
    return specs


# --- embedding lookup / logits -----------------------------------------------


def embed(params, buffers, cfg: ModelConfig, tokens):
    """tokens (B, S) or (B, S, n_codebooks) -> (B, S, d)."""
    emb = make_emb(cfg)
    if cfg.n_codebooks:
        # offset each codebook into its own vocab range, sum embeddings
        offs = jnp.arange(cfg.n_codebooks, dtype=tokens.dtype) * cfg.vocab
        x = emb.lookup(params["emb"], buffers["emb"], tokens + offs).sum(axis=-2)
    else:
        x = emb.lookup(params["emb"], buffers["emb"], tokens)
    if cfg.emb_scale:
        x = x * math.sqrt(cfg.d_model)
    return x.astype(cfg.dtype)


def logits_fn(params, buffers, cfg: ModelConfig, h):
    """h (..., d) -> (..., vocab) or (..., n_codebooks, vocab)."""
    n_out = cfg.n_codebooks if cfg.n_codebooks else 1
    if cfg.tie_embeddings or cfg.emb_method != "full":
        tab = make_emb(cfg)
        key = "emb" if cfg.tie_embeddings else "head"
        out = tab.logits(params[key], buffers[key], h.astype(cfg.dtype))
    else:
        out = h.astype(cfg.dtype) @ params["head"].astype(cfg.dtype).T
    if cfg.n_codebooks:
        out = out.reshape(*h.shape[:-1], n_out, cfg.vocab)
    return out


# --- forward (training / prefill) ---------------------------------------------


def _block_train(p, cfg: ModelConfig, x, positions, freqs, *, decode_cache=None, axes=None):
    """One non-xlstm block over a full sequence.  Returns (x, aux, cache)."""
    aux = jnp.float32(0)
    h = L.apply_norm(p["ln1"], x)
    new_cache = None
    if decode_cache is None:
        attn = L.attention_train(p["attn"], cfg, h, positions, freqs, axes=axes)
    else:
        attn, ck, cv = L.attention_decode(
            p["attn"], cfg, h, positions, decode_cache["k"], decode_cache["v"],
            freqs, axes=axes,
        )
        new_cache = dict(decode_cache, k=ck, v=cv)
    if cfg.family == "hybrid":
        if decode_cache is None:
            s = ssm_lib.ssm_train(p["ssm"], cfg, h)
        else:
            s, hst, cst = ssm_lib.ssm_decode(
                p["ssm"], cfg, h, decode_cache["ssm"], decode_cache["conv"]
            )
            new_cache = dict(new_cache, ssm=hst, conv=cst)
        # hymba: mean of per-branch RMS-normed outputs
        attn = L.rms_norm_dim(attn, p["attn_norm"])
        s = L.rms_norm_dim(s, p["ssm_norm"])
        x = x + 0.5 * (attn + s)
    elif cfg.parallel_block:
        # command-r: attn and FFN both read ln1(x), summed into the residual
        x = x + attn + L.apply_mlp(p["mlp"], cfg, h)
        return x, aux, new_cache
    else:
        x = x + attn
    if cfg.family == "moe":
        h2 = L.apply_norm(p["ln2"], x)
        if decode_cache is None:
            moe_fn = {"sort": moe_lib.apply_moe_sort,
                      "sort_sm": moe_lib.apply_moe_sort_sm,
                      "einsum": moe_lib.apply_moe}[cfg.moe_impl]
            mo, aux = moe_fn(p["moe"], cfg, h2, group_size=cfg.moe_group)
        else:
            mo = moe_lib.apply_moe_decode(p["moe"], cfg, h2)
        x = x + mo
    elif cfg.d_ff and not cfg.parallel_block:
        x = x + L.apply_mlp(p["mlp"], cfg, L.apply_norm(p["ln2"], x))
    return x, aux, new_cache


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _constrain(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside a mesh context (CPU smoke tests)


def forward(params, buffers, cfg: ModelConfig, batch, *, batch_axes=("data",)):
    """Full-sequence forward.  batch: dict with "tokens" (B,S[,cb]) int32 and
    optional "patch_emb" (B, n_patches, d) for vlm.  Returns (logits, aux).
    """
    tokens = batch["tokens"]
    x = embed(params, buffers, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    if cfg.family == "vlm" and "patch_emb" in batch:
        pe = batch["patch_emb"].astype(cfg.dtype) @ params["patch_proj"].astype(cfg.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    x = _constrain(x, P(batch_axes, None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos_emb(positions, cfg.d_model).astype(x.dtype)
    freqs = L.rope_freqs(cfg)

    aux_total = jnp.float32(0)
    if cfg.family == "xlstm":
        x, _ = _xlstm_forward(params["blocks"], cfg, x)
    else:
        policy = _remat_policy(cfg)

        # under fsdp the 'model' axis belongs to the batch — attention runs
        # fully local, no head sharding
        fsdp = batch_axes and "model" in batch_axes
        axes = None if fsdp else (batch_axes, "model")
        # sequence-parallel residual (§Perf): the stream between blocks is
        # sharded over (dp, TP-on-seq); XLA then reduce-scatters the block
        # outputs and all-gathers before the next projection — same math,
        # half the bytes of the baseline's full all-reduces, and norms run
        # 1/|TP| as wide.
        res_spec = P(batch_axes, "model" if cfg.seq_shard and not fsdp else None, None)

        def body(carry, lp):
            x = carry
            x = _constrain(x, res_spec)
            x, aux, _ = _block_train(lp, cfg, x, positions, freqs, axes=axes)
            return x, aux

        if cfg.remat != "none":
            body = jax.checkpoint(body, policy=policy)
        if cfg.scan_layers:
            x, auxs = jax.lax.scan(body, x, params["blocks"])
            aux_total = auxs.sum()
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda t: t[i], params["blocks"])
                x, aux = body(x, lp)
                aux_total = aux_total + aux

    x = L.apply_norm(params["ln_f"], x)
    if cfg.family == "vlm" and "patch_emb" in batch:
        x = x[:, -tokens.shape[1]:]  # only text positions produce logits
    logits = logits_fn(params, buffers, cfg, x)
    vocab_axis = None if (batch_axes and "model" in batch_axes) else "model"
    logits = _constrain(
        logits,
        P(batch_axes, None, *([None] * (logits.ndim - 3)), vocab_axis),
    )
    return logits, aux_total


def _xlstm_forward(blocks, cfg: ModelConfig, x, *, collect_state: bool = False):
    """Returns (x, cache_pytree | None).  ``collect_state`` gathers the
    terminal recurrent state per block (prefill); training skips it to avoid
    materializing the (L,B,H,hd,hd) matrix memories."""
    policy = _remat_policy(cfg)

    def m_body(x, lp):
        h = L.apply_norm(lp["norm"], x)
        y, state = xlstm_lib.mlstm_train(lp["p"], cfg, h)
        out = state if collect_state else None
        return x + y, out

    if cfg.remat != "none" and not collect_state:
        m_body = jax.checkpoint(m_body, policy=policy)

    if cfg.slstm_every:
        def super_body(x, sp):
            x, mstates = jax.lax.scan(
                m_body, x, {"p": sp["mlstm"], "norm": sp["norms_m"]}
            )
            h = L.apply_norm(sp["norms_s"], x)
            y, sstate = xlstm_lib.slstm_seq(sp["slstm"], cfg, h)
            out = None
            if collect_state:
                C, n, m = mstates
                out = {"C": C, "n": n, "m": m, "s_c": sstate[0],
                       "s_n": sstate[1], "s_h": sstate[2], "s_m": sstate[3]}
            return x + y, out

        if cfg.remat != "none" and not collect_state:
            super_body = jax.checkpoint(super_body, policy=policy)
        stacked = {
            "mlstm": blocks["mlstm"], "slstm": blocks["slstm"],
            "norms_m": blocks["norms"]["m"], "norms_s": blocks["norms"]["s"],
        }
        x, cache = jax.lax.scan(super_body, x, stacked)
        return x, cache
    x, states = jax.lax.scan(m_body, x, {"p": blocks["mlstm"], "norm": blocks["norms"]})
    cache = None
    if collect_state:
        C, n, m = states
        cache = {"C": C, "n": n, "m": m}
    return x, cache


# --- loss ----------------------------------------------------------------------


def next_token_loss(params, buffers, cfg: ModelConfig, batch, *, batch_axes=("data",)):
    """Causal LM loss with next-token targets; aux-loss weighted in for MoE."""
    logits, aux = forward(params, buffers, cfg, batch, batch_axes=batch_axes)
    tokens = batch["tokens"]
    lg = logits[:, :-1]  # (B,S-1,V) or (B,S-1,cb,V)
    tg = tokens[:, 1:]  # (B,S-1) or (B,S-1,cb)
    lg = lg.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    # one-hot contraction partitions cleanly over a vocab-sharded last dim
    picked = jnp.sum(jax.nn.one_hot(tg, cfg.vocab, dtype=lg.dtype) * lg, axis=-1)
    ce = (logz - picked).mean()
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# --- decode --------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode cache pytree with a stacked (L, ...) leading dim for scan."""
    if cfg.family == "xlstm":
        return _init_xlstm_cache(cfg, batch)
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    Lc = cfg.n_layers
    cache = {
        "k": jnp.zeros((Lc, batch, S, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((Lc, batch, S, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    }
    if cfg.family == "hybrid":
        cache["ssm"] = jnp.zeros((Lc, batch, cfg.ssm_inner, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((Lc, batch, cfg.ssm_conv - 1, cfg.ssm_inner), jnp.float32)
    return cache


def _init_xlstm_cache(cfg: ModelConfig, batch: int):
    di = 2 * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    if cfg.slstm_every:
        n_super = cfg.n_layers // cfg.slstm_every
        n_m = cfg.slstm_every - 1
        return {
            "C": jnp.zeros((n_super, n_m, batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((n_super, n_m, batch, H, hd), jnp.float32),
            "m": jnp.full((n_super, n_m, batch, H), -jnp.inf, jnp.float32),
            "s_c": jnp.zeros((n_super, batch, cfg.d_model), jnp.float32),
            "s_n": jnp.zeros((n_super, batch, cfg.d_model), jnp.float32),
            "s_h": jnp.zeros((n_super, batch, cfg.d_model), jnp.float32),
            "s_m": jnp.full((n_super, batch, cfg.d_model), -jnp.inf, jnp.float32),
        }
    Lc = cfg.n_layers
    return {
        "C": jnp.zeros((Lc, batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((Lc, batch, H, hd), jnp.float32),
        "m": jnp.full((Lc, batch, H), -jnp.inf, jnp.float32),
    }


def cache_batch_axis(cfg: ModelConfig):
    """Pytree (matching init_cache) of the batch-dimension index per leaf —
    the serving engine scatters per-slot prefill results along it."""
    if cfg.family == "xlstm" and cfg.slstm_every:
        return {"C": 2, "n": 2, "m": 2, "s_c": 1, "s_n": 1, "s_h": 1, "s_m": 1}
    if cfg.family == "xlstm":
        return {"C": 1, "n": 1, "m": 1}
    base = {"k": 1, "v": 1}
    if cfg.family == "hybrid":
        base |= {"ssm": 1, "conv": 1}
    return base


def cache_specs(cfg: ModelConfig, *, batch_axes=("data",), tp="model"):
    dp = batch_axes
    if cfg.family == "xlstm":
        # few heads (4) — shard the (large) head_dim axis of the matrix
        # memory over TP, not the head axis
        if cfg.slstm_every:
            return {
                "C": P(None, None, dp, None, tp, None),
                "n": P(None, None, dp, None, tp),
                "m": P(None, None, dp, None),
                "s_c": P(None, dp, tp), "s_n": P(None, dp, tp),
                "s_h": P(None, dp, tp), "s_m": P(None, dp, tp),
            }
        return {
            "C": P(None, dp, None, tp, None),
            "n": P(None, dp, None, tp),
            "m": P(None, dp, None),
        }
    # KV-head counts (1..24) rarely divide the TP axis — shard head_dim
    # (always 64/128, divisible) instead
    spec = {
        "k": P(None, dp, None, None, tp),
        "v": P(None, dp, None, None, tp),
    }
    if cfg.family == "hybrid":
        spec["ssm"] = P(None, dp, tp, None)
        spec["conv"] = P(None, dp, None, tp)
    return spec


def decode_step(params, buffers, cfg: ModelConfig, tokens, pos, cache, *, batch_axes=("data",)):
    """One-token decode.  tokens (B,) or (B, cb); pos (B,) int32.
    Returns (logits (B, vocab[, cb]), new cache)."""
    x = embed(params, buffers, cfg, tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :])
    x = _constrain(x, P(batch_axes, None, None))
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos_emb(pos[:, None], cfg.d_model).astype(x.dtype)
    freqs = L.rope_freqs(cfg)

    if cfg.family == "xlstm":
        x, cache = _xlstm_decode(params["blocks"], cfg, x, cache)
    else:
        axes = (batch_axes, "model")

        def body(x, inp):
            lp, lc = inp
            x, _, nc = _block_train(lp, cfg, x, pos, freqs, decode_cache=lc, axes=axes)
            return x, nc

        x, cache = jax.lax.scan(body, x, (params["blocks"], cache))

    x = L.apply_norm(params["ln_f"], x)
    logits = logits_fn(params, buffers, cfg, x[:, 0])
    return logits, cache


def _xlstm_decode(blocks, cfg: ModelConfig, x, cache):
    def m_body(x, inp):
        lp, (C, n, m) = inp
        h = L.apply_norm(lp["norm"], x)
        y, (C, n, m) = xlstm_lib.mlstm_decode(lp["p"], cfg, h, (C, n, m))
        return x + y, (C, n, m)

    if cfg.slstm_every:
        def super_body(x, inp):
            sp, sc = inp
            x, (C, n, m) = jax.lax.scan(
                m_body, x,
                ({"p": sp["mlstm"], "norm": sp["norms_m"]}, (sc["C"], sc["n"], sc["m"])),
            )
            h = L.apply_norm(sp["norms_s"], x)
            st = (sc["s_c"], sc["s_n"], sc["s_h"], sc["s_m"])
            y, st = xlstm_lib.slstm_seq(sp["slstm"], cfg, h, st)
            nc = {"C": C, "n": n, "m": m, "s_c": st[0], "s_n": st[1], "s_h": st[2], "s_m": st[3]}
            return x + y, nc

        stacked = {
            "mlstm": blocks["mlstm"], "slstm": blocks["slstm"],
            "norms_m": blocks["norms"]["m"], "norms_s": blocks["norms"]["s"],
        }
        x, cache = jax.lax.scan(super_body, x, (stacked, cache))
        return x, cache
    x, (C, n, m) = jax.lax.scan(
        m_body, x,
        ({"p": blocks["mlstm"], "norm": blocks["norms"]}, (cache["C"], cache["n"], cache["m"])),
    )
    return x, {"C": C, "n": n, "m": m}


def prefill(params, buffers, cfg: ModelConfig, tokens, cache, *, batch_axes=("data",),
            last_idx=None):
    """Process a full prompt, fill the cache, return logits of last position.

    For attention families this recomputes k/v per layer and writes them into
    the cache (the standard prefill); for xlstm it runs the chunked forms and
    stores the terminal recurrent state.

    ``last_idx`` (traced scalar, default ``S - 1``) selects which position's
    logits come back — a serving engine that right-pads prompts into
    power-of-two length buckets passes the true last-token index so padding
    never changes the returned logits (causal masking keeps the positions
    before ``last_idx`` pad-blind; only attention families without a sliding
    window may pad, since recurrent/ring-buffer caches consume the pads).
    """
    B, S = tokens.shape[0], tokens.shape[1]

    def _last(x):
        if last_idx is None:
            return x[:, -1:]
        return jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)

    if cfg.family == "xlstm":
        # chunked-parallel forms with terminal-state collection: O(S·chunk)
        # prefill, after which decode continues from the recurrent states.
        x = embed(params, buffers, cfg, tokens)
        x = _constrain(x, P(batch_axes, None, None))
        x, cache = _xlstm_forward(params["blocks"], cfg, x, collect_state=True)
        x = L.apply_norm(params["ln_f"], _last(x))
        return logits_fn(params, buffers, cfg, x[:, 0]), cache
    freqs = L.rope_freqs(cfg)
    x = embed(params, buffers, cfg, tokens)
    x = _constrain(x, P(batch_axes, None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_pos_emb(positions, cfg.d_model).astype(x.dtype)

    def body(x, inp):
        lp, lc = inp
        h = L.apply_norm(lp["ln1"], x)
        q, k, v = L._project_qkv(lp["attn"], cfg, h)
        if cfg.pos_emb == "rope":
            q = L.apply_rope(q, positions, freqs)
            k = L.apply_rope(k, positions, freqs)
        Sc = lc["k"].shape[1]
        if cfg.sliding_window and Sc < S:
            # keep only the last window of k/v in the ring buffer
            ks_, vs_ = k[:, -Sc:], v[:, -Sc:]
            start = (S - Sc) % Sc
            idx = (jnp.arange(Sc) + start) % Sc
            nk = lc["k"].at[:, idx].set(ks_)
            nv = lc["v"].at[:, idx].set(vs_)
        else:
            nk = lc["k"].at[:, :S].set(k)
            nv = lc["v"].at[:, :S].set(v)
        mask = L.causal_mask(S, S, cfg.sliding_window)
        attn = L._sdpa(cfg, q, k, v, mask, axes=(batch_axes, "model"))
        attn = attn.reshape(B, S, cfg.q_dim) @ lp["attn"]["wo"].astype(x.dtype)
        nc = dict(lc, k=nk, v=nv)
        if cfg.family == "hybrid":
            s = ssm_lib.ssm_train(lp["ssm"], cfg, h)
            # also capture terminal ssm state for subsequent decode
            st, cv = _ssm_terminal_state(lp["ssm"], cfg, h)
            nc["ssm"], nc["conv"] = st, cv
            attn = L.rms_norm_dim(attn, lp["attn_norm"])
            s = L.rms_norm_dim(s, lp["ssm_norm"])
            x = x + 0.5 * (attn + s)
        elif cfg.parallel_block:
            x = x + attn + L.apply_mlp(lp["mlp"], cfg, h)
            return x, nc
        else:
            x = x + attn
        if cfg.family == "moe":
            h2 = L.apply_norm(lp["ln2"], x)
            moe_fn = (moe_lib.apply_moe_sort if cfg.moe_impl == "sort"
                      else moe_lib.apply_moe)
            mo, _ = moe_fn(lp["moe"], cfg, h2, group_size=cfg.moe_group)
            x = x + mo
        elif cfg.d_ff and not cfg.parallel_block:
            x = x + L.apply_mlp(lp["mlp"], cfg, L.apply_norm(lp["ln2"], x))
        return x, nc

    x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.apply_norm(params["ln_f"], _last(x))
    return logits_fn(params, buffers, cfg, x[:, 0]), cache


def _ssm_terminal_state(p, cfg: ModelConfig, x_in):
    """Terminal (ssm_state, conv_state) after consuming x_in — for prefill."""
    xz = x_in @ p["in_proj"].astype(x_in.dtype)
    dt, B_t, C_t, z, xc, conv_state = ssm_lib._selective_terms(p, cfg, xz)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    B_, S, di = xc.shape

    def step(h, inp):
        dt_t, B_tt, x_t = inp
        a = jnp.exp(dt_t[..., None] * A)
        bx = (dt_t * x_t)[..., None] * B_tt[..., None, :]
        return a * h + bx, None

    inputs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B_t.astype(jnp.float32), 1, 0),
        jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
    )
    h, _ = jax.lax.scan(step, jnp.zeros((B_, di, cfg.ssm_state), jnp.float32), inputs)
    return h, conv_state
