"""Common transformer layers — pure functions over param pytrees.

Conventions:
  * params are nested dicts of jnp arrays; init functions return params.
  * activations are (B, S, d) in ``cfg.dtype``; params kept in
    ``cfg.param_dtype`` and cast at use (mixed precision).
  * every function takes/returns explicit state — no globals, no classes
    with mutable state, so everything works under jit/scan/shard_map.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Any


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


# --- norms -------------------------------------------------------------------


def init_norm(cfg: ModelConfig, with_bias: bool | None = None):
    p = {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    if with_bias if with_bias is not None else cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return p


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_dim(x, scale, eps: float = 1e-6):
    """RMS-norm over the last dim with a given scale vector (qk_norm etc.)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --- positions ---------------------------------------------------------------


def rope_freqs(cfg: ModelConfig) -> jax.Array:
    half = cfg.head_dim // 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, freqs: jax.Array) -> jax.Array:
    """x (..., S, H, D) with positions (..., S) -> rotated x."""
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- attention ---------------------------------------------------------------


def init_attention(key, cfg: ModelConfig):
    d = cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": truncated_normal(kq, (d, cfg.q_dim), s, cfg.param_dtype),
        "wk": truncated_normal(kk, (d, cfg.kv_dim), s, cfg.param_dtype),
        "wv": truncated_normal(kv, (d, cfg.kv_dim), s, cfg.param_dtype),
        "wo": truncated_normal(ko, (cfg.q_dim, d), s / math.sqrt(2 * cfg.n_layers), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), cfg.param_dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x):
    """x (B, S, d) -> q (B,S,H,D), k/v (B,S,KVH,D)."""
    B, S, _ = x.shape
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm_dim(q, p["q_norm"])
        k = rms_norm_dim(k, p["k_norm"])
    return q, k, v


def _shard(x, spec):
    """Best-effort sharding constraint (no-op outside a mesh context)."""
    try:
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def _sdpa(cfg: ModelConfig, q, k, v, mask, axes=None) -> jax.Array:
    """Grouped-query scaled dot-product attention.

    q (B, Sq, H, D); k/v (B, Sk, KVH, D); mask broadcastable (B, 1, Sq, Sk)
    or (Sq, Sk).  Returns (B, Sq, H, D).

    ``axes`` = (dp_axes, tp_axis) mesh hints: the query-head dim is sharded
    over TP (GSPMD pads when H % tp != 0) so score tensors — the largest
    transients at long sequence — stay distributed.  KV is expanded to H
    heads per-use (fused, bandwidth stays KVH-sized from the cache).
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    if G > 1:  # expand GQA kv heads to the full head count
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if axes is not None:
        dp, tp = axes
        q = _shard(q, (dp, None, tp, None))
        k = _shard(k, (dp, None, tp, None))
        v = _shard(v, (dp, None, tp, None))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    if cfg.logit_softcap:
        cap = cfg.logit_softcap
        scores = jnp.tanh(scores / cap) * cap
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    if axes is not None:
        scores = _shard(scores, (axes[0], axes[1], None, None))
    if cfg.attn_impl == "dense_bf16p":
        # §Perf: keep row statistics in f32 but store the exp'd
        # probabilities in bf16 — the S^2 tensors after the max-subtraction
        # carry values in [0, 1] where bf16 is plenty; halves the dominant
        # HBM-traffic term of non-flash attention.
        m = jax.lax.stop_gradient(scores.max(axis=-1, keepdims=True))
        p = jnp.exp(scores - m).astype(jnp.bfloat16)
        ell = p.astype(jnp.float32).sum(axis=-1, keepdims=True)
        w = (p / ell.astype(jnp.bfloat16)).astype(q.dtype)
    else:
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return out


def causal_mask(Sq: int, Sk: int, sliding_window: int = 0, offset: int = 0):
    """(Sq, Sk) boolean mask. ``offset`` = absolute position of query 0
    relative to key 0 (for chunked prefill)."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Sk)[None, :]
    m = qi >= kj
    if sliding_window:
        m = m & (qi - kj < sliding_window)
    return m


def _sdpa_chunked(cfg: ModelConfig, q, k, v, axes=None) -> jax.Array:
    """Flash-style causal attention: lax.scan over KV chunks with an online
    softmax (running max m, denominator l, output accumulator) — the S x S
    score matrix NEVER exists in HBM; peak transient is (B, H, Sq, chunk).

    At seq 4096 this removes the dominant HBM-traffic term of the dense
    path (~10 TB/step/device on qwen3-14b — see EXPERIMENTS.md §Perf).
    Backward differentiates through the scan: per-chunk recompute, same
    O(S·chunk) working set.
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if axes is not None:
        dp, tp = axes
        q = _shard(q, (dp, None, tp, None))
        k = _shard(k, (dp, None, tp, None))
        v = _shard(v, (dp, None, tp, None))
    C = min(cfg.attn_chunk, Sq)
    assert Sq % C == 0, (Sq, C)
    nc = Sq // C
    scale = 1.0 / math.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,S,D)
    kc = jnp.moveaxis(k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B, H, nc, C, D), 2, 0)
    vc = jnp.moveaxis(v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B, H, nc, C, D), 2, 0)
    qpos = jnp.arange(Sq)

    def body(carry, inp):
        acc, m, ell = carry  # (B,H,S,D), (B,H,S), (B,H,S)
        j, kj, vj = inp  # chunk idx, (B,H,C,D), (B,H,C,D)
        kpos = j * C + jnp.arange(C)
        s = jnp.einsum("bhqd,bhcd->bhqc", qf, kj)  # (B,H,S,C)
        valid = qpos[:, None] >= kpos[None, :]
        if cfg.sliding_window:
            valid &= qpos[:, None] - kpos[None, :] < cfg.sliding_window
        s = jnp.where(valid[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))  # stays -inf if all masked
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        pexp = jnp.where(valid[None, None], jnp.exp(s - safe_m[..., None]), 0.0)
        ell = ell * alpha + pexp.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqc,bhcd->bhqd", pexp, vj)
        return (acc, m_new, ell), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    # remat the chunk body: the backward otherwise SAVES the per-chunk
    # exp-weights — which re-materializes the full S^2 traffic the chunked
    # form exists to avoid
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (acc, m, ell), _ = jax.lax.scan(body, (acc0, m0, l0), (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(ell, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,S,H,D)


def attention_train(p, cfg: ModelConfig, x, positions, freqs, axes=None) -> jax.Array:
    """Full-sequence causal attention (training / prefill)."""
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    S = x.shape[1]
    if cfg.attn_impl == "chunked" and S > cfg.attn_chunk:
        out = _sdpa_chunked(cfg, q, k, v, axes=axes)
    else:
        mask = causal_mask(S, S, cfg.sliding_window)
        out = _sdpa(cfg, q, k, v, mask, axes=axes)
    return out.reshape(*x.shape[:2], cfg.q_dim) @ p["wo"].astype(x.dtype)


def attention_decode(p, cfg: ModelConfig, x, pos, cache_k, cache_v, freqs, axes=None):
    """One-token decode with a KV cache.

    x (B, 1, d); pos (B,) int32 current positions; cache_k/v
    (B, S_max, KVH, D).  Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)  # q (B,1,H,D), k/v (B,1,KVH,D)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, pos[:, None], freqs)
        k = apply_rope(k, pos[:, None], freqs)
    S_max = cache_k.shape[1]  # = min(max_seq, window) for sliding-window
    ring = bool(cfg.sliding_window)
    slot = pos % S_max if ring else pos
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    kj = jnp.arange(S_max)[None, :]  # (1, S_max) cache slots
    if ring:
        # once the ring is full (pos >= S_max) every slot is live,
        # before that only slots up to the write point.
        valid = (kj <= slot[:, None]) | (pos[:, None] >= S_max)
    else:
        valid = kj <= pos[:, None]
    mask = valid[:, None, None, :]  # (B,1,1,S_max) over (B,h,q,k)
    out = _sdpa(cfg, q, cache_k, cache_v, mask, axes=axes)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


# --- MLP ---------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    if cfg.act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wi": truncated_normal(k1, (d, f), s, cfg.param_dtype),
            "wg": truncated_normal(k2, (d, f), s, cfg.param_dtype),
            "wo": truncated_normal(k3, (f, d), so, cfg.param_dtype),
        }
    k1, k2 = jax.random.split(key)
    return {
        "wi": truncated_normal(k1, (d, f), s, cfg.param_dtype),
        "bi": jnp.zeros((f,), cfg.param_dtype),
        "wo": truncated_normal(k2, (f, d), so, cfg.param_dtype),
        "bo": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def apply_mlp(p, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
        return h @ p["wo"].astype(dt)
    h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)
