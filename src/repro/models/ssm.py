"""Selective SSM (Mamba-style S6) — the SSM branch of hymba's hybrid heads.

Diagonal selective state space:  per channel i and state n,
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = <C_t, h_t> + D * x_t
with input-dependent dt_t, B_t, C_t (the "selective" part).

TPU adaptation: the recurrence is evaluated CHUNKWISE — an outer
``lax.scan`` over chunks carries the (B, di, ds) state, an inner
``associative_scan`` (log-depth) parallelizes within the chunk.  The
4-D decay/drive tensors (B, chunk, di, ds) only ever exist per chunk, so
peak memory is O(chunk·di·ds) instead of O(S·di·ds) — at hymba scale
(di=3200, ds=16, S=4096) that's 52 MB instead of 840 MB per sequence.

Decode carries the state explicitly — O(1) per token, which is what makes
hymba eligible for long_500k.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal

DEFAULT_CHUNK = 256


def init_ssm(key, cfg: ModelConfig):
    d, di, ds = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    # S4D-real init for A: -(1..ds) per state, shared log-param per channel
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * di), s, cfg.param_dtype),
        "conv": truncated_normal(ks[1], (cfg.ssm_conv, di), 1.0 / math.sqrt(cfg.ssm_conv), cfg.param_dtype),
        "x_proj": truncated_normal(ks[2], (di, 2 * ds + 1), 1.0 / math.sqrt(di), cfg.param_dtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.param_dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(cfg.param_dtype),
        "D": jnp.ones((di,), cfg.param_dtype),
        "out_proj": truncated_normal(
            ks[3], (di, d), 1.0 / math.sqrt(di) / math.sqrt(2 * cfg.n_layers), cfg.param_dtype
        ),
    }


def _selective_terms(p, cfg: ModelConfig, xz, conv_state=None):
    """Conv + selective projections (the cheap, di/ds-sized tensors).

    xz (B, S, 2*di) from in_proj.  Returns (dt (B,S,di) f32, B_t, C_t
    (B,S,ds), gate z, conv'd x, new_conv_state).
    """
    di, ds = cfg.ssm_inner, cfg.ssm_state
    x, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each
    K = cfg.ssm_conv
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    new_conv_state = xp[:, -(K - 1):] if K > 1 else None
    # depthwise causal conv via K shifted adds (K is tiny, typically 4)
    conv = sum(
        xp[:, i : i + x.shape[1]] * p["conv"].astype(x.dtype)[i]
        for i in range(K)
    )
    x = jax.nn.silu(conv)
    proj = x @ p["x_proj"].astype(x.dtype)  # (B, S, 2ds+1)
    B_t = proj[..., :ds]
    C_t = proj[..., ds : 2 * ds]
    # dt: shared per-token scalar + per-channel bias (dt_rank=1 variant)
    dt = jax.nn.softplus(
        proj[..., 2 * ds :].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, di)
    return dt, B_t, C_t, z, x, new_conv_state


def _combine(c1, c2):
    """Associative op for h_t = a_t h_{t-1} + b_t."""
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def ssm_train(p, cfg: ModelConfig, x_in, *, chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """Full-sequence chunked selective scan.  x_in (B, S, d) -> (B, S, d)."""
    B, S, _ = x_in.shape
    di, ds = cfg.ssm_inner, cfg.ssm_state
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xz = x_in @ p["in_proj"].astype(x_in.dtype)
    dt, B_t, C_t, z, x, _ = _selective_terms(p, cfg, xz)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds)
    xf = x.astype(jnp.float32)

    def to_chunks(t):  # (B, S, ...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    dt_c, B_c, C_c, x_c = map(to_chunks, (dt, B_t.astype(jnp.float32), C_t.astype(jnp.float32), xf))

    def body(h0, inp):
        dt_i, B_i, C_i, x_i = inp  # (B, chunk, ...)
        a = jnp.exp(dt_i[..., None] * A)  # (B, chunk, di, ds)
        bx = (dt_i * x_i)[..., None] * B_i[..., None, :]
        A_cum, h_loc = jax.lax.associative_scan(_combine, (a, bx), axis=1)
        h = h_loc + A_cum * h0[:, None]  # carry contribution
        y = jnp.einsum("bcdn,bcn->bcd", h, C_i)
        return h[:, -1], y

    h_last, y = jax.lax.scan(body, jnp.zeros((B, di, ds), jnp.float32), (dt_c, B_c, C_c, x_c))
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, di)
    y = y + xf * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_in.dtype)
    return y @ p["out_proj"].astype(x_in.dtype)


def ssm_decode(p, cfg: ModelConfig, x_in, ssm_state, conv_state):
    """One-token step.  x_in (B, 1, d); ssm_state (B, di, ds) f32;
    conv_state (B, K-1, di).  Returns (y (B,1,d), ssm_state, conv_state)."""
    xz = x_in @ p["in_proj"].astype(x_in.dtype)
    dt, B_t, C_t, z, x, new_conv = _selective_terms(p, cfg, xz, conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * A)  # (B, di, ds)
    bx = (dt[:, 0] * x[:, 0].astype(jnp.float32))[..., None] * B_t[:, 0].astype(jnp.float32)[:, None, :]
    h = a * ssm_state + bx  # (B, di, ds)
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0].astype(jnp.float32))
    y = y + x[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None].astype(x_in.dtype)
    return y @ p["out_proj"].astype(x_in.dtype), h, new_conv


def init_ssm_state(cfg: ModelConfig, batch: int):
    return (
        jnp.zeros((batch, cfg.ssm_inner, cfg.ssm_state), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_inner), jnp.float32),
    )
