"""Model configuration — one frozen dataclass covers every assigned
architecture family (dense / MoE / SSM-hybrid / xLSTM / VLM-backbone /
audio-backbone) plus the paper's own DLRM.

Configs are constructed in `repro/configs/<arch>.py`; reduced smoke-test
variants come from `.reduced()`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | xlstm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: int = 0  # 0 = full attention
    parallel_block: bool = False  # command-r style parallel attn+FFN
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    logit_softcap: float = 0.0
    emb_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    pos_emb: str = "rope"  # rope | sinusoidal | none

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid (hymba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # xLSTM
    slstm_every: int = 0  # 1 sLSTM per this many blocks (0 = none)

    # modality frontend stubs
    n_patches: int = 0  # vlm: number of precomputed patch embeddings
    n_codebooks: int = 0  # audio: EnCodec codebooks summed at input

    # embedding-table compression (the paper's technique)
    emb_method: str = "full"  # full | hash | hemb | ce | robe | dhe | tt | cce
    emb_budget: int = 0  # parameter budget for compressed tables (0=full)
    emb_c: int = 4  # CCE / CE columns
    tie_embeddings: bool = False

    # numerics
    dtype: Any = jnp.bfloat16  # activations/weights compute dtype
    param_dtype: Any = jnp.float32

    # distribution knobs (hillclimbed per arch in the perf pass)
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True
    train_microbatch: int = 16  # sequences per microbatch at train_4k
    moe_group: int = 2048  # MoE routing group size (tokens)

    # beyond-paper perf features (§Perf; default OFF = paper-faithful
    # baseline, enabled per-cell in the hillclimb)
    attn_impl: str = "dense"  # dense | chunked (flash-style online softmax)
    attn_chunk: int = 512  # kv-chunk for chunked attention
    seq_shard: bool = False  # sequence-parallel residual stream
    moe_impl: str = "einsum"  # einsum (GShard) | sort (MegaBlocks-style)
    zero2_grads: bool = False  # shard grad accumulators over the data axis
    parallelism: str = "tp"  # tp (megatron TP over 'model') | fsdp (batch
    #   over data x model, weights gathered per layer, grads reduce-scattered)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in sequence length (no KV cache)."""
        return self.family == "xlstm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (sliding-window or recurrent)."""
        return self.family in ("xlstm",) or (
            self.family == "hybrid" and self.sliding_window > 0
        )

    def n_params(self) -> int:
        """Total parameter count (analytic, matches init)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        if self.family == "xlstm":
            per = _xlstm_params(self)
            blocks = per * L
            attn = 0
            ffn = 0
        elif self.family == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            blocks = L * (attn + ffn + 2 * d)
        elif self.family == "hybrid":
            ssm = _ssm_params(self)
            ffn = 3 * d * self.d_ff
            blocks = L * (attn + ssm + ffn + 2 * d)
        else:
            ffn = (3 if self.act == "swiglu" else 2) * d * self.d_ff
            blocks = L * (attn + ffn + 2 * d)
        n_heads_out = self.n_codebooks if self.n_codebooks else 1
        emb = self.vocab * d * (1 if self.tie_embeddings else 1 + n_heads_out)
        if self.emb_method != "full" and self.emb_budget:
            emb = self.emb_budget * (1 if self.tie_embeddings else 1 + n_heads_out)
        return blocks + emb + d

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        ffn = self.top_k * 3 * d * self.d_ff + d * self.n_experts
        blocks = L * (attn + ffn + 2 * d)
        emb = self.vocab * d * 2
        return blocks + emb + d

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=257,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 4) if self.ssm_state else 0,
            n_patches=min(self.n_patches, 4) if self.n_patches else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            emb_budget=2048 if self.emb_method != "full" else 0,
            dtype=jnp.float32,
            remat="none",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _ssm_params(cfg: ModelConfig) -> int:
    di, ds = cfg.ssm_inner, cfg.ssm_state
    d = cfg.d_model
    # in_proj (x+z), conv, dt/B/C proj, A, D, out_proj
    return (
        d * 2 * di
        + cfg.ssm_conv * di
        + di * (2 * ds + 1)
        + di * ds
        + di
        + di * d
    )


def _xlstm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di = 2 * d  # mLSTM up-projection factor 2
    hd = di // cfg.n_heads
    # up/down proj + qkv + gates + conv + norm + skip
    m = 2 * d * di + di * d + 3 * di * hd * 0  # qkv are per-head, see xlstm.py
    m = 2 * d * di + 3 * di * di // cfg.n_heads * cfg.n_heads + 2 * di + di * d
    return m + 2 * d
