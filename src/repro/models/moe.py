"""GShard-style top-k Mixture-of-Experts FFN.

Dispatch/combine are expressed as einsums over a (groups, group_size, E, C)
one-hot capacity tensor — the SPMD-friendly formulation (no sorts/scatters,
so the XLA partitioner shards it cleanly: groups over the data axis, experts
over the EP axis, expert hidden dim over the model axis; the regrouping
between token- and expert-sharded layouts lowers to all-to-alls).

Tokens are routed within fixed-size groups (``group_size`` tokens) so the
capacity tensor is O(group_size · E · C) per group regardless of global
batch — the knob that keeps the dispatch tensor inside HBM at pod scale.

Returns the load-balancing aux loss (Shazeer/GShard: E · Σ_e f_e · p_e).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal, _shard

DEFAULT_GROUP = 2048
EP_AXIS = "data"  # expert-parallel axis of the production mesh


def _to_experts(t):
    """(G, E, C, d) group-sharded -> expert-sharded: forces the all-to-all
    that moves token slots to where the expert weights live, instead of
    letting the partitioner all-gather the (much larger) expert weights."""
    return _shard(t, (None, EP_AXIS, None, None))


def _to_groups(t):
    return _shard(t, (EP_AXIS, None, None, None))


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    return {
        "router": truncated_normal(kr, (d, E), s, cfg.param_dtype),
        "wi": truncated_normal(k1, (E, d, f), s, cfg.param_dtype),
        "wg": truncated_normal(k2, (E, d, f), s, cfg.param_dtype),
        "wo": truncated_normal(k3, (E, f, d), so, cfg.param_dtype),
    }


def apply_moe(p, cfg: ModelConfig, x, *, group_size: int = DEFAULT_GROUP):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    C = max(k, int(math.ceil(g * k / E * cfg.capacity_factor)))
    xg = x.reshape(G, g, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # capacity assignment: choice-major priority (all 1st choices first)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.float32)
    for j in range(k):
        mask = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.float32)  # (G,g,E)
        pos = jnp.cumsum(mask, axis=1) - mask + counts[:, None, :]  # (G,g,E)
        counts = counts + mask.sum(axis=1)
        keep = mask * (pos < C)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        combine = combine + gate_vals[..., j, None, None] * keep[..., None] * pos_oh

    dispatch = (combine > 0).astype(x.dtype)  # (G,g,E,C)
    # token -> expert slots; then all-to-all to the expert shards
    expert_in = _to_experts(jnp.einsum("gtec,gtd->gecd", dispatch, xg))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(x.dtype))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), _to_groups(expert_out))

    # GShard load-balance loss: E * sum_e (fraction routed to e) * (mean prob e)
    frac = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32).mean(axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return out.reshape(B, S, d), aux


def apply_moe_sort(p, cfg: ModelConfig, x, *, group_size: int = DEFAULT_GROUP):
    """Sort-based (MegaBlocks-style) routing — §Perf replacement for the
    GShard einsum dispatch.

    The einsum formulation multiplies every token by a (E x C)-slot one-hot
    — at 128 experts that dispatch matmul costs ~10x the expert FFN compute
    itself and materializes (g, E, C) tensors.  Here tokens are instead
    argsorted by expert id WITHIN each (shard-local) group, scattered into
    their (E, C, d) slots, and combined back with a segment-sum — integer
    routing, zero matmul overhead, same capacity/drop semantics (choice-
    rank priority rather than token-order priority on overflow).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    G = T // g
    C = max(k, int(math.ceil(g * k / E * cfg.capacity_factor)))
    xg = x.reshape(G, g, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def route_one(xi, gv, gi):
        # xi (g,d); gv/gi (g,k) — flatten CHOICE-MAJOR so the stable sort
        # gives overflow priority to 1st choices (matches apply_moe)
        flat_e = gi.T.reshape(g * k)
        flat_gate = gv.T.reshape(g * k)
        token_of = jnp.tile(jnp.arange(g), k)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(g * k) - starts[e_sorted]
        keep = pos < C
        slot = jnp.where(keep, e_sorted * C + pos, E * C)  # E*C = drop bin
        tok_sorted = token_of[order]
        buf = jnp.zeros((E * C + 1, d), xi.dtype).at[slot].add(xi[tok_sorted])
        expert_in = buf[: E * C].reshape(E, C, d)
        return expert_in, (slot, keep, flat_gate[order], tok_sorted)

    expert_in, (slot, keep, gate_sorted, tok_sorted) = jax.vmap(route_one)(
        xg, gate_vals, gate_idx
    )
    # (G,E,C,d): groups live on the EP shards; move slots to the experts
    expert_in = _to_experts(expert_in)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(x.dtype))
    expert_out = _to_groups(jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype)))

    def combine_one(eo, slot, keep, gate, tok):
        flat_out = eo.reshape(E * C, d)
        picked = jnp.where(
            keep[:, None], flat_out[jnp.clip(slot, 0, E * C - 1)], 0.0
        ) * gate[:, None].astype(eo.dtype)
        return jax.ops.segment_sum(picked, tok, num_segments=g)

    out = jax.vmap(combine_one)(expert_out, slot, keep, gate_sorted, tok_sorted)

    frac = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32).mean(axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return out.reshape(B, S, d), aux


def apply_moe_sort_sm(p, cfg: ModelConfig, x, *, group_size: int = DEFAULT_GROUP,
                      axes=("data", "model")):
    """Sort routing + shard_map expert FFN with MANUAL collective placement
    (§Perf).  The GSPMD version psums the (G,E,C,d) slot tensor over TP —
    slots are ~top_k·cf times the token count, so that all-reduce dominates
    the MoE step.  Since combine is linear, the partial (f-shard) expert
    outputs can be combined into TOKEN space first and psummed there:

        a2a(slots->experts) . local FFN . a2a(experts->slots)
          . local combine . psum_tp(tokens)

    cutting the dominant collective by ~top_k·cf·(bytes f32/bf16) ~= 10-20x.
    Falls back to `apply_moe_sort` when no mesh is active (CPU tests).
    """
    ep, tp = axes
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or ep not in (mesh.axis_names or ()):
            return apply_moe_sort(p, cfg, x, group_size=group_size)
        n_ep = mesh.shape[ep]
    except Exception:
        return apply_moe_sort(p, cfg, x, group_size=group_size)

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    if G % n_ep or E % n_ep:
        return apply_moe_sort(p, cfg, x, group_size=group_size)
    C = max(k, int(math.ceil(g * k / E * cfg.capacity_factor)))
    xg = x.reshape(G, g, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def route_one(xi, gv, gi):
        flat_e = gi.T.reshape(g * k)
        flat_gate = gv.T.reshape(g * k)
        token_of = jnp.tile(jnp.arange(g), k)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(g * k) - starts[e_sorted]
        keep = pos < C
        slot = jnp.where(keep, e_sorted * C + pos, E * C)
        tok_sorted = token_of[order]
        buf = jnp.zeros((E * C + 1, d), xi.dtype).at[slot].add(xi[tok_sorted])
        return buf[: E * C].reshape(E, C, d), slot, keep, flat_gate[order], tok_sorted

    expert_in, slot, keep, gate_s, tok_s = jax.vmap(route_one)(xg, gate_vals, gate_idx)

    from jax.sharding import PartitionSpec as P

    def ffn_combine(ein, slot, keep, gate, tok, wg, wi, wo):
        # local shapes: ein (G/n, E, C, d); weights (E/n, d, f/tp)
        ein = jax.lax.all_to_all(ein, ep, split_axis=1, concat_axis=0,
                                 tiled=True)  # -> (G, E/n, C, d)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, wg.astype(ein.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", ein, wi.astype(ein.dtype))
        part = jnp.einsum("gecf,efd->gecd", h, wo.astype(ein.dtype))
        part = jax.lax.all_to_all(part, ep, split_axis=0, concat_axis=1,
                                  tiled=True)  # -> (G/n, E, C, d) f-partial

        def combine_one(eo, slot, keep, gate, tok):
            flat = eo.reshape(E * C, d)
            picked = jnp.where(
                keep[:, None], flat[jnp.clip(slot, 0, E * C - 1)], 0.0
            ) * gate[:, None].astype(eo.dtype)
            return jax.ops.segment_sum(picked, tok, num_segments=g)

        out = jax.vmap(combine_one)(part, slot, keep, gate, tok)  # (G/n, g, d)
        return jax.lax.psum(out.astype(jnp.float32), tp).astype(out.dtype)

    out = compat.shard_map(
        ffn_combine,
        mesh=mesh,
        in_specs=(P(ep), P(ep), P(ep), P(ep), P(ep), P(ep, None, tp),
                  P(ep, None, tp), P(ep, tp, None)),
        out_specs=P(ep),
    )(expert_in, slot, keep, gate_s.astype(x.dtype), tok_s,
      p["wg"], p["wi"], p["wo"])

    frac = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32).mean(axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return out.reshape(B, S, d), aux


def apply_moe_decode(p, cfg: ModelConfig, x):
    """Decode path (few tokens): dense masked evaluation.

    x (B, 1, d) -> (B, 1, d).  Every expert runs on every token, masked by
    the (renormalized) top-k gates.  For single-token decode with a real
    batch, nearly every expert is hit by some token anyway (B·k >> E), so
    weight traffic — the decode bottleneck — is identical to gather-based
    routing, while the dense einsums shard cleanly under SPMD (experts on
    the EP axis, psum to combine).  No capacity tensor, no token dropping.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(B * S, d)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # dense (T, E) gate matrix, zero outside the top-k
    gates = jnp.zeros_like(probs)
    t_idx = jnp.arange(xf.shape[0])[:, None]
    gates = gates.at[t_idx, gate_idx].set(gate_vals)  # (T,E)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wg"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->tef", xf, p["wi"].astype(x.dtype))
    y = jnp.einsum("tef,efd->ted", h, p["wo"].astype(x.dtype))  # (T,E,d)
    out = jnp.einsum("te,ted->td", gates.astype(x.dtype), y)
    return out.reshape(B, S, d)
