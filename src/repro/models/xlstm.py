"""xLSTM blocks (Beck et al. 2024) — mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

xlstm-1.3b stacks 48 blocks with 1 sLSTM per ``slstm_every`` (=8) mLSTM
blocks, i.e. the xLSTM[7:1] ratio.  d_ff=0: there is no separate FFN — the
mLSTM block carries its own 2x up-projection, sLSTM a gated FFN.

mLSTM forms implemented:
  * train/prefill: stabilized chunkwise-quadratic attention-like form —
    D_ij = exp(sum_{l=j+1..i} logsig(f_l) + log i_j - m_i); h = (Q K^T * D) V
    evaluated per chunk with a running (C, n, m) inter-chunk state, so cost
    is O(S * chunk * d) not O(S^2 d).
  * decode: recurrent (C, n, m) state update — O(1) per token.  This is why
    xlstm runs long_500k with no KV cache at all.

sLSTM: scalar-memory recurrence with exponential gating, evaluated with a
``lax.scan`` over time (inherently sequential; kept narrow — head_dim-sized
ops only).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal

MLSTM_CHUNK = 256


# --- mLSTM -------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d  # fixed 2x up-projection (xLSTM paper)
    H = cfg.n_heads
    hd = di // H
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(hd)
    return {
        "up": truncated_normal(ks[0], (d, 2 * di), s, cfg.param_dtype),  # x & gate z
        "wq": truncated_normal(ks[1], (di, di), si, cfg.param_dtype),
        "wk": truncated_normal(ks[2], (di, di), si, cfg.param_dtype),
        "wv": truncated_normal(ks[3], (di, di), si, cfg.param_dtype),
        "wi": truncated_normal(ks[4], (di, H), s, cfg.param_dtype),  # input gate
        "wf": truncated_normal(ks[5], (di, H), s, cfg.param_dtype),  # forget gate
        "bf": jnp.full((H,), 3.0, cfg.param_dtype),  # forget-bias init (remember)
        "bi": jnp.zeros((H,), cfg.param_dtype),
        "ln_scale": jnp.ones((di,), cfg.param_dtype),
        "down": truncated_normal(
            ks[6], (di, d), 1.0 / math.sqrt(di) / math.sqrt(2 * cfg.n_layers), cfg.param_dtype
        ),
    }


def _mlstm_qkvgates(p, cfg: ModelConfig, x):
    """x (B, S, d) -> q,k,v (B,S,H,hd), log-gates i,f (B,S,H), gate z (B,S,di)."""
    B, S, d = x.shape
    di = 2 * d
    H = cfg.n_heads
    hd = di // H
    up = x @ p["up"].astype(x.dtype)  # (B,S,2di)
    xm, z = jnp.split(up, 2, axis=-1)
    q = (xm @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xm @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (xm @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    ig = (xm @ p["wi"].astype(x.dtype)).astype(jnp.float32) + p["bi"].astype(jnp.float32)
    fg = (xm @ p["wf"].astype(x.dtype)).astype(jnp.float32) + p["bf"].astype(jnp.float32)
    return q, k, v, ig, fg, z


def _headnorm(h, scale, eps=1e-6):
    """Per-head RMS norm then flatten heads (the xLSTM 'output norm')."""
    B, S, H, hd = h.shape
    var = (h * h).mean(-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h.reshape(B, S, H * hd) * scale.astype(h.dtype))


def mlstm_train(p, cfg: ModelConfig, x_in, *, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel stabilized mLSTM.  x_in (B,S,d) -> (B,S,d)."""
    B, S, d = x_in.shape
    q, k, v, ig, fg, z = _mlstm_qkvgates(p, cfg, x_in)
    H = q.shape[2]
    hd = q.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    logf = jax.nn.log_sigmoid(fg)  # (B,S,H)

    def to_chunks(t):  # (B,S,...) -> (nc,B,chunk,...)
        return jnp.moveaxis(t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    qc, kc, vc, ic, lfc = map(to_chunks, (q, k, v, ig, logf))

    def body(carry, inp):
        C, n, m = carry  # C (B,H,hd,hd); n (B,H,hd); m (B,H)
        qi, ki, vi, ii, lfi = inp  # (B, chunk, ...)
        qf, kf, vf = (t.astype(jnp.float32) for t in (qi, ki, vi))
        csum = jnp.cumsum(lfi, axis=1)  # (B,chunk,H) inclusive logf cumsum
        # intra gate matrix: sum_{l=j+1..t} logf_l + log i_j = csum_t - csum_j + i_j
        dmat = csum[:, :, None, :] - csum[:, None, :, :]  # (B,t,j,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        logD = jnp.where(tri[None, :, :, None], dmat + ii[:, None, :, :], -jnp.inf)
        # per-query stabilizer: max over intra gates and the carried state's m
        m_intra = jnp.max(logD, axis=2)  # (B,chunk,H)
        m_inter = m[:, None] + csum  # (B,chunk,H)
        m_new = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(logD - m_new[:, :, None, :])  # (B,t,j,H)
        scores = jnp.einsum("bthd,bjhd->btjh", qf, kf)
        w = scores * D  # w[t,j] = (q_t . k_j) * gate
        # numerator: intra attention-like term + carried-state readout
        inter_scale = jnp.exp(m_inter - m_new)  # (B,chunk,H)
        h_num = jnp.einsum("btjh,bjhd->bthd", w, vf)
        h_num += jnp.einsum("bthd,bhde->bthe", qf, C) * inter_scale[..., None]
        # denominator: q . n_total = sum_j w[t,j] + inter part
        qn = w.sum(axis=2) + jnp.einsum("bthd,bhd->bth", qf, n) * inter_scale
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        h_out = h_num / den[..., None]
        # ---- carry the state to the end of the chunk ----
        tot = csum[:, -1]  # (B,H) total decay across the chunk
        decay_to_end = tot[:, None, :] - csum  # sum_{l=j+1..end} logf_l
        m_next = jnp.maximum(m + tot, jnp.max(ii + decay_to_end, axis=1))
        scale_old = jnp.exp(m + tot - m_next)  # (B,H)
        gate = jnp.exp(decay_to_end + ii - m_next[:, None])  # (B,chunk,H)
        C_new = C * scale_old[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", gate, kf, vf
        )
        n_new = n * scale_old[..., None] + jnp.einsum("bjh,bjhd->bhd", gate, kf)
        return (C_new, n_new, m_next), h_out

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    state, hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)  # (B,S,H,hd)
    out = _headnorm(h.astype(x_in.dtype), p["ln_scale"])
    out = out * jax.nn.silu(z)
    return out @ p["down"].astype(x_in.dtype), state


def mlstm_decode(p, cfg: ModelConfig, x_in, state):
    """One-token recurrent mLSTM step.  state = (C (B,H,hd,hd), n, m)."""
    q, k, v, ig, fg, z = _mlstm_qkvgates(p, cfg, x_in)  # S=1
    C, n, m = state
    q1, k1, v1 = q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    i1, lf1 = ig[:, 0], jax.nn.log_sigmoid(fg[:, 0])  # (B,H)
    m_new = jnp.maximum(lf1 + m, i1)
    C = C * jnp.exp(lf1 + m - m_new)[..., None, None] + jnp.exp(i1 - m_new)[
        ..., None, None
    ] * jnp.einsum("bhd,bhe->bhde", k1, v1)
    n = n * jnp.exp(lf1 + m - m_new)[..., None] + jnp.exp(i1 - m_new)[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n)), jnp.exp(-m_new))
    h = (num / den[..., None])[:, None]  # (B,1,H,hd)
    out = _headnorm(h.astype(x_in.dtype), p["ln_scale"])
    out = out * jax.nn.silu(z)
    return out @ p["down"].astype(x_in.dtype), (C, n, m_new)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    di = 2 * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    return (
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), -jnp.inf, jnp.float32),
    )


# --- sLSTM -------------------------------------------------------------------


def slstm_ffn_dim(cfg: ModelConfig) -> int:
    """~4/3·d gated-FFN width, rounded up to a TP-shardable multiple of 128."""
    return ((4 * cfg.d_model // 3) + 127) // 128 * 128


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    f = slstm_ffn_dim(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        # z/i/f/o pre-activations from input + per-head recurrent weights
        "wx": truncated_normal(ks[0], (d, 4 * d), s, cfg.param_dtype),
        "wr": truncated_normal(ks[1], (H, hd, 4 * hd), 1.0 / math.sqrt(hd), cfg.param_dtype),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(cfg.param_dtype),
        "ln_scale": jnp.ones((d,), cfg.param_dtype),
        "up": truncated_normal(ks[2], (d, 2 * f), s, cfg.param_dtype),
        "down": truncated_normal(
            ks[3], (f, d), 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers), cfg.param_dtype
        ),
    }


def slstm_seq(p, cfg: ModelConfig, x_in, state=None):
    """Sequential sLSTM over a whole sequence.  x_in (B,S,d) -> (B,S,d).

    state (optional) = (c, n, h, m) each (B, d) f32 — pass for decode
    continuation; returned as second output.
    """
    B, S, d = x_in.shape
    H = cfg.n_heads
    hd = d // H
    zx = x_in @ p["wx"].astype(x_in.dtype) + p["b"].astype(x_in.dtype)  # (B,S,4d)
    if state is None:
        state = init_slstm_state(cfg, B)

    wr = p["wr"].astype(jnp.float32)

    def step(carry, zt):
        c, n, h, m = carry  # (B, d) f32 each
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, wr).reshape(B, 4 * d)
        za = zt.astype(jnp.float32) + rec
        zi, ii, ff, oo = jnp.split(za, 4, axis=-1)
        zv = jnp.tanh(zi)
        o = jax.nn.sigmoid(oo)
        logf = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(logf + m, ii)
        i_s = jnp.exp(ii - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * zv
        n = f_s * n + i_s
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    zx_t = jnp.moveaxis(zx, 1, 0)  # (S,B,4d)
    state, hs = jax.lax.scan(step, state, zx_t)
    h = jnp.moveaxis(hs, 0, 1).astype(x_in.dtype)  # (B,S,d)
    # output norm + gated FFN (xLSTM post-up-projection, factor 4/3)
    var = (h.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    h = (h * jax.lax.rsqrt(var + 1e-6).astype(h.dtype)) * p["ln_scale"].astype(h.dtype)
    up = h @ p["up"].astype(h.dtype)
    a, g = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * g) @ p["down"].astype(h.dtype), state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.full((batch, d), -jnp.inf, jnp.float32))
