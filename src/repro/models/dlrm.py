"""DLRM (Naumov et al. 2019) — the paper's backbone recommendation model.

13 dense features -> bottom MLP; 26 categorical features -> one embedding
table each (every table independently compressible by any method in the
unified sketch framework, incl. CCE); pairwise dot-product interaction;
top MLP -> 1 logit; Binary Cross-Entropy loss.  Matches the open-source
DLRM benchmark configuration the paper trains on Criteo.

The 26 tables live behind an ``EmbeddingCollection`` (core/collection.py):
fuse-compatible tables are stacked into grouped supertables and the whole
forward issues O(n_groups) heavy lookups — for the compressed Criteo
config that is ONE universal supertable launch for ALL 26 tables (CCE +
small full tables share the fused Pallas ``cce_lookup``; DESIGN.md §6),
instead of 26 independent gathers.  ``params["emb"]``/``buffers["emb"]``
are in the collection's grouped layout; use
``cfg.collection.feature_params`` / ``feature_buffers`` for a per-feature
view, and ``checkpoint_migrations(cfg)`` to restore pre-collection
checkpoints.  A host-translating pipeline (``data.translate``) may ship
``batch["rows"]`` instead of raw ids — the device then never gathers the
pointer tables.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import embeddings as emb_lib
from repro.core.collection import (
    EmbeddingCollection,
    grouped_layout_migration,
    legacy_layout_migration,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    vocab_sizes: tuple[int, ...]  # one per categorical feature (26 on Criteo)
    n_dense: int = 13
    emb_dim: int = 16
    bottom_mlp: tuple[int, ...] = (512, 256, 64, 16)
    top_mlp: tuple[int, ...] = (512, 256, 1)
    # per-table compression: method + cap on the LARGEST table's params
    emb_method: str = "full"
    emb_param_cap: int = 0  # 0 = uncapped
    emb_c: int = 4
    # CCE transition: what happens to per-row optimizer moments when
    # cluster() rewrites a table ("remap" | "reset" | "keep" — see
    # repro.optim.remap), and the id-chunk size for the full-vocab
    # assignment pass (0 = unchunked; multi-million-row tables should
    # stream so (c, d1, dsub) never materializes at once)
    emb_opt_policy: str = "remap"
    emb_cluster_chunk: int = 1 << 18
    # route grouped CCE lookups through the fused Pallas kernel.  None =
    # auto: Mosaic on TPU, interpret mode on CPU, jnp gather path on GPU
    # (the kernel is TPU-shaped; GPUs have fast native gathers).  CPU
    # interpret mode is SLOWER than the jnp path — it stays the default
    # deliberately so training exercises the exact kernel that ships to
    # TPU (this container's validation contract); set False for CPU speed.
    emb_use_kernel: bool | None = None
    # collection grouping mode: "univ" (universal fusion — ONE heavy
    # launch for the whole embedding stack on the compressed Criteo
    # config), "group" (the pre-universal per-signature grouping) or
    # "loop" (per-feature lookups).  The non-default modes exist as
    # benchmark baselines (bench_kernels --fuse) and escape hatches.
    emb_fuse: str = "univ"
    # model-parallel shard count the supertable codebook axis must divide
    # by: sharded configs set it to the model mesh size (k_pad rounds up;
    # the pad rows are unreachable and stay zero, so layouts with
    # different emb_k_multiple checkpoint-restore into each other
    # bit-exactly — see checkpoint_migrations)
    emb_k_multiple: int = 1
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def _build_table(self, i: int):
        v = self.vocab_sizes[i]
        cap = self.emb_param_cap
        if self.emb_method == "full" or not cap or v * self.emb_dim <= cap:
            # small tables stay uncompressed (paper §Repro: full table for
            # small features, compressed for the big ones)
            return emb_lib.make_table("full", v, self.emb_dim, dtype=self.dtype)
        return emb_lib.make_table(
            self.emb_method, v, self.emb_dim, budget=cap, c=self.emb_c,
            dtype=self.dtype, seed_salt=i,
        )

    @functools.cached_property
    def collection(self) -> EmbeddingCollection:
        """The grouped-table view — built ONCE per config (forward and the
        transition used to reconstruct every table object on every call)."""
        return EmbeddingCollection.build(
            tuple(self._build_table(i) for i in range(self.n_sparse)),
            mode=self.emb_fuse,
            k_multiple=self.emb_k_multiple,
        )

    def table(self, i: int):
        return self.collection.tables[i]

    def n_emb_params(self) -> int:
        return sum(self.table(i).n_params for i in range(self.n_sparse))

    def compression(self) -> float:
        full = sum(v * self.emb_dim for v in self.vocab_sizes)
        return full / max(1, self.n_emb_params())


def _init_mlp(key, sizes: Sequence[int], dtype):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        params.append({
            "w": (jax.random.normal(k, (a, b)) / math.sqrt(a)).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        })
    return params


def _apply_mlp(params, x, final_act: bool = False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init(key, cfg: DLRMConfig):
    kb, kt, ke = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "bottom": _init_mlp(kb, (cfg.n_dense, *cfg.bottom_mlp), cfg.dtype),
    }
    buffers: dict[str, Any] = {}
    # grouped layout: one stacked supertable per fuse-compatible group
    # (slices bit-identical to the legacy per-table init)
    params["emb"], buffers["emb"] = cfg.collection.init(ke)
    n_pairs = (cfg.n_sparse + 1) * cfg.n_sparse // 2
    top_in = cfg.bottom_mlp[-1] + n_pairs
    params["top"] = _init_mlp(kt, (top_in, *cfg.top_mlp), cfg.dtype)
    return params, buffers


def interact(params, cfg: DLRMConfig, dense, emb):
    """Everything downstream of the embedding lookup: bottom MLP, pairwise
    dot interaction, top MLP -> (B,) logits.

    ``params`` needs only the ``bottom``/``top`` subtrees.  Split out of
    ``forward`` so the serve engine (serve/dlrm.py) can feed embeddings
    assembled from its hot cache through the identical math — a cache hit
    and a supertable lookup produce bit-identical logits."""
    dense = dense.astype(cfg.dtype)
    x0 = _apply_mlp(params["bottom"], dense, final_act=True)  # (B, emb_dim)
    V = jnp.concatenate([x0[:, None, :], emb.astype(cfg.dtype)], axis=1)
    # pairwise dot interactions (upper triangle, no self)
    inter = jnp.einsum("bie,bje->bij", V, V)
    iu, ju = jnp.triu_indices(V.shape[1], k=1)
    feats = jnp.concatenate([x0, inter[:, iu, ju]], axis=-1)
    return _apply_mlp(params["top"], feats)[:, 0]


def forward(params, buffers, cfg: DLRMConfig, batch, *, mesh=None,
            model_axis=None, batch_axes=None):
    """batch: {"dense": (B, 13) f32, "sparse": (B, 26) int32} -> (B,) logits.

    A host-translating input pipeline (``data.translate``, DESIGN.md §4)
    ships ``batch["rows"]`` — pre-translated codebook row indices —
    instead of (or alongside) ``batch["sparse"]``: the device program
    then never gathers the (c, d1) pointer tables.

    ``mesh``/``model_axis``/``batch_axes`` switch the supertable lookup
    to the model-parallel shard_map path (the slab k-sharded over
    ``model_axis``, ids routed by all-to-all; ``batch_axes`` is the
    FULL batch layout including the model axis —
    ``launch.mesh.all_batch_axes``).  MLPs stay data-parallel under
    jit's normal sharding propagation."""
    use_kernel = cfg.emb_use_kernel
    if use_kernel is None:
        use_kernel = jax.default_backend() in ("tpu", "cpu")
    emb = cfg.collection.lookup_all(
        params["emb"], buffers["emb"], batch.get("sparse"),
        use_kernel=use_kernel, rows=batch.get("rows"),
        mesh=mesh, model_axis=model_axis, batch_axes=batch_axes,
    )  # (B, n_sparse, emb_dim) in O(n_groups) heavy lookups (ONE on Criteo)
    return interact(params, cfg, batch["dense"], emb)


def bce_loss(params, buffers, cfg: DLRMConfig, batch, *, mesh=None,
             model_axis=None, batch_axes=None):
    logits = forward(params, buffers, cfg, batch, mesh=mesh,
                     model_axis=model_axis, batch_axes=batch_axes)
    y = batch["label"].astype(jnp.float32)
    lg = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg))))


def cluster_tables(key, params, buffers, cfg: DLRMConfig, opt=None, *,
                   id_counts=None, policy: str | None = None,
                   chunk_size: int | None = None,
                   use_kernel: bool | None = None,
                   max_points_per_centroid: int = 256,
                   mesh=None, shard_axis: str | None = None):
    """Run the CCE clustering transition on every CCE table (the training
    callback — Alg. 3 `Cluster`), group-wise through the collection.

    With ``opt`` (the optimizer state, e.g. from ``TrainState.opt``), the
    per-row moments of every transitioned table are carried through the new
    cluster assignments per ``policy`` (default ``cfg.emb_opt_policy``) and
    the updated state is returned as a third element — the 4-arg
    ``Trainer`` cluster protocol.  Without it, returns (params, buffers)
    as before (moments go stale; kept for ablation/legacy callers).

    ``id_counts`` (per-feature histograms, e.g. ``IdFrequencyTracker.counts``)
    runs each table's k-means count-WEIGHTED on the OBSERVED ids — the
    paper's epoch-boundary distribution with zero sampling variance — and
    weights the moment remap the same way.  Without it the sample is
    uniform over the vocab, which on Zipf data lets the never-trained tail
    dominate the centroids.
    """
    from repro.optim.remap import remap_opt_state
    from repro.train.transition import transition_collection

    policy = policy or cfg.emb_opt_policy
    if chunk_size is None:
        chunk_size = cfg.emb_cluster_chunk or None
    new_emb_p, new_emb_b, update_emb = transition_collection(
        cfg.collection, key, params["emb"], buffers["emb"],
        id_counts=id_counts, policy=policy, chunk_size=chunk_size,
        use_kernel=use_kernel, max_points_per_centroid=max_points_per_centroid,
        mesh=mesh, shard_axis=shard_axis,
    )
    new_params = dict(params, emb=new_emb_p)
    new_buffers = dict(buffers, emb=new_emb_b)
    if opt is None:
        return new_params, new_buffers

    def update_moments(moments, _slot):
        return dict(moments, emb=update_emb(moments["emb"]))

    return new_params, new_buffers, remap_opt_state(
        opt, update_moments, policy=policy
    )


def make_id_tracker(cfg: DLRMConfig, stream=None, *, key: str = "sparse"):
    """The frequency tracker the Trainer/transition pair consumes.

    ``stream=None`` returns the DENSE reference tracker (one int64 per
    vocab row — exact, but a second full-vocab array per feature).  A
    ``repro.stream.StreamConfig`` returns the sketch-backed tracker at
    vocab-independent memory, wired through the collection: only the
    features that actually transition (the CCE tables) carry sketches —
    full/loop tables never cluster, so their histograms would be dead
    weight.  Either tracker plugs into ``Trainer(id_tracker=...)`` and
    ``cluster_tables(id_counts=tracker.counts)`` unchanged."""
    from repro.core.cce import CCE
    from repro.stream import IdFrequencyTracker, SketchFrequencyTracker

    if stream is None:
        return IdFrequencyTracker(cfg.vocab_sizes, key=key)
    tracked = tuple(
        i for i, t in enumerate(cfg.collection.tables) if isinstance(t, CCE)
    )
    return SketchFrequencyTracker(
        cfg.vocab_sizes, stream, tracked=tracked, key=key
    )


#: ``k_multiple`` layouts every DLRM trainer can restore checkpoints
#: FROM (and write checkpoints readable BY): 1 covers the 1-device
#: trainer, the powers of two cover the common model-shard counts.  A
#: writer with a k_multiple outside this set needs its own migration.
KNOWN_K_MULTIPLES = (1, 2, 4, 8)


def checkpoint_migrations(cfg: DLRMConfig):
    """``Trainer(migrations=...)`` entries for every older emb layout:
    the pre-collection per-feature layout, the pre-universal grouped
    layout (per-signature CCE slab + full buckets), and every
    ``KNOWN_K_MULTIPLES`` sharded-padding variant of the universal layout
    — all restore bit-exact into this config's supertables (params,
    optimizer moments, buffers, error feedback).  The k_multiple
    migrations are what lets a model-sharded trainer's checkpoint restore
    into a 1-device trainer and vice versa: the extra pad rows are
    unreachable and provably zero, so dropping/adding them through the
    per-feature view loses nothing."""
    migrations = [legacy_layout_migration(cfg.collection)]
    grouped = EmbeddingCollection.build(cfg.collection.tables, mode="group")
    same_layout = tuple((g.kind, g.features) for g in grouped.groups) == tuple(
        (g.kind, g.features) for g in cfg.collection.groups
    )
    if not same_layout:
        migrations.append(
            grouped_layout_migration(cfg.collection, grouped)
        )

    def k_pads(coll):
        return tuple(
            coll.groups[g].k_pad for g in coll.univ_groups
        )

    for m in KNOWN_K_MULTIPLES:
        if m == cfg.emb_k_multiple:
            continue
        other = EmbeddingCollection.build(
            cfg.collection.tables, mode=cfg.emb_fuse, k_multiple=m,
        )
        if k_pads(other) == k_pads(cfg.collection):
            continue  # same padded layout — nothing to migrate
        migrations.append(grouped_layout_migration(cfg.collection, other))
    return migrations
