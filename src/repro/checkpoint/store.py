"""Sharded, async, resume-exact checkpointing (numpy-backed).

Layout: one directory per step,
    <dir>/step_000123/
        manifest.json        — tree structure, shapes, dtypes, step, mesh
        arr_<idx>.npy        — one file per leaf (row-chunked for big leaves)
        _COMMITTED           — written last; partial checkpoints are ignored

Properties needed at pod scale, all implemented here:
  * atomicity — the _COMMITTED marker is written after all data + fsync,
    so a job killed mid-save restarts from the previous step (tested).
  * async — `CheckpointManager.save_async` snapshots device arrays to host
    (cheap) and writes on a background thread; training continues.
  * cross-mesh (elastic) restore — arrays are stored UNSHARDED (gathered),
    and `reshard_restore` places them into any new mesh/sharding, so you
    can save on 512 chips and restore on 256 (tested on CPU with
    sub-meshes).
  * retention — keep_last N checkpoints, garbage-collected after commit.

On a real pod you'd swap the gather for per-host shard files (same
manifest format, `shard_id` field is reserved for it) — the control flow
(atomic commit, async thread, retention, reshard on restore) is the part
that carries over unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

Pytree = Any

_COMMIT = "_COMMITTED"


def _tree_paths(tree: Pytree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree, *, extra: dict | None = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = _tree_paths(tree)
    try:  # informational only; restore uses template= (custom nodes like
        # NamedTuple states don't proto-serialize)
        treedef_hex = jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
    except (ValueError, TypeError):
        treedef_hex = str(treedef)
    manifest = {
        "step": step,
        "treedef": treedef_hex,
        "n_leaves": len(flat),
        "extra": extra or {},
        "leaves": [],
    }
    if isinstance(tree, dict):
        # top-level section index: per-key leaf counts, in jax's dict
        # flatten order (sorted keys).  Lets a differently-configured
        # reader align optional host-state sections (id_counts, trigger)
        # by NAME — dropping departed sections and defaulting new ones —
        # instead of leaf-count arithmetic over the whole tree.
        manifest["toplevel"] = [
            [k, len(jax.tree.leaves(tree[k]))] for k in sorted(tree)
        ]
    for i, leaf in enumerate(flat):
        # device_get on a multi-device jax.Array assembles the GLOBAL
        # array from its addressable shards — checkpoints are always
        # stored in the unsharded 1-device layout, which is what makes a
        # sharded trainer's checkpoint restore into a 1-device trainer
        # (and vice versa) without a dedicated converter
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        sharding = getattr(leaf, "sharding", None)
        spec = getattr(sharding, "spec", None)
        if spec is not None and any(s is not None for s in spec):
            # informational: how the WRITER sharded this leaf (the reader
            # places leaves per its own mesh via ``shardings=``)
            entry["sharding"] = str(spec)
        manifest["leaves"].append(entry)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    with open(os.path.join(path, _COMMIT), "w") as f:
        f.write(str(time.time()))
        f.flush()
        os.fsync(f.fileno())
    return path


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    """Committed checkpoints, ascending by step."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        p = os.path.join(directory, name)
        if name.startswith("step_") and os.path.exists(os.path.join(p, _COMMIT)):
            out.append((int(name.split("_")[1]), p))
    return sorted(out)


def _shapes_match(t_leaves, stored) -> bool:
    """Template-vs-stored leaf compatibility: equal count, equal shapes —
    except zero-size template leaves, which are wildcards (they absorb a
    stored leaf of any shape)."""
    return len(t_leaves) == len(stored) and not any(
        hasattr(t, "shape")
        and np.size(t) > 0
        and tuple(t.shape) != tuple(leaf.shape)
        for t, leaf in zip(t_leaves, stored)
    )


def _align_toplevel(tmpl: Pytree, leaves, toplevel, *, allow_drop: bool) -> Pytree | None:
    """Section-aware restore for top-level dict trees: align stored leaf
    runs to template keys by NAME.  With ``allow_drop``, stored sections
    the template lacks are dropped (a departed writer's id histograms);
    template keys the store lacks keep the template's value (fresh state
    — how a pre-trigger checkpoint restores into a trigger-enabled
    Trainer).  Returns None when any shared section's leaves don't fit
    the template, or (without ``allow_drop``) when a stored section goes
    unconsumed — the caller tries drop-free candidates first so a
    candidate that merely discards data never shadows one that migrates
    it."""
    if not isinstance(tmpl, dict):
        return None
    stored: dict[str, list] = {}
    off = 0
    for k, n in toplevel:
        stored[k] = leaves[off : off + n]
        off += n
    if off != len(leaves):
        return None  # corrupt/foreign section index
    if not allow_drop and any(k not in tmpl for k in stored):
        return None
    out = {}
    for k, sub in tmpl.items():
        if k not in stored:
            out[k] = sub
            continue
        s_leaves, s_def = jax.tree.flatten(sub)
        if not _shapes_match(s_leaves, stored[k]):
            return None
        out[k] = jax.tree.unflatten(s_def, stored[k])
    return out


def load_checkpoint(directory: str, *, step: int | None = None,
                    template: Pytree | None = None, migrations=()):
    """Load the latest (or given-step) committed checkpoint.

    Returns (step, tree, extra).  If ``template`` is given, the tree
    structure is taken from it (robust to treedef serialization versions).

    ``migrations`` is an ordered sequence of ``(template, convert)``
    layout candidates for checkpoints written by older code: each template
    is tried in turn (after ``template``, if given) until one matches the
    stored leaves, and its ``convert`` — None for identity — maps the
    restored tree to the current layout.  A template matches when the leaf
    COUNT and every leaf SHAPE agree — two layouts of the same state can
    coincide in leaf count (a per-feature emb list vs. a stacked slab plus
    histogram placeholders), and shape is what tells them apart.  A
    zero-size template leaf is a wildcard: it absorbs a stored leaf of any
    shape (the Trainer uses this to drop a departed writer's id
    histograms).
    """
    ckpts = list_checkpoints(directory)
    if not ckpts:
        raise FileNotFoundError(f"no committed checkpoints under {directory}")
    if step is None:
        step, path = ckpts[-1]
    else:
        match = [p for s, p in ckpts if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not found under {directory}")
        path = match[0]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = [
        np.load(os.path.join(path, f"arr_{i}.npy"))
        for i in range(manifest["n_leaves"])
    ]
    candidates = ([(template, None)] if template is not None else []) + list(
        migrations
    )
    if not candidates:
        raise ValueError("pass template= to reconstruct the tree structure")
    toplevel = manifest.get("toplevel")
    err: Exception | None = None
    # two passes: exact whole-tree and drop-free section alignment first,
    # then alignments that DISCARD stored sections — so a candidate that
    # merely drops data never wins over a later one that migrates it
    for allow_drop in (False, True):
        for tmpl, convert in candidates:
            t_leaves, treedef = jax.tree.flatten(tmpl)
            if not allow_drop and _shapes_match(t_leaves, leaves):
                tree = jax.tree.unflatten(treedef, leaves)
            elif toplevel is not None:
                # whole-tree match failed (e.g. an optional host-state
                # section appeared or departed): align by section name
                tree = _align_toplevel(tmpl, leaves, toplevel,
                                       allow_drop=allow_drop)
                if tree is None:
                    err = err or ValueError(
                        "stored sections do not fit this layout template"
                    )
                    continue
            else:
                err = err or ValueError(
                    f"leaf count/shape mismatch: checkpoint has {len(leaves)} "
                    f"leaves, template has {len(t_leaves)}"
                )
                continue
            if convert is not None:
                tree = convert(tree)
            return manifest["step"], tree, manifest.get("extra", {})
    raise err  # no candidate layout matched


def reshard_restore(tree: Pytree, shardings: Pytree) -> Pytree:
    """Place a host (numpy) tree onto devices under arbitrary shardings —
    the elastic-rescale path: the saved mesh and the restore mesh need not
    match."""
    return jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), tree, shardings
    )


class CheckpointManager:
    """Async save + retention.  One background writer thread; `wait()` for
    a barrier (used before exit and in tests)."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree: Pytree, *, extra: dict | None = None):
        self.wait()  # one in-flight save at a time
        # snapshot to host memory NOW so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        ckpts = list_checkpoints(self.directory)
        for _, path in ckpts[: -self.keep_last]:
            shutil.rmtree(path, ignore_errors=True)

    def restore_latest(self, template: Pytree, *, shardings: Pytree | None = None):
        """``shardings`` (a tree of ``jax.sharding.Sharding`` matching the
        restored tree, or a prefix thereof) places the host arrays onto
        the restore mesh — the cross-mesh round-trip: a 1-device
        checkpoint restores sharded, a sharded checkpoint restores onto
        one device, without either side knowing the other's mesh."""
        self.wait()
        step, tree, extra = load_checkpoint(self.directory, template=template)
        if shardings is not None:
            tree = reshard_restore(tree, shardings)
        return step, tree, extra
