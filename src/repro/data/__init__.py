from repro.data.synthetic import (  # noqa: F401
    ClickstreamConfig,
    clickstream_batches,
    lm_token_batches,
    planted_embedding_model,
)
from repro.data.translate import HostTranslator, translate_batches  # noqa: F401
