"""Host-side pointer translation — DESIGN.md §4, wired into §6's
single-launch dataflow.

The CCE pointer tables ``(c, d1)`` are the only O(vocab) training-time
state besides uncompressed embeddings.  On a single device they live in
device memory and the row translation is a cheap fused gather; on a pod
they are HOST-resident and ride the input pipeline: this module
translates raw ids -> supertable codebook rows on the host, using
bit-exact numpy mirrors of every table's row function
(``table.fuse_rows_np``: learned-pointer gather + ``multiply_shift_np``
helper hashes for CCE, plain hashes for hash/CE tables, clamped identity
for fused full tables).  The translated batch ships ONE int32 tensor

    rows : (B, collection.rows_n_cols, collection.rows_n_tables)

— the only sparse input the device program needs (``-1`` marks padded
sub-table slots; the one-hot kernel treats them as no-ops), and the
device program never gathers the pointer tables
(``EmbeddingCollection.lookup_all(rows=...)``; asserted at the jaxpr
level in tests/test_collection.py).

The mirrors are snapshots: the clustering transition rewrites ``ptr`` /
``hs``, so ``HostTranslator.update(emb_buffers)`` must run after every
transition (and after a checkpoint restore) before translating further
batches — exactly where a pod pipeline re-broadcasts the id-sharded
pointer the sharded transition produces (§2).  Pass the translator to
``Trainer(translator=...)`` and the training loop does both re-syncs
itself (``translate_batches`` is lazy, so the next batch already uses
the fresh mirrors — host-rows training is bit-identical to raw-ids
training across transitions, tested).
"""
from __future__ import annotations

import numpy as np

from repro.core.collection import EmbeddingCollection, _expand_rows, bucket_rows


class HostTranslator:
    """ids -> supertable rows on host, bit-exact with the device path.

    With ``n_shards=M`` the translator additionally plays the ps-lite
    worker-side id-router role: each universal group's rows are bucketed
    by owning model shard (shard ``s`` owns codebook rows
    ``[s*k_pad/M, (s+1)*k_pad/M)``) and ``rows()`` emits shard-LOCAL
    indices (B, M, rows_n_cols, rows_n_tables) — the device program then
    skips the bucketing arithmetic and goes straight to all-to-all
    (``EmbeddingCollection._univ_lookup_sharded``)."""

    def __init__(self, collection: EmbeddingCollection, emb_buffers=None,
                 *, n_shards: int = 1):
        self.collection = collection
        self.n_shards = int(n_shards)
        for g in collection.univ_groups:
            grp = collection.groups[g]
            if grp.k_pad % self.n_shards:
                raise ValueError(
                    f"group {g}: k_pad {grp.k_pad} not divisible by "
                    f"n_shards {n_shards}; build the collection with "
                    f"k_multiple={n_shards}"
                )
        self._buffers = None
        if emb_buffers is not None:
            self.update(emb_buffers)

    def update(self, emb_buffers) -> None:
        """Refresh the host mirrors from the (possibly device-resident)
        buffer tree — numpy copies of every leaf the row functions read.
        Cheap for everything but the pointer tables, whose device->host
        pull is the point: afterwards the device never touches them."""
        mirrored = []
        for g, grp in enumerate(self.collection.groups):
            if grp.kind != "univ":
                mirrored.append(emb_buffers[g])
                continue
            mirrored.append(
                [
                    {k: v if isinstance(v, tuple) else np.asarray(v)
                     for k, v in feat.items()}
                    for feat in emb_buffers[g]
                ]
            )
        self._buffers = mirrored

    def rows(self, sparse: np.ndarray) -> np.ndarray:
        """(B, n_features) raw ids -> (B, rows_n_cols, rows_n_tables)
        int32 supertable rows (universal groups concatenated along the
        column axis; narrower groups' extra sub-table slots are -1).
        With ``n_shards=M`` > 1 the result gains a shard-bucket axis:
        (B, M, rows_n_cols, rows_n_tables) shard-local indices, each
        group bucketed by its own ``k_pad / M``."""
        if self._buffers is None:
            raise RuntimeError("HostTranslator.update(emb_buffers) first")
        coll = self.collection
        M = self.n_shards
        sparse = np.asarray(sparse)
        T = coll.rows_n_tables
        blocks = []
        for g in coll.univ_groups:
            grp = coll.groups[g]
            grows = np.concatenate(
                [
                    _expand_rows(
                        t.fuse_rows_np(self._buffers[g][f], sparse[:, i]),
                        grp.col_counts[f] // t.fuse_spec.cols,
                        grp.n_tables,
                        np,
                    )
                    for f, (i, t) in enumerate(zip(grp.features, grp.tables))
                ],
                axis=0,
            )  # (n_cols, B, T_g)
            if grows.shape[-1] < T:
                pad = np.full(grows.shape[:-1] + (T - grows.shape[-1],), -1,
                              np.int32)
                grows = np.concatenate([grows, pad], axis=-1)
            if M > 1:
                grows = bucket_rows(grows, grp.k_pad // M, M, np)
                # (M, n_cols, B, T)
            blocks.append(grows)
        rows = np.concatenate(blocks, axis=-3)  # col axis, with/without M
        if M > 1:
            return np.moveaxis(rows, (0, 1, 2), (1, 2, 0)).astype(np.int32)
        return np.moveaxis(rows, 0, 1).astype(np.int32)

    def rows_masked(self, sparse: np.ndarray, skip: np.ndarray) -> np.ndarray:
        """Translate like :meth:`rows`, then mask every column of a
        skipped (batch-element, feature) pair to the ``-1`` sentinel.

        ``skip`` is (B, n_features) bool — True where a serve-side cache
        already holds the decoded embedding, so the fused kernel must do
        ZERO work for that feature (the sentinel is a free no-op in the
        one-hot kernel; the cache value is added outside the launch).
        Single-shard only: the serve path has no all-to-all."""
        if self.n_shards != 1:
            raise ValueError(
                "rows_masked is a serve-path helper; it does not emit "
                f"shard-bucketed rows (n_shards={self.n_shards})"
            )
        rows = self.rows(sparse)
        m = np.asarray(skip, bool)[:, self.collection.rows_col_feature]
        return np.where(m[:, :, None], np.int32(-1), rows)

    def __call__(self, batch: dict, *, drop_sparse: bool = False) -> dict:
        """Translate one batch dict: adds ``rows``; ``drop_sparse=True``
        removes the raw ids so the translated rows are the ONLY sparse
        input shipped to the device (a tracker-carrying pipeline keeps
        them — frequency sketches hash raw ids)."""
        if drop_sparse:
            unfused = [
                g.kind for g in self.collection.groups if g.kind != "univ"
            ]
            if unfused:
                # rows only cover universal groups; the full/loop groups
                # still consume raw ids — dropping them would crash the
                # lookup far from the cause
                raise ValueError(
                    "drop_sparse=True needs every table universally fused; "
                    f"this collection still has {sorted(set(unfused))} "
                    "groups that consume raw ids"
                )
        out = dict(batch, rows=self.rows(batch["sparse"]))
        if drop_sparse:
            del out["sparse"]
        return out


def translate_batches(batches, translator: HostTranslator, *,
                      drop_sparse: bool = False):
    """Wrap a batch iterator with the host translation stage (the input
    pipeline runs on CPU hosts — see data/synthetic.py)."""
    from repro.obs.trace import span

    for batch in batches:
        with span("translate"):
            out = translator(batch, drop_sparse=drop_sparse)
        yield out
