"""Deterministic synthetic data pipelines.

Two generators:

1. ``clickstream_batches`` — a Criteo-like CTR stream for DLRM: 13 dense +
   N categorical features, power-law (Zipf) id frequencies like real click
   logs, and a PLANTED low-rank cluster structure: each id belongs to one
   of ``n_latent`` latent concepts, and the click probability depends on
   the latent concepts, not the raw ids.  This is exactly the regime where
   clustering ids (CCE) is strictly better than hashing them randomly —
   the data has ground-truth mergeable ids, so the paper's ordering
   (CCE > CE > hashing at equal budget) is measurable at small scale.

2. ``lm_token_batches`` — power-law token stream with Markov structure for
   LM smoke training.

Both are host-side numpy generators (the real input pipeline runs on CPU
hosts on a pod — see DESIGN.md §4), deterministic in (seed, step) so any
host can regenerate any shard: this is what makes checkpoint-restart and
elastic rescaling exact — a restarted job replays from the step counter,
no data-state checkpoint needed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClickstreamConfig:
    vocab_sizes: tuple[int, ...] = (1000, 5000, 20000, 100, 50000)
    n_dense: int = 13
    n_latent: int = 32  # latent concepts per feature (the planted clusters)
    zipf_a: float = 1.1  # id frequency skew
    noise: float = 0.5  # logit noise — keeps BCE away from 0
    seed: int = 0


def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** a
    return p / p.sum()


def planted_embedding_model(cfg: ClickstreamConfig):
    """The ground truth: id -> latent concept maps and concept weights."""
    rng = np.random.default_rng(cfg.seed)
    concept_of = [
        rng.integers(0, cfg.n_latent, size=v) for v in cfg.vocab_sizes
    ]
    concept_w = [
        rng.normal(0, 1.0, size=cfg.n_latent) for _ in cfg.vocab_sizes
    ]
    dense_w = rng.normal(0, 0.3, size=cfg.n_dense)
    return concept_of, concept_w, dense_w


def clickstream_batches(
    cfg: ClickstreamConfig, batch: int, *, start_step: int = 0,
    host_id: int = 0, n_hosts: int = 1,
) -> Iterator[dict]:
    """Yields {"dense", "sparse", "label"} batches.  (seed, step, host)
    fully determine the batch — restart-exact and shardable across hosts."""
    concept_of, concept_w, dense_w = planted_embedding_model(cfg)
    probs = [_zipf_probs(v, cfg.zipf_a) for v in cfg.vocab_sizes]
    step = start_step
    while True:
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + host_id * n_hosts
        )
        dense = rng.normal(0, 1, size=(batch, cfg.n_dense)).astype(np.float32)
        sparse = np.stack(
            [rng.choice(len(p), size=batch, p=p) for p in probs], axis=1
        ).astype(np.int32)
        logit = dense @ dense_w
        for f in range(len(cfg.vocab_sizes)):
            logit = logit + concept_w[f][concept_of[f][sparse[:, f]]]
        logit = logit + rng.normal(0, cfg.noise, size=batch)
        label = (rng.uniform(size=batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        yield {"dense": dense, "sparse": sparse, "label": label, "step": step}
        step += 1


def lm_token_batches(
    vocab: int, batch: int, seq: int, *, seed: int = 0, start_step: int = 0,
    host_id: int = 0, n_hosts: int = 1, n_codebooks: int = 0,
) -> Iterator[dict]:
    """Power-law Markov token stream: token t+1 ~ mix of a power-law prior
    and a deterministic successor map — enough structure for loss curves to
    move within a few hundred steps."""
    rng0 = np.random.default_rng(seed)
    succ = rng0.integers(0, vocab, size=vocab)
    prior = _zipf_probs(vocab, 1.2)
    step = start_step
    while True:
        rng = np.random.default_rng((seed * 9_999_991 + step) * 257 + host_id * n_hosts)
        shape = (batch, seq, n_codebooks) if n_codebooks else (batch, seq)
        toks = np.empty(shape, np.int32)
        first = rng.choice(vocab, size=shape[:1] + shape[2:], p=prior)
        toks[:, 0] = first
        for t in range(1, seq):
            follow = rng.uniform(size=shape[:1] + shape[2:]) < 0.7
            rand = rng.choice(vocab, size=shape[:1] + shape[2:], p=prior)
            toks[:, t] = np.where(follow, succ[toks[:, t - 1]], rand)
        yield {"tokens": toks, "step": step}
        step += 1
