from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.dlrm import (  # noqa: F401
    DLRMServeEngine,
    HotCache,
    MicroBatcher,
    ServeRequest,
    ServeResult,
    StaleCacheError,
    make_serve_fns,
)
