"""Batched serving engine with continuous batching.

Slot-based design (the TPU-friendly fixed-shape variant of vLLM-style
serving): the decode cache is allocated once at (max_batch, max_seq); each
request owns a slot.  Per tick:

  1. admit queued requests into ALL free slots first (one jitted prefill per
     request — prompts are ragged — then ONE fixed-arity jitted scatter
     writes every admitted slot's cache rows at once),
  2. one batched decode step for all active slots,
  3. retire finished requests (eos / max_tokens).

Everything device-side is fixed-shape.  Prompts right-pad into power-of-two
length buckets (attention families only — recurrent/ring-buffer caches
consume pads), so the compiled-program inventory is bounded independent of
traffic: one decode, one slot scatter, and at most log2(max_seq) prefill
buckets — no per-prompt-length shape churn, which is what keeps a TPU
serving deployment at high duty cycle.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs.runlog import LatencyHistogram
from repro.train.loop import merge_buffers, split_buffers


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_tokens: int = 16
    eos: int | None = None
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float | None = None  # admit -> retire wall time
    _t_admit: float | None = None


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        buffers,
        *,
        max_batch: int = 8,
        max_seq: int = 256,
        sample: str = "greedy",
        runlog=None,
    ):
        assert not cfg.n_codebooks, "audio serving uses examples/musicgen_decode"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        dyn, static = split_buffers(buffers)
        self._dyn, self._static = dyn, static
        self.cache = lm.init_cache(cfg, max_batch, max_seq)
        self.pos = np.zeros((max_batch,), np.int32)
        self.last_token = np.zeros((max_batch,), np.int32)
        self.slots: list[Request | None] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self.ticks = 0
        # per-request admit->retire latency at constant memory (the seed
        # of ROADMAP item 2's p50/p99 serve artifact); optionally logged
        # to a repro.obs RunLog per retired request + a final histogram
        # via flush_stats()
        self.latency = LatencyHistogram()
        self.runlog = runlog

        def _decode(dyn, tokens, pos, cache):
            buffers = merge_buffers(dyn, static)
            return lm.decode_step(params, buffers, cfg, tokens, pos, cache,
                                  batch_axes=None)

        def _prefill_one(dyn, tokens, cache1, last_idx):
            buffers = merge_buffers(dyn, static)
            return lm.prefill(params, buffers, cfg, tokens, cache1,
                              batch_axes=None, last_idx=last_idx)

        baxis = lm.cache_batch_axis(cfg)

        def _scatter(big_cache, idx, *ones):
            # all admitted slot caches in ONE compiled update: stack each
            # leaf along its batch axis, scatter at idx.  Pad entries index
            # max_batch and drop (never -1: negative indices WRAP in jax).
            def upd(big, ax, *xs):
                stacked = jnp.concatenate(
                    [jnp.moveaxis(x, ax, 0) for x in xs], axis=0
                )
                out = jnp.moveaxis(big, ax, 0).at[idx].set(
                    stacked.astype(big.dtype), mode="drop"
                )
                return jnp.moveaxis(out, 0, ax)

            return jax.tree.map(upd, big_cache, baxis, *ones)

        self._decode = jax.jit(_decode, donate_argnums=(3,))
        self._prefill = jax.jit(_prefill_one)
        self._scatter = jax.jit(_scatter, donate_argnums=(0,))
        # padded prefill is only sound when no cache state is a function of
        # the WHOLE padded sequence: recurrent families fold pads into the
        # terminal state, sliding windows rotate the ring by S
        self._pad_prompts = (
            cfg.family not in ("xlstm", "hybrid") and not cfg.sliding_window
        )

    # --- public API ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_ticks: int = 1000) -> list[Request]:
        finished = []
        while (self.queue or any(self.slots)) and self.ticks < max_ticks:
            finished.extend(self.tick())
        return finished

    # --- engine internals ----------------------------------------------------

    def _bucket_len(self, S: int) -> int:
        """Smallest power-of-two >= S (min 2, capped at max_seq): prompt
        shapes collapse to <= log2(max_seq) distinct prefill programs."""
        L = 2
        while L < S:
            L *= 2
        return min(L, self.max_seq)

    def _admit(self):
        # 1) prefill every admissible request (prompts are ragged, so one
        #    prefill call each — but padded to power-of-two buckets, so the
        #    number of COMPILED prefills stays bounded)
        slot_ids: list[int] = []
        ones: list = []
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            S = len(req.prompt)
            assert S < self.max_seq, "prompt longer than max_seq"
            L = self._bucket_len(S) if self._pad_prompts else S
            toks = np.zeros((1, L), np.int32)
            toks[0, :S] = req.prompt
            cache1 = lm.init_cache(self.cfg, 1, self.max_seq)
            logits, cache1 = self._prefill(
                self._dyn, jnp.asarray(toks), cache1, jnp.int32(S - 1)
            )
            slot_ids.append(slot)
            ones.append(cache1)
            self.slots[slot] = req
            self.pos[slot] = S
            self.last_token[slot] = int(jnp.argmax(logits[0][: self.cfg.vocab]))
            req.generated.append(int(self.last_token[slot]))
            req._t_admit = time.perf_counter()
        if not ones:
            return
        # 2) ONE batched scatter of all admitted slot caches (fixed arity:
        #    pad with repeats of the first cache, routed to a dropped index)
        n = len(ones)
        ones.extend(ones[0] for _ in range(self.max_batch - n))
        idx = np.full((self.max_batch,), self.max_batch, np.int32)
        idx[:n] = slot_ids
        self.cache = self._scatter(self.cache, jnp.asarray(idx), *ones)

    def tick(self) -> list[Request]:
        self._admit()
        self.ticks += 1
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        logits, self.cache = self._decode(
            self._dyn,
            jnp.asarray(self.last_token),
            jnp.asarray(self.pos),
            self.cache,
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        finished = []
        for i in active:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            self.pos[i] += 1
            self.last_token[i] = nxt[i]
            if (
                len(req.generated) >= req.max_tokens
                or (req.eos is not None and nxt[i] == req.eos)
                or self.pos[i] >= self.max_seq - 1
            ):
                req.done = True
                self._retire(req)
                finished.append(req)
                self.slots[i] = None
        return finished

    def _retire(self, req: Request) -> None:
        req.latency_s = time.perf_counter() - req._t_admit
        self.latency.observe(req.latency_s)
        if self.runlog is not None:
            self.runlog.append(
                "request", dedupe=False, uid=req.uid,
                n_prompt=len(req.prompt), n_generated=len(req.generated),
                latency_s=req.latency_s,
            )

    def flush_stats(self) -> dict:
        """Write the aggregate latency histogram to the run log (one
        ``latency_hist`` event per call) and return it."""
        hist = self.latency.to_dict() | {"label": "serve-requests"}
        if self.runlog is not None:
            self.runlog.append("latency_hist", dedupe=False, **hist)
        return hist
