"""Batched DLRM serving with a SpaceSaving-fed hot-id cache — DESIGN.md §11.

The serve path composes three earned invariants at inference time:

* **One fused launch per serve batch.**  Lookups route through
  ``collection.lookup_all`` with HOST-translated rows
  (``HostTranslator``), so the device program never gathers the pointer
  tables — the same no-ptr-gather contract the training step carries,
  audited by the ``serve_dlrm_cold`` spec.

* **The Zipf head never touches the supertable.**  The SpaceSaving head
  already *names* each feature's hot ids; :class:`HotCache` materializes
  their DECODED embeddings into one small dense device table.  A cache
  hit is a direct gather; the cold tail falls back to the fused launch on
  a COMPACTED sub-batch, with the hit features' rows masked to the ``-1``
  sentinel (a free no-op in the one-hot kernel) so kernel work scales
  with true misses only.  A fully-hit batch skips the launch entirely
  (``serve_dlrm_hit`` audits 0 pallas calls).  Cache answers are
  bit-exact with ``lookup_all`` answers: both are gathers of the same
  decoded rows, and the masked kernel contributes an exact zero.

* **Freshness is enforced, not hoped for.**  The cache records the CCE
  transition epoch of every cached feature at build time; serving across
  a clustering transition without a refresh RAISES
  :class:`StaleCacheError` (silently returning pre-transition rows would
  be a correctness bug, not a performance one).  Refreshes happen at
  transitions (``update_state``), on SpaceSaving head churn
  (``maybe_refresh``, Jaccard distance vs the live tracker export), or
  manually — each one is a ``cache_refresh`` run-log event.

Concurrent user requests aggregate in :class:`MicroBatcher` under a
latency budget: a micro-batch launches when it fills ``max_batch`` or
when the OLDEST request has waited ``latency_budget_s``.  Batches pad to
fixed bucket shapes (default: one batch bucket + one cold bucket = two
compiled programs total); the budget bounds host-side queue wait before
dispatch — NOT device compute, transfer, or cache-refresh pauses.
Per-request latency rides the PR-9 run-log machinery (``request`` events
+ ``LatencyHistogram``).
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embeddings as emb_lib
from repro.data.translate import HostTranslator
from repro.models import dlrm as dlrm_lib
from repro.obs.runlog import LatencyHistogram
from repro.stream.trigger import head_churn


class StaleCacheError(RuntimeError):
    """The hot cache was built against a pre-transition supertable."""


# --- the two compiled programs ----------------------------------------------


def make_serve_fns(cfg, *, use_kernel: bool | None = None):
    """Build the (hit, cold) serve programs for one DLRM config.

    ``hit_fn(mlp_params, cache_tab, slots, dense)`` — fully-cache-hit
    batch: ONE gather of the decoded-embedding cache (slot ``-1`` rows
    contribute zero) feeding the interaction MLPs.  Zero heavy launches;
    takes only the bottom/top MLP params so every input is live.

    ``cold_fn(params, emb_buffers, cache_tab, slots, dense, rows,
    cold_idx)`` — mixed batch: the same cache gather, plus ONE fused
    supertable launch over the compacted cold sub-batch (host-translated
    ``rows``, hit features pre-masked to ``-1`` so the kernel does zero
    work for them and the sum is exactly the cache value), scattered back
    by ``cold_idx`` (pad entries index past the batch and drop).
    ``emb_buffers`` rides along dead — the rows path never reads ptr/hs,
    which is exactly what the ``serve_dlrm_cold`` audit asserts.
    """
    coll = cfg.collection
    if use_kernel is None:
        use_kernel = jax.default_backend() in ("tpu", "cpu")

    def _cache_gather(cache_tab, slots):
        live = (slots >= 0)[..., None].astype(cache_tab.dtype)
        return cache_tab[jnp.maximum(slots, 0)] * live  # (B, F, d2)

    def hit_fn(mlp_params, cache_tab, slots, dense):
        emb = _cache_gather(cache_tab, slots)
        return dlrm_lib.interact(mlp_params, cfg, dense, emb)

    def cold_fn(params, emb_buffers, cache_tab, slots, dense, rows, cold_idx):
        emb = _cache_gather(cache_tab, slots)
        cold = coll.lookup_all(
            params["emb"], emb_buffers, None,
            use_kernel=use_kernel, rows=rows,
        )  # (B_cold, F, d2): ONE fused launch
        emb = emb.at[cold_idx].add(cold.astype(emb.dtype), mode="drop")
        mlp = {"bottom": params["bottom"], "top": params["top"]}
        return dlrm_lib.interact(mlp, cfg, dense, emb)

    return hit_fn, cold_fn


# --- the hot-id cache -------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HotCache:
    """Dense decoded-embedding cache over each feature's hot-id set.

    One concatenated (n_slots, emb_dim) device table; per cached feature
    a SORTED unique id array plus its base offset, so the host-side slot
    lookup is a ``searchsorted`` per feature.  ``epochs`` snapshots the
    CCE transition counter of every cached feature whose buffers carry
    one — the staleness token the engine checks before every batch."""

    ids: dict[int, np.ndarray]  # feature -> sorted unique cached ids
    base: dict[int, int]  # feature -> row offset into `table`
    table: jax.Array  # (max(n_slots, 1), emb_dim) decoded embeddings
    epochs: dict[int, int]  # feature -> transition epoch at build time
    n_slots: int

    @classmethod
    def build(cls, collection, emb_params, emb_buffers,
              head_ids: dict[int, np.ndarray], *, dtype=None) -> "HotCache":
        """Decode ``head_ids[f]`` for every feature through its own table
        (unstacking each touched group ONCE) into the dense cache.  Out
        -of-range / negative ids (empty SpaceSaving slots) are dropped;
        features left with no ids are simply not cached."""
        per_feature: dict[int, np.ndarray] = {}
        for f, ids in head_ids.items():
            t = collection.tables[f]
            ids = np.unique(np.asarray(ids, np.int64))
            ids = ids[(ids >= 0) & (ids < t.d1)].astype(np.int32)
            if ids.size:
                per_feature[f] = ids

        groups_needed = sorted({collection._locate[f][0] for f in per_feature})
        unstacked = {
            g: collection.unstack_group_params(
                collection.groups[g], emb_params[g]
            )
            for g in groups_needed
        }

        base: dict[int, int] = {}
        epochs: dict[int, int] = {}
        chunks = []
        off = 0
        for f in sorted(per_feature):
            g, f_local = collection._locate[f]
            t = collection.tables[f]
            fb = emb_buffers[g][f_local]
            chunks.append(
                t.lookup(unstacked[g][f_local], fb, jnp.asarray(per_feature[f]))
            )
            base[f] = off
            off += per_feature[f].size
            if "epoch" in fb:
                epochs[f] = int(fb["epoch"])
        if chunks:
            table = jnp.concatenate(chunks, axis=0)
            if dtype is not None:
                table = table.astype(dtype)
        else:
            d2 = collection.tables[0].d2 if collection.tables else 1
            table = jnp.zeros((1, d2), dtype or jnp.float32)
        return cls(ids=per_feature, base=base, table=table,
                   epochs=epochs, n_slots=off)

    def slots(self, sparse: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(B, n_features) raw ids -> (slots, hit): cache-table row per
        lookup (``-1`` = miss) and the boolean hit mask.  Features with
        no cached ids miss everywhere."""
        sparse = np.asarray(sparse)
        B, F = sparse.shape
        slots = np.full((B, F), -1, np.int32)
        hit = np.zeros((B, F), bool)
        for f, ids in self.ids.items():
            col = sparse[:, f]
            pos = np.searchsorted(ids, col)
            ok = (pos < ids.size) & (ids[np.minimum(pos, ids.size - 1)] == col)
            slots[ok, f] = self.base[f] + pos[ok]
            hit[:, f] = ok
        return slots, hit

    def check_fresh(self, collection, emb_buffers) -> None:
        """Raise :class:`StaleCacheError` if any cached feature has
        transitioned since the cache was built."""
        for f, ep in self.epochs.items():
            live = int(collection.feature_buffers(emb_buffers, f)["epoch"])
            if live != ep:
                raise StaleCacheError(
                    f"hot cache built at epoch {ep} for feature {f}, "
                    f"supertable is at epoch {live}; refresh the cache "
                    "(DLRMServeEngine.update_state) before serving"
                )


# --- request aggregation ----------------------------------------------------


@dataclasses.dataclass
class ServeRequest:
    uid: int
    dense: np.ndarray  # (n_dense,)
    sparse: np.ndarray  # (n_sparse,) raw ids
    t_arrival: float | None = None


@dataclasses.dataclass(frozen=True)
class ServeResult:
    uid: int
    logit: float
    latency_s: float
    cache_hit: bool  # every feature answered from the hot cache


class MicroBatcher:
    """Aggregate concurrent requests into fixed-shape micro-batches.

    A batch is ready when ``max_batch`` requests are pending or the
    OLDEST pending request has waited ``latency_budget_s`` — the budget
    bounds queue wait before dispatch, nothing downstream of it.  The
    clock is injectable so tests drive time deterministically."""

    def __init__(self, *, max_batch: int, latency_budget_s: float = 2e-3,
                 clock=time.monotonic):
        self.max_batch = int(max_batch)
        self.latency_budget_s = float(latency_budget_s)
        self.clock = clock
        self._pending: collections.deque[ServeRequest] = collections.deque()

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, req: ServeRequest) -> None:
        if req.t_arrival is None:
            req.t_arrival = self.clock()
        self._pending.append(req)

    def ready(self) -> bool:
        if len(self._pending) >= self.max_batch:
            return True
        if not self._pending:
            return False
        waited = self.clock() - self._pending[0].t_arrival
        return waited >= self.latency_budget_s

    def take(self) -> list[ServeRequest]:
        return [
            self._pending.popleft()
            for _ in range(min(self.max_batch, len(self._pending)))
        ]


def _pick_bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


# --- the engine -------------------------------------------------------------


class DLRMServeEngine:
    """Batched DLRM inference over the fused supertable + hot-id cache.

    ``tracker`` (a ``SketchFrequencyTracker``) feeds the cache: its
    SpaceSaving heads name the hot ids per CCE feature, and small full
    tables (``d1 <= full_cache_max``) are cached whole.  ``cache=False``
    disables the cache entirely (every batch takes the cold path — the
    bench baseline).  Shapes are bucketed: ``batch_buckets`` /
    ``cold_buckets`` default to ``(max_batch,)`` so the engine compiles
    exactly two programs; finer cold buckets trade extra compiles for
    less padded kernel work on sparse-miss traffic."""

    def __init__(self, params, buffers, cfg, *, tracker=None, cache=True,
                 max_batch: int = 8, latency_budget_s: float = 2e-3,
                 batch_buckets: tuple[int, ...] | None = None,
                 cold_buckets: tuple[int, ...] | None = None,
                 head_n: int | None = None, full_cache_max: int = 8192,
                 churn_threshold: float = 0.5,
                 use_kernel: bool | None = None, run_log=None,
                 clock=time.monotonic):
        coll = cfg.collection
        unfused = sorted({g.kind for g in coll.groups if g.kind != "univ"})
        if unfused:
            raise ValueError(
                "DLRMServeEngine serves host-translated rows, which cover "
                f"universal groups only; this collection has {unfused} "
                "groups (build the config with emb_fuse='univ')"
            )
        self.cfg = cfg
        self.params = params
        self.buffers = buffers
        self.tracker = tracker
        self.run_log = run_log
        self.clock = clock
        self.head_n = head_n
        self.full_cache_max = int(full_cache_max)
        self.churn_threshold = float(churn_threshold)
        self.max_batch = int(max_batch)
        self.batch_buckets = tuple(sorted(batch_buckets or (max_batch,)))
        self.cold_buckets = tuple(sorted(cold_buckets or (max_batch,)))
        if self.batch_buckets[-1] < max_batch:
            raise ValueError("batch_buckets must cover max_batch")

        hit_fn, cold_fn = make_serve_fns(cfg, use_kernel=use_kernel)
        self._hit = jax.jit(hit_fn)
        self._cold = jax.jit(cold_fn)
        self._mlp_params = {"bottom": params["bottom"], "top": params["top"]}
        self.translator = HostTranslator(coll, buffers["emb"])
        self._live_epochs = self._read_epochs(buffers["emb"])

        self.batcher = MicroBatcher(max_batch=max_batch,
                                    latency_budget_s=latency_budget_s,
                                    clock=clock)
        self.hist = LatencyHistogram()
        self.hist_hit = LatencyHistogram()
        self.hist_cold = LatencyHistogram()
        self.counters = collections.Counter()

        self.cache: HotCache | None = None
        self._use_cache = bool(cache)
        if self._use_cache:
            self.refresh_cache(reason="init")

    # --- cache lifecycle --------------------------------------------------

    def _read_epochs(self, emb_buffers) -> dict[int, int]:
        coll = self.cfg.collection
        out = {}
        for f in range(self.cfg.n_sparse):
            fb = coll.feature_buffers(emb_buffers, f)
            if "epoch" in fb:
                out[f] = int(fb["epoch"])
        return out

    def _head_ids(self) -> dict[int, np.ndarray]:
        """Cache coverage: SpaceSaving heads for tracked (CCE) features,
        whole tables for full tables small enough to hold outright."""
        out: dict[int, np.ndarray] = {}
        coll = self.cfg.collection
        for f, t in enumerate(coll.tables):
            if isinstance(t, emb_lib.FullTable) and t.d1 <= self.full_cache_max:
                out[f] = np.arange(t.d1, dtype=np.int32)
        if self.tracker is not None:
            for f, ids in self.tracker.export_heads(self.head_n).items():
                if f not in out:
                    out[f] = ids
        return out

    def refresh_cache(self, *, reason: str = "manual",
                      churn: float | None = None) -> HotCache:
        """(Re)build the hot cache from the live params/buffers + tracker
        heads; logs a ``cache_refresh`` run-log event."""
        self._use_cache = True
        self.cache = HotCache.build(
            self.cfg.collection, self.params["emb"], self.buffers["emb"],
            self._head_ids(),
        )
        self.counters["n_refreshes"] += 1
        if self.run_log is not None:
            fields = dict(reason=reason, n_slots=self.cache.n_slots,
                          n_features=len(self.cache.ids))
            if churn is not None:
                fields["churn"] = float(churn)
            self.run_log.append("cache_refresh", dedupe=False, **fields)
        return self.cache

    def update_state(self, params, buffers, *, refresh_cache: bool = True):
        """Point the engine at post-transition params/buffers: re-syncs
        the host translator and (by default) rebuilds the cache.  With
        ``refresh_cache=False`` the stale cache is KEPT — the next served
        batch raises :class:`StaleCacheError` (tested), because the live
        epochs advance here while the cache's snapshot does not."""
        self.params = params
        self.buffers = buffers
        self._mlp_params = {"bottom": params["bottom"], "top": params["top"]}
        self.translator.update(buffers["emb"])
        self._live_epochs = self._read_epochs(buffers["emb"])
        if refresh_cache and self._use_cache:
            self.refresh_cache(reason="transition")

    def maybe_refresh(self) -> float | None:
        """Poll head churn: Jaccard distance between each cached head and
        the tracker's CURRENT head, refresh at ``churn_threshold``.
        Returns the max churn observed (None without tracker+cache)."""
        if self.tracker is None or self.cache is None:
            return None
        fresh = self.tracker.export_heads(self.head_n)
        churns = [
            head_churn(self.cache.ids[f], fresh[f])
            for f in self.cache.ids
            if f in fresh
        ]
        if not churns:
            return None
        churn = max(churns)
        if churn >= self.churn_threshold:
            self.refresh_cache(reason="head-churn", churn=churn)
        return churn

    # --- serving ----------------------------------------------------------

    def predict(self, dense: np.ndarray, sparse: np.ndarray) -> np.ndarray:
        """Synchronous batch inference (tests / bench): (B, n_dense) f32 +
        (B, n_sparse) ids -> (B,) logits, through the same bucketed
        hit/cold programs the request path uses."""
        logits, _ = self._serve_batch(np.asarray(dense), np.asarray(sparse))
        return logits

    def submit(self, req: ServeRequest) -> None:
        self.batcher.submit(req)

    def step(self) -> list[ServeResult]:
        """Serve ONE micro-batch if the batcher is ready (full, or the
        oldest request exceeded the latency budget)."""
        if not self.batcher.ready():
            return []
        return self._run(self.batcher.take())

    def drain(self) -> list[ServeResult]:
        """Serve everything pending regardless of the budget."""
        out = []
        while len(self.batcher):
            out.extend(self._run(self.batcher.take()))
        return out

    def _run(self, reqs: list[ServeRequest]) -> list[ServeResult]:
        dense = np.stack([r.dense for r in reqs]).astype(np.float32)
        sparse = np.stack([r.sparse for r in reqs]).astype(np.int64)
        logits, elem_hit = self._serve_batch(dense, sparse)
        t_done = self.clock()
        results = []
        for i, r in enumerate(reqs):
            lat = t_done - (r.t_arrival if r.t_arrival is not None else t_done)
            hit = bool(elem_hit[i])
            results.append(ServeResult(uid=r.uid, logit=float(logits[i]),
                                       latency_s=lat, cache_hit=hit))
            self.hist.observe(lat)
            (self.hist_hit if hit else self.hist_cold).observe(lat)
            self.counters["n_requests"] += 1
            self.counters["n_hit_requests"] += int(hit)
            if self.run_log is not None:
                self.run_log.append("request", dedupe=False, uid=r.uid,
                                    latency_s=lat, cache_hit=hit)
        return results

    def _serve_batch(self, dense, sparse) -> tuple[np.ndarray, np.ndarray]:
        """The two-program core: cache slots on host, compact the cold
        tail, ONE fused launch iff it is non-empty."""
        cache = self.cache
        if cache is not None and cache.epochs != {
            f: self._live_epochs[f] for f in cache.epochs
        }:
            stale = [f for f, ep in cache.epochs.items()
                     if self._live_epochs.get(f) != ep]
            raise StaleCacheError(
                f"hot cache is stale for features {stale}: the supertable "
                "transitioned since the last refresh; call update_state() "
                "or refresh_cache() before serving"
            )
        n_real, F = sparse.shape[0], self.cfg.n_sparse
        if self.tracker is not None and n_real:
            self.tracker.observe({self.tracker.key: sparse})
        B = _pick_bucket(n_real, self.batch_buckets)
        dense_p = np.zeros((B, dense.shape[1]), np.float32)
        dense_p[:n_real] = dense
        if cache is not None and cache.n_slots:
            slots, hit = cache.slots(sparse)
            cache_tab = cache.table
        else:
            slots = np.full((n_real, F), -1, np.int32)
            hit = np.zeros((n_real, F), bool)
            cache_tab = self._empty_tab()
        # pad elements are fully "hit": slot -1 gathers zero, no cold work
        slots_p = np.full((B, F), -1, np.int32)
        slots_p[:n_real] = slots
        hit_p = np.ones((B, F), bool)
        hit_p[:n_real] = hit
        elem_hit = hit.all(axis=1) if n_real else np.zeros((0,), bool)

        self.counters["n_batches"] += 1
        self.counters["n_id_lookups"] += int(n_real) * F
        self.counters["n_id_hits"] += int(hit.sum())  # audit: allow-int-cast

        cold = np.flatnonzero(~hit_p.all(axis=1))
        if cold.size == 0:
            self.counters["n_hit_batches"] += 1
            out = self._hit(self._mlp_params, cache_tab,
                            jnp.asarray(slots_p), jnp.asarray(dense_p))
        else:
            self.counters["n_cold_batches"] += 1
            self.counters["n_launches"] += 1
            Bc = _pick_bucket(cold.size, self.cold_buckets)
            coll = self.cfg.collection
            rows = self.translator.rows_masked(sparse[cold], hit[cold])
            rows_p = np.full(
                (Bc, coll.rows_n_cols, coll.rows_n_tables), -1, np.int32
            )
            rows_p[: cold.size] = rows
            # pad entries index past the batch: dropped by mode="drop"
            # (never -1 — negative indices WRAP in jax scatters)
            cold_idx = np.full((Bc,), B, np.int32)
            cold_idx[: cold.size] = cold
            out = self._cold(self.params, self.buffers["emb"], cache_tab,
                             jnp.asarray(slots_p), jnp.asarray(dense_p),
                             jnp.asarray(rows_p), jnp.asarray(cold_idx))
        return np.asarray(out)[:n_real], elem_hit

    def _empty_tab(self):
        if not hasattr(self, "_empty_tab_cached"):
            self._empty_tab_cached = jnp.zeros(
                (1, self.cfg.emb_dim), self.cfg.dtype
            )
        return self._empty_tab_cached

    # --- stats ------------------------------------------------------------

    def flush_stats(self) -> dict:
        """Summary rates + (when a run log is attached) three labeled
        ``latency_hist`` events: overall / cache-hit / cold."""
        c = self.counters
        out = {
            "n_requests": int(c["n_requests"]),
            "n_batches": int(c["n_batches"]),
            "n_launches": int(c["n_launches"]),
            "n_refreshes": int(c["n_refreshes"]),
            "hit_rate_requests": (
                c["n_hit_requests"] / c["n_requests"] if c["n_requests"] else 0.0
            ),
            "hit_rate_ids": (
                c["n_id_hits"] / c["n_id_lookups"] if c["n_id_lookups"] else 0.0
            ),
            "launches_per_batch": (
                c["n_launches"] / c["n_batches"] if c["n_batches"] else 0.0
            ),
        }
        if self.run_log is not None:
            for hist, label in ((self.hist, "serve-dlrm"),
                                (self.hist_hit, "serve-dlrm-hit"),
                                (self.hist_cold, "serve-dlrm-cold")):
                if hist.n:
                    self.run_log.append(
                        "latency_hist", dedupe=False,
                        **(hist.to_dict() | {"label": label}),
                    )
        return out
