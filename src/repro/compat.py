"""Compatibility shims for jax API drift.

The repo targets the current jax API surface (``jax.shard_map``,
``jax.sharding.set_mesh``, dict-valued ``Compiled.cost_analysis()``), but
must also run on the 0.4.x line this container ships.  Every call site
that would otherwise need a version check imports from here instead.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.4.35 exports it at top level as jax.shard_map
    shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off.

    Bodies that call pallas kernels (custom_vjp around ``pallas_call``)
    have no replication rule on the 0.4.x line, so the checker refuses
    them outright.  The flag was renamed ``check_rep`` -> ``check_vma``
    across jax versions; try the modern spelling first."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

# pallas has no stable top-level home yet; this is the ONE sanctioned
# import of it (kernels do `from repro.compat import pallas as pl`, and
# the no-raw-experimental source rule keeps it that way)
from jax.experimental import pallas  # noqa: E402,F401


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.sharding.set_mesh`` (new) or the ``with mesh:`` thread-local
    context (0.4.x) — both make ``mesh`` ambient for jit/PartitionSpec."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()``: newer jax returns one dict,
    older returns a list with one dict per partition."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})
