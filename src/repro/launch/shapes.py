"""Assigned input shapes and ShapeDtypeStruct input specs per (arch, shape).

Shapes (from the assignment):
    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, zero allocation (the dry-run contract).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN.md §long_500k)."""
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def microbatch(cfg: ModelConfig, shape: Shape, n_dp: int) -> tuple[int, int]:
    """(accum, micro) for a train shape given the data-parallel degree."""
    micro = max(cfg.train_microbatch, n_dp)  # at least 1 seq per dp shard
    micro = min(micro, shape.global_batch)
    accum = shape.global_batch // micro
    return accum, micro


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: Shape, n_dp: int):
    """Batch pytree of ShapeDtypeStructs, leaves (accum, micro, ...)."""
    accum, micro = microbatch(cfg, shape, n_dp)
    S = shape.seq
    if cfg.n_codebooks:
        batch = {"tokens": sds((accum, micro, S, cfg.n_codebooks), jnp.int32)}
    elif cfg.family == "vlm":
        # n_patches image positions + text fill the seq budget
        s_text = S - cfg.n_patches
        batch = {
            "tokens": sds((accum, micro, s_text), jnp.int32),
            "patch_emb": sds((accum, micro, cfg.n_patches, cfg.d_model), cfg.dtype),
        }
    else:
        batch = {"tokens": sds((accum, micro, S), jnp.int32)}
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: Shape):
    B, S = shape.global_batch, shape.seq
    if cfg.n_codebooks:
        toks = sds((B, S, cfg.n_codebooks), jnp.int32)
    else:
        toks = sds((B, S), jnp.int32)
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
    return {"tokens": toks, "cache": cache}


def decode_input_specs(cfg: ModelConfig, shape: Shape):
    B, S = shape.global_batch, shape.seq
    if cfg.n_codebooks:
        toks = sds((B, cfg.n_codebooks), jnp.int32)
    else:
        toks = sds((B,), jnp.int32)
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
    return {"tokens": toks, "pos": sds((B,), jnp.int32), "cache": cache}


def input_specs(cfg: ModelConfig, shape_name: str, n_dp: int = 16):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape, n_dp)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
