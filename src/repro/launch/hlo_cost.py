"""Trip-count-aware cost analysis of compiled HLO text.

Why: ``compiled.cost_analysis()`` counts a ``while`` body ONCE, but every
hot loop in this framework is a scan (grad-accum x layer stack x chunked
recurrences), so XLA's number under-reports flops/bytes/collectives by the
product of trip counts (verified: a 10-iteration scan of a matmul reports
exactly 1/10 the unrolled flops).  This module walks the compiled module's
call graph and multiplies each computation's cost by its execution count:

  * flops        — from ``dot`` ops: 2 * |result| * |contracted dims|
                   (matmul-exact; elementwise flops are ignored, they are
                   <2% on these models)
  * bytes        — per top-level instruction: operand + result buffer
                   sizes (post-fusion instruction boundaries ARE the HBM
                   round-trips; dynamic-update-slice fusions count the
                   update slice, not the aliased buffer)
  * collectives  — per kind, ICI vs DCN split by replica-group stride

Trip counts come from each while's condition computation (scan bounds are
static constants).  All numbers are per-device (the module is the SPMD-
partitioned one).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.compat import xla_cost_analysis  # noqa: F401  — re-exported: the
# baseline this module corrects; normalizes the dict/list[dict] API drift
# of Compiled.cost_analysis() across jax versions.
from repro.launch.dtypes import shape_bytes as _shape_bytes

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPCODE = re.compile(r"^(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^\s]*)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _nbytes(dtype: str, dims: str) -> int:
    return _shape_bytes(dtype, dims)


def _shape_list_bytes(text: str) -> int:
    return sum(_nbytes(m.group(1), m.group(2)) for m in _SHAPE.finditer(text))


@dataclasses.dataclass
class Instr:
    name: str
    dtype: str
    dims: str
    opcode: str
    line: str
    result_bytes: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict  # name -> (dtype, dims)
    root: Any = None  # the instruction marked ROOT (fallback: last)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        hm = _COMP_HEADER.match(line)
        if hm and line.endswith("{"):
            cur = Computation(hm.group(1), [], {})
            comps[cur.name] = cur
            # parameters are typed in the header
            for pm in re.finditer(r"([\w.\-]+):\s*(\w+)\[([\d,]*)\]", hm.group(2)):
                cur.symbols[pm.group(1)] = (pm.group(2), pm.group(3))
            continue
        if cur is None or line == "}" or not line:
            if line == "}":
                cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        is_root = line.lstrip().startswith("ROOT ")
        name, rest = im.group(1), im.group(2)
        om = _OPCODE.match(rest)
        if om:
            tuple_inner, dtype, dims, opcode = om.groups()
            if tuple_inner is not None:
                rbytes = _shape_list_bytes(tuple_inner)
                dtype, dims = "tuple", ""
            else:
                rbytes = _nbytes(dtype, dims)
                cur.symbols[name] = (dtype, dims)
        else:
            sm = _SHAPE.search(rest)
            dtype, dims = (sm.group(1), sm.group(2)) if sm else ("f32", "")
            opcode = rest.split("(")[0].split()[-1] if "(" in rest else "unknown"
            rbytes = _nbytes(dtype, dims)
            cur.symbols[name] = (dtype, dims)
        ins = Instr(name, dtype, dims, opcode, line, rbytes)
        cur.instrs.append(ins)
        if is_root:
            cur.root = ins
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        for m in _CONST_S32.finditer(ins.line):
            best = max(best, int(m.group(1)))
    # also scan raw symbol lines (constants may live in fused compare comps)
    for ins in cond.instrs:
        cm = _CALLS.search(ins.line)
        if cm and cm.group(1) in comps:
            for ins2 in comps[cm.group(1)].instrs:
                for m in _CONST_S32.finditer(ins2.line):
                    best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out = 1
    for d in ins.dims.split(","):
        if d:
            out *= int(d)
    ops = _OPERANDS.findall(ins.line.split("dot(")[1].split(")")[0])
    lhs = comp.symbols.get(ops[0]) if ops else None
    cm = _LHS_CDIMS.search(ins.line)
    k = 1
    if lhs and cm and cm.group(1):
        ldims = [int(x) for x in lhs[1].split(",") if x]
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(ldims):
                k *= ldims[ci]
    return 2.0 * out * k


def _group_stride(line: str) -> int:
    m = _GROUPS.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        if len(ids) < 2:
            return 1
        return min(abs(b - a) for a, b in zip(ids, ids[1:]))
    m = _GROUPS_IOTA.search(line)
    if m:
        dims = [int(x) for x in m.group(3).split(",")]
        return 256 if dims and dims[0] == 2 else 1
    return 1


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    inner = ins.line.split("(", 1)
    if len(inner) < 2:
        return 0
    args = inner[1].split(")")[0]
    total = 0
    for name in _OPERANDS.findall(args):
        sym = comp.symbols.get(name)
        if sym:
            total += _nbytes(*sym)
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.ici_bytes += mult * other.ici_bytes
        self.dcn_bytes += mult * other.dcn_bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0) + mult * v


_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _comp_cost(comps: dict, name: str, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    c = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        base = op.replace("-start", "")
        if base in _COLLECTIVE_KINDS and not op.endswith("-done"):
            nb = ins.result_bytes * _COLL_MULT[base]
            c.coll[base] = c.coll.get(base, 0) + 1
            if _group_stride(ins.line) >= 256:
                c.dcn_bytes += nb
            else:
                c.ici_bytes += nb
            c.bytes += ins.result_bytes  # HBM side of the transfer
            continue
        if op == "dot":
            c.flops += _dot_flops(comp, ins)
            c.bytes += ins.result_bytes + _operand_bytes(comp, ins)
            continue
        if op == "while":
            body = _CALLS.search(ins.line)
            cond = _COND.search(ins.line)
            trips = _trip_count(comps, cond.group(1)) if cond else 1
            if body:
                c.add(_comp_cost(comps, body.group(1), memo), trips)
            if cond:
                c.add(_comp_cost(comps, cond.group(1), memo), trips)
            continue
        if op in ("fusion", "call", "custom-call", "conditional", "map",
                  "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
            callee = _CALLS.search(ins.line)
            if callee:
                sub = _comp_cost(comps, callee.group(1), memo)
                # fused dots still cost flops; fused BYTES stay in registers
                c.flops += sub.flops
                c.add(Cost(coll=sub.coll, ici_bytes=sub.ici_bytes,
                           dcn_bytes=sub.dcn_bytes))
            if op == "fusion" and callee:
                c.bytes += _fusion_bytes(comp, ins, comps.get(callee.group(1)))
            elif op in ("custom-call", "reduce", "scatter", "sort"):
                c.bytes += ins.result_bytes + _operand_bytes(comp, ins)
            continue
        if op in _SKIP_BYTES:
            continue
        c.bytes += ins.result_bytes + _operand_bytes(comp, ins)
    memo[name] = c
    return c


def _fusion_bytes(comp: Computation, ins: Instr, callee) -> float:
    """HBM traffic of one fusion execution, slice-aware.

    Fusions routinely take a whole scan-carried stash (e.g. the (L, B, S, d)
    saved-activation buffer) as an operand but only read ONE dynamic-slice
    of it; similarly a dynamic-update-slice root writes one slice in place.
    Charging full operand/result sizes overstates traffic by the layer
    count — so per callee parameter we charge the slice actually read, and
    a DUS-rooted fusion is charged the update, with its aliased input
    skipped."""
    if callee is None:
        return ins.result_bytes + _operand_bytes(comp, ins)
    args = ins.line.split("(", 1)[1].split(")")[0]
    operand_names = _OPERANDS.findall(args)

    # map callee parameter index -> bytes actually read
    param_reads: dict[int, int] = {}
    param_of: dict[str, int] = {}
    alias_names: set[str] = set()
    for cins in callee.instrs:
        if cins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", cins.line)
            if m:
                param_of[cins.name] = int(m.group(1))
                param_reads[int(m.group(1))] = _nbytes(cins.dtype, cins.dims)
        elif cins.opcode == "bitcast":
            src = _OPERANDS.findall(cins.line.split("(", 1)[1])[:1]
            if src and src[0] in param_of:  # bitcast of a param: track alias
                param_of[cins.name] = param_of[src[0]]
                alias_names.add(cins.name)
    # params whose ONLY uses are dynamic-slices: charge the slice(s)
    sliced: dict[int, int] = {}
    other_use: set[int] = set()
    for cins in callee.instrs:
        if cins.opcode in ("parameter",):
            continue
        srcs = _OPERANDS.findall(cins.line.split("(", 1)[1].split(")")[0]) if "(" in cins.line else []
        for s in srcs:
            if s in param_of:
                pi = param_of[s]
                if cins.opcode == "dynamic-slice":
                    sliced[pi] = sliced.get(pi, 0) + _nbytes(cins.dtype, cins.dims)
                elif cins.opcode == "bitcast" and cins.name in alias_names:
                    pass
                else:
                    other_use.add(pi)

    # dynamic-update-slice anywhere in the fusion: model it as the in-place
    # slice write it is on TPU (the CPU backend sometimes wraps the whole
    # buffer in converts around the DUS — an artifact we normalize away:
    # the roofline targets the TPU memory system)
    result_bytes = float(ins.result_bytes)
    dus = next((ci for ci in callee.instrs
                if ci.opcode == "dynamic-update-slice"), None)
    big_skip = 0
    if dus is not None and dus.result_bytes >= ins.result_bytes // 2:
        ops = _OPERANDS.findall(dus.line.split("(", 1)[1].split(")")[0])
        upd = callee.symbols.get(ops[1]) if len(ops) > 1 else None
        if upd:
            result_bytes = _nbytes(*upd) * 2.0  # read-modify-write the slice
            big_skip = ins.result_bytes  # skip ONE full-buffer operand (alias)

    total = result_bytes
    for i, name in enumerate(operand_names):
        sym = comp.symbols.get(name)
        if sym is None:
            continue
        full = _nbytes(*sym)
        if big_skip and full == big_skip:
            big_skip = 0  # the aliased input buffer: not real traffic
            continue
        if i in sliced and i not in other_use:
            total += min(sliced[i], full)
        else:
            total += full
    return total


def _entry_name(hlo_text: str, comps: dict) -> str:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                return m.group(1)
            break
    # fall back: the computation named main-ish
    return next((n for n in comps if "main" in n), next(iter(comps)))


def analyze(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    return _comp_cost(comps, _entry_name(hlo_text, comps), {})


# --- peak-live-buffer estimation ---------------------------------------------
#
# The number that decides whether a config FITS a device: walk each
# computation in (topological = textual) order, track which result buffers
# are live (def index -> last-use index), and take the max running sum.
# Estimator contract (DESIGN.md §8):
#   * counted:  parameter buffers (live from entry to last use), every
#     non-aliasing instruction result from its definition to its last use,
#     the root to the end of its computation, and — at while/call/
#     conditional sites — the callee's own peak minus its parameter bytes
#     (the params alias the caller's operand buffers, which are already
#     live at the call site).
#   * aliased away: tuple / get-tuple-element / bitcast define no storage;
#     their uses extend the liveness of the aliased source buffer.
#   * fusion bodies contribute nothing (fused intermediates live in
#     registers); the fusion's operands/result are caller-side buffers.
#   * NOT modeled: input-output aliasing (donation) — the estimate is the
#     un-donated upper bound — and backend scratch allocations.

_ALIAS_OPS = {"tuple", "get-tuple-element", "bitcast"}
_BODY_CALLS = {"while", "call", "conditional"}

_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_NAMED_CALLEES = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)"
    r"=%?([\w.\-]+)"
)


def _callee_names(line: str) -> list[str]:
    names = [m.group(1) for m in _NAMED_CALLEES.finditer(line)]
    bm = _BRANCHES.search(line)
    if bm:
        names.extend(_OPERANDS.findall(bm.group(1)))
    return names


def _operand_names(ins: Instr) -> list[str]:
    """%names inside the instruction's CALL parens.  The call paren is the
    one right after the opcode — for tuple-result instructions the first
    ``(`` in the line belongs to the result *type* — and the operand list
    may itself contain tuple-typed (parenthesized) operands, so scan to
    the balancing close instead of the first ``)``."""
    m = re.search(rf"\b{re.escape(ins.opcode)}\(", ins.line)
    if not m:
        return []
    start = m.end()
    depth = 1
    for i in range(start, len(ins.line)):
        ch = ins.line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERANDS.findall(ins.line[start:i])
    return _OPERANDS.findall(ins.line[start:])


@dataclasses.dataclass
class LivenessEstimate:
    peak_bytes: float = 0.0
    param_bytes: float = 0.0


def _comp_peak(comps: dict, name: str, memo: dict) -> LivenessEstimate:
    if name in memo:
        return memo[name]
    memo[name] = LivenessEstimate()  # cycle guard
    comp = comps.get(name)
    if comp is None or not comp.instrs:
        return memo[name]
    n = len(comp.instrs)

    alias_src = {
        ins.name: ops[0]
        for ins in comp.instrs
        if ins.opcode in _ALIAS_OPS and (ops := _operand_names(ins))
    }

    def root_of(nm: str) -> str:
        seen = set()
        while nm in alias_src and nm not in seen:
            seen.add(nm)
            nm = alias_src[nm]
        return nm

    size: dict[str, float] = {}  # root buffer -> bytes
    def_at: dict[str, int] = {}
    last_use: dict[str, int] = {}
    callee_extra = [0.0] * n
    param_bytes = 0.0
    for i, ins in enumerate(comp.instrs):
        rt = root_of(ins.name)
        if ins.opcode in _ALIAS_OPS:
            last_use[rt] = max(last_use.get(rt, i), i)
        else:
            size[rt] = float(ins.result_bytes)
            def_at.setdefault(rt, i)
        if ins.opcode == "parameter":
            param_bytes += float(ins.result_bytes)
            def_at[rt] = 0
        for op_name in _operand_names(ins):
            r = root_of(op_name)
            last_use[r] = max(last_use.get(r, 0), i)
        if ins.opcode in _BODY_CALLS:
            for callee in _callee_names(ins.line):
                sub = _comp_peak(comps, callee, memo)
                callee_extra[i] = max(
                    callee_extra[i],
                    max(0.0, sub.peak_bytes - sub.param_bytes),
                )
    # the root value (and, for a root tuple, everything it aliases) lives
    # to the end of the computation
    root_ins = comp.root or comp.instrs[-1]
    for op_name in _operand_names(root_ins):
        last_use[root_of(op_name)] = n
    last_use[root_of(root_ins.name)] = n

    add_at: dict[str, list] = {}
    rm_after: dict[str, list] = {}
    for rt, i in def_at.items():
        if rt in size:
            add_at.setdefault(i, []).append(size[rt])
            end = min(last_use.get(rt, i), n - 1)
            rm_after.setdefault(end, []).append(size[rt])
    peak = live = 0.0
    for i in range(n):
        live += sum(add_at.get(i, ()))
        peak = max(peak, live + callee_extra[i])
        live -= sum(rm_after.get(i, ()))
    memo[name] = LivenessEstimate(peak_bytes=peak, param_bytes=param_bytes)
    return memo[name]


def liveness(hlo_text: str) -> LivenessEstimate:
    """Peak-live-buffer estimate of the module's entry computation (and its
    entry parameter bytes) — see the contract comment above."""
    comps = parse_module(hlo_text)
    return _comp_peak(comps, _entry_name(hlo_text, comps), {})
