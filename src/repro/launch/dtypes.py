"""The single HLO dtype-size table shared by every HLO-text analysis.

``hlo_cost.py`` and ``roofline.py`` each used to carry a private copy and
the copies diverged (one spelled ``f8e4m3fn``, the other ``f8e4m3`` — so
one of them silently sized fp8 buffers as the 4-byte fallback).  This
module is now the ONE place a dtype's byte width lives; both spellings
are present because XLA has used both across versions.

``JNP_TO_HLO`` maps the ``str(aval.dtype)`` names rules see on traced
programs to the short HLO names the compiled text uses, so analyses that
correlate jaxpr inputs with HLO entry parameters (``NoReplicatedParam``)
share the same vocabulary.
"""
from __future__ import annotations

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

# str(jnp dtype) -> HLO short name (the subset this repo's programs use)
JNP_TO_HLO = {
    "float64": "f64", "float32": "f32", "float16": "f16",
    "bfloat16": "bf16",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
    "int64": "s64", "uint64": "u64", "int32": "s32", "uint32": "u32",
    "int16": "s16", "uint16": "u16", "int8": "s8", "uint8": "u8",
    "bool": "pred", "complex64": "c64", "complex128": "c128",
}


def shape_bytes(dtype: str, dims: str) -> int:
    """Byte size of one ``dtype[dims]`` HLO shape (``dims`` the raw
    comma-joined digit string, e.g. ``"128,512"``; ``""`` is a scalar).
    Unknown dtypes fall back to 4 bytes — both former copies did, and a
    wrong-but-nonzero size keeps ratios sane while a KeyError would kill
    the whole analysis over one exotic buffer."""
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)
