"""Production mesh construction and the canonical axis names.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax
init, smoke tests must keep seeing 1 device.

``DATA_AXIS`` / ``MODEL_AXIS`` are the ONE definition of the mesh axis
names: every shard_map / PartitionSpec call site routes through them (or
through ``batch_axes``/``model_axis``) instead of ad-hoc string
literals, so the audit's source rules can grep one symbol.
"""
from __future__ import annotations

import jax

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod \
        else (DATA_AXIS, MODEL_AXIS)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small (data, model) mesh over however many (CPU) devices exist —
    tests/examples.  ``model`` is honoured exactly (the slab shard count
    must divide k); ``data`` shrinks to fit the device count."""
    n = len(jax.devices())
    if model > n:
        raise ValueError(f"model={model} exceeds device count {n}")
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), (DATA_AXIS, MODEL_AXIS))


def batch_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod axis included when present).

    The model axis is deliberately excluded: LM layers treat "model" in
    their batch axes as the FSDP signal.  DLRM's sharded step, which
    spreads the batch over ALL devices, uses ``all_batch_axes``."""
    names = mesh.axis_names
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in names)


def all_batch_axes(mesh) -> tuple[str, ...]:
    """Batch axes spanning EVERY device — the DLRM sharded-step layout:
    the batch dim is sharded over (data × model) so each device runs
    MLPs on a distinct slice while the supertable stays model-sharded."""
    axes = batch_axes(mesh)
    if model_axis(mesh) is not None:
        axes = axes + (MODEL_AXIS,)
    return axes


def model_axis(mesh) -> str | None:
    """The model-parallel axis name, or None when the mesh has no
    nontrivial model dimension (1-device / pure-data-parallel)."""
    names = mesh.axis_names
    if MODEL_AXIS in names and mesh.shape.get(MODEL_AXIS, 1) > 1:
        return MODEL_AXIS
    return None


def ptr_partition_spec(c: int, d1: int, n_shards: int, axis: str = MODEL_AXIS):
    """At-rest layout for a (c, d1) CCE pointer table over ``n_shards``.

    Prefer id-sharding (dim 1 — matches the transition kernels' compute
    layout, so ``cluster_sharded``/``remap_moments_sharded`` consume it
    reshard-free); jax rejects uneven shardings, so ragged vocabs
    (Criteo's 10_131_227 is odd) fall back to column-sharding (dim 0 —
    one reshard all-to-all at transition time), and replicate only when
    nothing divides.  The ONE definition of this policy: the trainer's
    state specs and the audit harness both route through it."""
    from jax.sharding import PartitionSpec as P

    if n_shards <= 1:
        return P()
    if d1 % n_shards == 0:
        return P(None, axis)
    if c % n_shards == 0:
        return P(axis, None)
    return P()
