"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax
init, smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod axis included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
