"""Roofline-term extraction from a compiled (dry-run) artifact.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = sum over collective ops of (bytes moved per chip) / link_bw
                 (ICI and inter-pod DCN classified separately by inspecting
                  replica_groups strides)

Sources: ``compiled.cost_analysis()`` for flops/bytes (already per-device
for an SPMD-partitioned module); the compiled HLO text for collectives —
cost_analysis does not count them.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, ~25 GB/s/chip DCN for the pod axis.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.dtypes import shape_bytes

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link
DCN_BW = 25e9  # bytes/s/chip (inter-pod)

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# matches e.g.  %all-reduce.5 = f32[128,512]{1,0} all-reduce(f32[128,512]{1,0} %x), replica_groups={{0,1},{2,3}}
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _tuple_bytes(inner: str) -> int:
    return sum(shape_bytes(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(inner))


def _group_stride(line: str) -> int:
    """Smallest stride between consecutive members of the first replica
    group — 256+ means the collective crosses the pod boundary (DCN)."""
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        if len(ids) < 2:
            return 1
        return min(abs(b - a) for a, b in zip(ids, ids[1:]))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [G,S]<=[dims...] — group members stride by the product
        # of trailing dims after the split point; conservative: parse dims
        dims = [int(x) for x in m.group(3).split(",")]
        gsize = int(m.group(2))
        # members of one group are adjacent in the innermost reshaped dim
        stride = 1
        prod = 1
        for d in reversed(dims):
            if prod >= gsize:
                break
            prod *= d
            stride = 1 if prod <= gsize else stride
        # innermost-contiguous groups -> stride 1; otherwise full analysis
        # would need the permutation; assume intra-pod unless dims[0]==2
        return 256 if dims and dims[0] == 2 and gsize % 2 == 0 and prod > 256 else 1
    m = _SRC_TGT_RE.search(line)
    if m:
        return abs(int(m.group(2)) - int(m.group(1)))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    ici_bytes: float  # per-chip bytes over ICI links
    dcn_bytes: float  # per-chip bytes over the pod interconnect

    def as_dict(self):
        return {
            "counts": self.counts,
            "ici_bytes": self.ici_bytes,
            "dcn_bytes": self.dcn_bytes,
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-chip traffic estimate per collective op.

    Ring-algorithm accounting on the RESULT shape R with n participants:
      all-gather       : each chip receives R·(n-1)/n  ~= R
      all-reduce       : reduce-scatter + all-gather    ~= 2·R
      reduce-scatter   : receives R (result is already the shard)
                          ... operand O = n·R, traffic ~= O/n·(n-1) ~= O
      all-to-all       : R (re-distribution of the full block)
      collective-permute: R (one send + one recv)
    """
    counts: dict[str, int] = {}
    ici = 0.0
    dcn = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        inner, dtype, dims, op = m.groups()
        nbytes = _tuple_bytes(inner) if inner is not None else shape_bytes(dtype, dims)
        mult = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}[op]
        counts[op] = counts.get(op, 0) + 1
        if _group_stride(line) >= 256:
            dcn += mult * nbytes
        else:
            ici += mult * nbytes
    return CollectiveStats(counts=counts, ici_bytes=ici, dcn_bytes=dcn)


def roofline_terms(hlo: "Cost", *, n_chips: int, model_flops: float,
                   compute_dtype_bytes: int = 2) -> dict:
    """The three roofline terms + utilization ratios.

    ``hlo`` = hlo_cost.analyze(compiled.as_text()) — trip-count-corrected
    per-device flops / bytes / collective traffic.  ``model_flops`` =
    global useful flops per call (6·N·tokens train, 2·N·tokens inference).

    roofline_fraction = (useful work at peak) / (modelled step time), i.e.
    an MFU bound for compute-dominated cells and a "how far from the
    achievable roofline" measure when memory or collectives dominate.
    """
    hlo_flops = float(hlo.flops)
    hlo_bytes = float(hlo.bytes)
    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_ici = hlo.ici_bytes / ICI_BW
    t_dcn = hlo.dcn_bytes / DCN_BW
    t_coll = t_ici + t_dcn
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll,
             "collective_ici": t_ici, "collective_dcn": t_dcn}
    dominant = max(("compute", "memory", "collective"), key=lambda k: terms[k])
    t_step = max(t_compute, t_memory, t_coll)
    mfu = (model_flops / n_chips / PEAK_FLOPS) / t_step if t_step else 0.0
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / n_chips / max(hlo_flops, 1.0),
        "roofline_fraction": mfu,
    }


def model_flops_n(n_active: int, shape) -> float:
    """Useful (paper-counted) FLOPs per step: 6·N·tokens for train,
    2·N·tokens for inference (decode: tokens = batch)."""
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq
    return 2.0 * n_active * shape.global_batch  # decode: one token / sequence
