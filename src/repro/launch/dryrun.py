import os

# MUST run before any other import (jax locks device count on first init).
# DRYRUN_DEVICES exists for memory-constrained debugging only; the
# deliverable meshes need the full 512.
_N_DEV = os.environ.get("DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms from the compiled
artifact.  No allocation, no execution — ShapeDtypeStruct in, HLO out.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2x16x16
Results append to benchmarks/results/dryrun.json (one record per cell,
re-runs overwrite the cell).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import compat, configs  # noqa: E402
from repro.launch import hlo_cost, roofline, shapes as shp, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results")


def n_params_of(state_shape) -> int:
    import numpy as np

    return int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(state_shape.params)))


def active_params(cfg, total: int) -> int:
    if cfg.family != "moe":
        return total
    expert = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    return total - expert + expert * cfg.top_k // cfg.n_experts


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = configs.get(arch, **(overrides or {}))
    shape = shp.SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "overrides": {k: str(v) for k, v in (overrides or {}).items()}}
    if not shp.applicable(cfg, shape_name):
        rec["status"] = "n/a (full attention at 500k — DESIGN.md §long_500k)"
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            jitted, (state_shape, batch_sds), _ = steps.build_train_step(
                cfg, mesh, shape_name
            )
            lowered = jitted.lower(state_shape, batch_sds)
            n_total = n_params_of(state_shape)
        else:
            jitted, args = steps.build_serve_step(cfg, mesh, shape_name)
            lowered = jitted.lower(*args)
            import numpy as np

            n_total = int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(args[0])))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = hlo_cost.analyze(compiled.as_text())
        n_act = active_params(cfg, n_total)
        mf = roofline.model_flops_n(n_act, shape)
        terms = roofline.roofline_terms(hlo, n_chips=n_chips, model_flops=mf)

    rec.update(
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        n_params=n_total,
        n_active_params=n_act,
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        collectives={"counts": hlo.coll, "ici_bytes": hlo.ici_bytes,
                     "dcn_bytes": hlo.dcn_bytes},
        xla_cost_analysis={"flops": float(cost.get("flops", 0)),
                           "bytes": float(cost.get("bytes accessed", 0))},
        roofline=terms,
    )
    return rec


def save(rec: dict, path: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    if rec.get("overrides"):
        key += "|" + ",".join(f"{k}={v}" for k, v in sorted(rec["overrides"].items()))
    data[key] = rec
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(
        os.path.join(RESULTS, "dryrun.json")))
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides k=v (ints auto-cast)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    shape_names = [args.shape] if args.shape else list(shp.SHAPES)
    for arch in archs:
        for sn in shape_names:
            t0 = time.time()
            try:
                rec = run_cell(arch, sn, multi_pod=args.multi_pod,
                               overrides=overrides)
            except Exception as e:
                rec = {"arch": arch, "shape": sn,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "status": f"FAIL: {type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:],
                       "overrides": {k: str(v) for k, v in overrides.items()}}
            save(rec, args.out)
            dom = rec.get("roofline", {}).get("dominant", "-")
            frac = rec.get("roofline", {}).get("roofline_fraction", 0)
            print(f"[{time.time()-t0:7.1f}s] {arch:22s} {sn:12s} "
                  f"{rec['status'][:60]:60s} dom={dom} frac={frac:.3f}",
                  flush=True)


if __name__ == "__main__":
    main()
