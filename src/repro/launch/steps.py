"""Builds the jitted step functions with full sharding for a mesh —
shared by the dry-run, the benchmarks, and the real launchers.

Everything here is mesh-parametric: pass the 16x16 production mesh, the
2x16x16 multi-pod mesh, or a 1x1 CPU mesh and the same code lowers.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import shapes as shp
from repro.launch.mesh import (
    MODEL_AXIS,
    all_batch_axes,
    batch_axes as mesh_batch_axes,
    model_axis,
    ptr_partition_spec,
)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw, cosine_schedule
from repro.optim.optimizers import moment_specs
from repro.train.loop import TrainState, make_train_step, split_buffers


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_state(cfg: ModelConfig, optimizer):
    """eval_shape the full TrainState — zero allocation."""
    def mk():
        params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
        dyn, _ = split_buffers(buffers)
        return TrainState(
            params=params, opt=optimizer.init(params), ebuf=dyn,
            step=jnp.zeros((), jnp.int32), err=None,
        )

    return jax.eval_shape(mk)


def static_buffers_for(cfg: ModelConfig):
    """The static (hash-coefficient) halves of the buffers — pure numpy,
    never allocates tables or touches the mesh."""
    buffers = lm.init_buffers(cfg)
    _, static = split_buffers(buffers)
    return static


def state_specs(cfg: ModelConfig, state_shape, *, dp="data", tp="model", dp_size=16):
    pspecs = lm.param_specs(cfg, dp=dp, tp=tp)
    ospecs = moment_specs("adamw", pspecs, state_shape.params, dp_axis=dp, dp_size=dp_size)
    ebuf_specs = jax.tree.map(lambda _: P(), state_shape.ebuf)
    return TrainState(
        params=pspecs, opt=ospecs, ebuf=ebuf_specs, step=P(), err=None,
    )


def build_train_step(cfg: ModelConfig, mesh, shape_name: str = "train_4k"):
    """Returns (jitted_step, (state_sds, batch_sds)) ready to .lower()."""
    baxes = mesh_batch_axes(mesh)
    if cfg.parallelism == "fsdp":
        baxes = baxes + ("model",)  # batch over every axis; weights FSDP
    n_dp = 1
    for a in baxes:
        n_dp *= mesh.shape[a]
    shape = shp.SHAPES[shape_name]
    accum, micro = shp.microbatch(cfg, shape, n_dp)
    optimizer = adamw(weight_decay=0.1)
    lr_fn = cosine_schedule(3e-4, 100, 10_000)

    state_shape = abstract_state(cfg, optimizer)
    sspecs = state_specs(cfg, state_shape, dp="data", tp="model", dp_size=mesh.shape.get("data", 1))
    static_buf = static_buffers_for(cfg)

    def loss_fn(params, buffers, mb):
        return lm.next_token_loss(params, buffers, cfg, mb, batch_axes=baxes)

    grad_specs = None
    if cfg.zero2_grads:
        from repro.optim.optimizers import zero1_specs

        grad_specs = zero1_specs(
            lm.param_specs(cfg, dp="data", tp="model"), state_shape.params,
            dp_axis="data", dp_size=mesh.shape.get("data", 1),
        )
    step_fn = make_train_step(
        loss_fn, optimizer, lr_fn, static_buf, accum=accum, clip_norm=1.0,
        grad_specs=grad_specs,
    )

    batch_sds = shp.train_input_specs(cfg, shape, n_dp)
    bspec = jax.tree.map(lambda _: P(None, baxes), batch_sds)

    state_shardings = _ns(mesh, sspecs)
    batch_shardings = _ns(mesh, bspec)
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )
    return jitted, (state_shape, batch_sds), (state_shardings, batch_shardings)


# --- DLRM: the model-parallel supertable step (ROADMAP item 1) ---------------


def _replicated(tree):
    return jax.tree.map(lambda _: P(), tree)


def dlrm_abstract_state(cfg, optimizer):
    """eval_shape the DLRM TrainState — zero allocation."""
    from repro.models import dlrm

    def mk():
        params, buffers = dlrm.init(jax.random.PRNGKey(0), cfg)
        dyn, _ = split_buffers(buffers)
        return TrainState(
            params=params, opt=optimizer.init(params), ebuf=dyn,
            step=jnp.zeros((), jnp.int32), err=None,
        )

    return jax.eval_shape(mk)


def dlrm_state_specs(cfg, state_shape, *, model=MODEL_AXIS, n_shards=None):
    """PartitionSpec tree for the model-parallel DLRM TrainState.

    The sharding layout (DESIGN.md §9): every universal supertable
    ``(C, T, k_pad, dsub)`` splits its CODEBOOK axis over ``model``
    (``cfg.emb_k_multiple`` makes k_pad divide evenly), the adjacent
    ``ptr`` pointer buffers split per ``mesh.ptr_partition_spec`` (id
    axis when the vocab divides, column axis for ragged vocabs), and the
    optimizer moments mirror their params exactly — so no replica holds
    the full slab, the full moments, or the full pointer table.  MLPs,
    the tiny hash seeds (``hs``), the ``epoch`` counters, and the step
    counter stay replicated (all far below the audit's replication
    threshold).  ``n_shards`` is the model-axis size the specs will run
    under (needed for the divisibility choice; defaults to assuming the
    id axis divides)."""
    coll = cfg.collection
    univ = set(coll.univ_groups)
    slab = P(None, None, model, None)

    emb_p = [
        {"tables": slab} if g in univ
        else _replicated(state_shape.params["emb"][g])
        for g in range(len(coll.groups))
    ]
    pspecs = {
        k: (emb_p if k == "emb" else _replicated(v))
        for k, v in state_shape.params.items()
    }

    def feat_spec(fb):
        if not isinstance(fb, dict):
            return _replicated(fb)
        return {
            k: (ptr_partition_spec(*v.shape, n_shards, model)
                if k == "ptr" and v is not None and n_shards
                else P(None, model) if k == "ptr" and v is not None
                else _replicated(v))
            for k, v in fb.items()
        }

    ebuf_emb = [
        [feat_spec(fb) for fb in state_shape.ebuf["emb"][g]] if g in univ
        else _replicated(state_shape.ebuf["emb"][g])
        for g in range(len(coll.groups))
    ]
    ebuf_specs = {
        k: (ebuf_emb if k == "emb" else _replicated(v))
        for k, v in state_shape.ebuf.items()
    }
    # moments mirror params (sgd-momentum m / adam m,v); scalar slots
    # (adam's t) replicate
    ospecs = {
        slot: (pspecs if slot in ("m", "v") else P())
        for slot in state_shape.opt
    }
    return TrainState(
        params=pspecs, opt=ospecs, ebuf=ebuf_specs, step=P(), err=None,
    )


def dlrm_batch_struct(cfg, batch_size: int, *, accum: int = 1,
                      n_shards: int = 1, with_sparse: bool = False):
    """ShapeDtypeStructs of the sharded trainer's batch: host-translated
    (pre-bucketed when ``n_shards`` > 1) rows + dense + label, leaves
    shaped (accum, micro, ...).  ``with_sparse`` keeps the raw ids in the
    device batch (the host frequency tracker reads them from the SAME
    batch dict; XLA prunes the unused device copy)."""
    coll = cfg.collection
    micro = batch_size // accum
    rows_shape = (micro, coll.rows_n_cols, coll.rows_n_tables)
    if n_shards > 1:
        rows_shape = (micro, n_shards) + rows_shape[1:]
    batch = {
        "dense": jax.ShapeDtypeStruct((micro, cfg.n_dense), jnp.float32),
        "label": jax.ShapeDtypeStruct((micro,), jnp.float32),
        "rows": jax.ShapeDtypeStruct(rows_shape, jnp.int32),
    }
    if with_sparse:
        batch["sparse"] = jax.ShapeDtypeStruct(
            (micro, cfg.n_sparse), jnp.int32
        )
    return {
        k: jax.ShapeDtypeStruct((accum, *v.shape), v.dtype)
        for k, v in batch.items()
    }


def build_dlrm_train_step(cfg, mesh, *, batch_size: int, accum: int = 1,
                          optimizer=None, lr_fn=None, static_buffers=None,
                          with_sparse: bool = False, donate: bool = True,
                          telemetry=None):
    """The donated model-parallel DLRM step for a (data, model) mesh.

    Returns ``(jitted_step, (state_shape, batch_struct),
    (state_shardings, batch_shardings))``: state enters AND leaves on the
    sharded layout (slab + moments k-sharded, ptr id-sharded — see
    ``dlrm_state_specs``), batch leaves shard their batch dim over every
    device (``all_batch_axes``), and the supertable lookup routes ids by
    all-to-all inside the step (``EmbeddingCollection._univ_lookup_sharded``).
    On a mesh without a nontrivial model axis this degrades to the plain
    data-parallel step — same code path, no sharded lookup.

    ``telemetry`` (``repro.obs.TelemetryConfig``) adds the in-step health
    metrics to the returned metrics dict — including the per-shard
    routing-bucket occupancy read off the pre-bucketed rows, the
    all-to-all skew signal.  Same program, same launch count
    (``train_step_sharded_telemetry`` audit spec)."""
    from repro.models import dlrm
    from repro.optim import sgd

    if optimizer is None:
        optimizer = sgd(momentum=0.9)
    if lr_fn is None:
        def lr_fn(step):
            return jnp.float32(1e-3)
    if static_buffers is None:
        _, buffers = jax.eval_shape(
            lambda: dlrm.init(jax.random.PRNGKey(0), cfg)
        )
        _, static_buffers = split_buffers(buffers)
    m_ax = model_axis(mesh)
    baxes = all_batch_axes(mesh)
    n_shards = mesh.shape.get(MODEL_AXIS, 1)

    def loss_fn(p, b, mb):
        return dlrm.bce_loss(
            p, b, cfg, mb, mesh=mesh if m_ax else None,
            model_axis=m_ax, batch_axes=baxes if m_ax else None,
        ), {}

    step_fn = make_train_step(
        loss_fn, optimizer, lr_fn, static_buffers, accum=accum,
        telemetry=telemetry,
    )
    state_shape = dlrm_abstract_state(cfg, optimizer)
    sspecs = dlrm_state_specs(cfg, state_shape, n_shards=n_shards)
    batch_struct = dlrm_batch_struct(
        cfg, batch_size, accum=accum, n_shards=n_shards,
        with_sparse=with_sparse,
    )
    bspec = jax.tree.map(
        lambda s: P(None, baxes, *([None] * (s.ndim - 2))), batch_struct
    )
    state_shardings = _ns(mesh, sspecs)
    batch_shardings = _ns(mesh, bspec)
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, (state_shape, batch_struct), (state_shardings, batch_shardings)


def _maybe_dp(n: int, baxes, n_dp: int):
    """Batch-dim spec: shard over dp axes only when divisible."""
    return baxes if n % n_dp == 0 else None


def build_serve_step(cfg: ModelConfig, mesh, shape_name: str):
    """decode or prefill step, jitted with cache donation."""
    baxes = mesh_batch_axes(mesh)
    n_dp = 1
    for a in baxes:
        n_dp *= mesh.shape[a]
    shape = shp.SHAPES[shape_name]
    static_buf = static_buffers_for(cfg)
    pspecs = lm.param_specs(cfg, dp="data", tp="model")
    bdim = _maybe_dp(shape.global_batch, baxes, n_dp)
    cspecs = lm.cache_specs(cfg, batch_axes=bdim, tp="model")

    def mk():
        params, buffers = lm.init(jax.random.PRNGKey(0), cfg)
        dyn, _ = split_buffers(buffers)  # split INSIDE the trace: ints stay static
        return params, dyn

    params_shape, dyn_shape = jax.eval_shape(mk)
    ebuf_specs = jax.tree.map(lambda _: P(), dyn_shape)

    from repro.train.loop import merge_buffers

    if shape.kind == "decode":
        def step(params, ebuf, tokens, pos, cache):
            buffers = merge_buffers(ebuf, static_buf)
            return lm.decode_step(params, buffers, cfg, tokens, pos, cache,
                                  batch_axes=bdim or ())

        specs = shp.decode_input_specs(cfg, shape)
        tok_spec = P(bdim) if specs["tokens"].ndim == 1 else P(bdim, None)
        in_shardings = (
            _ns(mesh, pspecs), _ns(mesh, ebuf_specs),
            _ns(mesh, tok_spec), _ns(mesh, P(bdim)), _ns(mesh, cspecs),
        )
        out_shardings = (None, _ns(mesh, cspecs))
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings, donate_argnums=(4,))
        args = (params_shape, dyn_shape, specs["tokens"], specs["pos"], specs["cache"])
        return jitted, args

    def step(params, ebuf, tokens, cache):
        buffers = merge_buffers(ebuf, static_buf)
        return lm.prefill(params, buffers, cfg, tokens, cache,
                          batch_axes=bdim or ())

    specs = shp.prefill_input_specs(cfg, shape)
    tok_spec = P(bdim, None) if specs["tokens"].ndim == 2 else P(bdim, None, None)
    in_shardings = (
        _ns(mesh, pspecs), _ns(mesh, ebuf_specs),
        _ns(mesh, tok_spec), _ns(mesh, cspecs),
    )
    out_shardings = (None, _ns(mesh, cspecs))
    jitted = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(3,))
    args = (params_shape, dyn_shape, specs["tokens"], specs["cache"])
    return jitted, args


def build_step(cfg: ModelConfig, mesh, shape_name: str):
    shape = shp.SHAPES[shape_name]
    if shape.kind == "train":
        jitted, (state_shape, batch_sds), _ = build_train_step(cfg, mesh, shape_name)
        return jitted, (state_shape, batch_sds)
    return build_serve_step(cfg, mesh, shape_name)
