"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 50 --emb cce --ckpt-dir /tmp/ckpt

``--reduced`` runs the CPU-sized family variant (what the smoke tests use);
without it the full config lowers for whatever devices exist (on a real pod
this is the entry point — same code path the dry-run proves out).
DLRM (the paper's model): ``--arch dlrm``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import clickstream_batches, lm_token_batches, ClickstreamConfig
from repro.models import dlrm, lm
from repro.obs import RunLog, TelemetryConfig
from repro.obs.runlog import default_manifest
from repro.optim import adamw, sgd, cosine_schedule
from repro.optim.remap import remap_opt_state
from repro.train.freq import IdFrequencyTracker
from repro.train.transition import transition_table
from repro.train.loop import (
    FailureInjector,
    StragglerMonitor,
    Trainer,
    init_state,
    make_train_step,
    split_buffers,
)


def _obs_kit(args, config_name: str):
    """(telemetry, trainer obs kwargs) for ``--obs PATH``: in-step health
    metrics + a structured run log; ``--profile-steps A B`` additionally
    opens a profiler window (DESIGN.md §10)."""
    telemetry, kw = None, {}
    # getattr throughout: tests drive the builders with hand-built
    # Namespaces that predate the obs flags
    obs = getattr(args, "obs", None)
    if obs:
        telemetry = TelemetryConfig()
        kw["runlog"] = RunLog(
            obs, manifest=default_manifest(
                config_name, mesh={"data": getattr(args, "data_shards", 1),
                                   "model": getattr(args, "model_shards", 1)},
            ),
        )
    profile_steps = getattr(args, "profile_steps", None)
    if profile_steps:
        kw["profile_steps"] = tuple(profile_steps)
        kw["profile_dir"] = getattr(args, "profile_dir", "profile")
    return telemetry, kw


def build_lm_trainer(cfg, args):
    key = jax.random.PRNGKey(args.seed)
    params, buffers = lm.init(key, cfg)
    dyn, static = split_buffers(buffers)
    optimizer = adamw(weight_decay=0.1)
    lr_fn = cosine_schedule(args.lr, args.warmup, args.steps)

    def loss_fn(p, b, mb):
        return lm.next_token_loss(p, b, cfg, mb, batch_axes=None)

    telemetry, obs_kw = _obs_kit(args, cfg.name)
    step = make_train_step(loss_fn, optimizer, lr_fn, static, accum=args.accum,
                           telemetry=telemetry)
    state = init_state(params, optimizer, dyn)
    data = lm_token_batches(
        cfg.vocab, args.batch, args.seq, seed=args.seed,
        n_codebooks=cfg.n_codebooks,
    )

    cluster_fn = None
    tracker = None
    if cfg.emb_method == "cce":
        emb = lm.make_emb(cfg)
        # token histogram feeds the transition's k-means sample; for
        # codebook models the ids are offset per codebook inside embed(),
        # so plain token counts don't map to table rows — fall back to
        # uniform sampling there (ROADMAP follow-on)
        if not cfg.n_codebooks:
            tracker = IdFrequencyTracker((emb.d1,), key="tokens")

        def cluster_fn(key, params, buffers, opt):
            ep, eb, update = transition_table(
                emb, key, params["emb"], buffers["emb"],
                counts=tracker.counts[0] if tracker is not None else None,
                chunk_size=1 << 18,  # LM vocabs can be huge: stream the pass
            )

            def upd(moments, _slot):
                return dict(moments, emb=update(moments["emb"]))

            return (dict(params, emb=ep), dict(buffers, emb=eb),
                    remap_opt_state(opt, upd))

    return Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        cluster_fn=cluster_fn, cluster_every=args.cluster_every,
        id_tracker=tracker, accum=args.accum,
        failures=FailureInjector(tuple(args.fail_at)),
        monitor=StragglerMonitor(),
        seed=args.seed,
        **obs_kw,
    )


def build_dlrm_sharded_trainer(cfg, args, *, model: int, data_shards: int = 1):
    """The model-parallel DLRM trainer (ROADMAP item 1): supertable +
    optimizer moments codebook-sharded over the model mesh axis, ptr
    id-sharded the same way, host-translated rows pre-bucketed per shard,
    and the clustering transition running its O(d1) passes sharded over
    the same axis — no replica ever holds the full slab, full moments, or
    full pointer table (asserted by the ``dlrm_criteo_sharded`` audit's
    ``no-replicated-param`` rule at error severity)."""
    from repro.data.translate import HostTranslator, translate_batches
    from repro.launch.mesh import MODEL_AXIS, make_host_mesh
    from repro.launch.steps import build_dlrm_train_step

    mesh = make_host_mesh(data=data_shards, model=model)
    key = jax.random.PRNGKey(args.seed)
    params, buffers = dlrm.init(key, cfg)
    dyn, static = split_buffers(buffers)
    optimizer = sgd(momentum=args.momentum)

    def lr_fn(step):
        return jnp.float32(args.lr)

    track = args.emb == "cce"
    telemetry, obs_kw = _obs_kit(args, "dlrm_sharded")
    step, _, (state_shardings, _) = build_dlrm_train_step(
        cfg, mesh, batch_size=args.batch, accum=args.accum,
        optimizer=optimizer, lr_fn=lr_fn, static_buffers=static,
        with_sparse=track,  # the host tracker reads raw ids off the batch
        telemetry=telemetry,
    )
    state = jax.tree.map(
        lambda x, s: jax.device_put(x, s),
        init_state(params, optimizer, dyn), state_shardings,
    )
    translator = HostTranslator(cfg.collection, buffers["emb"], n_shards=model)
    data = translate_batches(
        clickstream_batches(
            ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=args.seed),
            args.batch,
        ),
        translator,
    )
    tracker = IdFrequencyTracker(cfg.vocab_sizes) if track else None

    def cluster_fn(key, params, buffers, opt):
        return dlrm.cluster_tables(
            key, params, buffers, cfg, opt, id_counts=tracker.counts,
            mesh=mesh, shard_axis=MODEL_AXIS,
        )

    return Trainer(
        step, state, static, data,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        cluster_fn=cluster_fn if track else None,
        cluster_every=args.cluster_every, id_tracker=tracker,
        translator=translator, accum=args.accum,
        failures=FailureInjector(tuple(args.fail_at)),
        seed=args.seed,
        migrations=dlrm.checkpoint_migrations(cfg),
        state_shardings=state_shardings,
        **obs_kw,
    )


def build_dlrm_trainer(args):
    from repro.configs import dlrm_criteo

    model = max(1, getattr(args, "model_shards", 1))
    cfg = dlrm_criteo.reduced(
        emb_method=args.emb, cap=args.emb_cap, k_multiple=model,
    )
    if model > 1:
        return build_dlrm_sharded_trainer(
            cfg, args, model=model,
            data_shards=max(1, getattr(args, "data_shards", 1)),
        )
    key = jax.random.PRNGKey(args.seed)
    params, buffers = dlrm.init(key, cfg)
    dyn, static = split_buffers(buffers)
    optimizer = sgd(momentum=args.momentum)  # paper default: plain SGD
    def lr_fn(step):
        return jnp.float32(args.lr)


    def loss_fn(p, b, mb):
        return dlrm.bce_loss(p, b, cfg, mb), {}

    telemetry, obs_kw = _obs_kit(args, "dlrm")
    step = make_train_step(loss_fn, optimizer, lr_fn, static, accum=args.accum,
                           telemetry=telemetry)
    state = init_state(params, optimizer, dyn)
    data = clickstream_batches(
        ClickstreamConfig(vocab_sizes=cfg.vocab_sizes, seed=args.seed), args.batch
    )

    tracker = IdFrequencyTracker(cfg.vocab_sizes) if args.emb == "cce" else None

    def cluster_fn(key, params, buffers, opt):
        return dlrm.cluster_tables(key, params, buffers, cfg, opt,
                                   id_counts=tracker.counts)

    return Trainer(
        jax.jit(step, donate_argnums=(0,)), state, static, data,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        cluster_fn=cluster_fn if args.emb == "cce" else None,
        cluster_every=args.cluster_every, id_tracker=tracker,
        accum=args.accum,
        failures=FailureInjector(tuple(args.fail_at)),
        seed=args.seed,
        # pre-collection (per-feature emb layout) checkpoints restore too
        migrations=dlrm.checkpoint_migrations(cfg),
        **obs_kw,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--warmup", type=int, default=10)
    # model-parallel DLRM: shard the supertable over this many devices
    # (the mesh is (data_shards, model_shards); 1 = the plain 1-device path)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--emb", default="cce")
    ap.add_argument("--emb-cap", type=int, default=512)
    ap.add_argument("--cluster-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    # observability (DESIGN.md §10): --obs writes a structured run log
    # and turns on the in-step telemetry; --profile-steps A B dumps a
    # jax.profiler trace for that step window
    ap.add_argument("--obs", default=None, metavar="RUN.jsonl")
    ap.add_argument("--profile-steps", type=int, nargs=2, default=None)
    ap.add_argument("--profile-dir", default="profile")
    args = ap.parse_args()

    if args.arch == "dlrm":
        trainer = build_dlrm_trainer(args)
    else:
        cfg = configs.get_reduced(args.arch, emb_method=args.emb)
        trainer = build_lm_trainer(cfg, args)

    t0 = time.time()
    hist = trainer.run(args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(f"{args.arch}: {len(hist)} steps in {dt:.1f}s  "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}  "
          f"stragglers={len(trainer.monitor.flagged)}")
    if args.obs:
        trainer.runlog.close()
        print(f"run log: {args.obs}  "
              f"(summarize: python -m repro.obs summarize {args.obs})")


if __name__ == "__main__":
    main()
