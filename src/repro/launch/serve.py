"""Serving launcher: spin up the batched engine on a reduced config and
stream a few requests through it.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    params, buffers = lm.init(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, buffers,
                         max_batch=args.max_batch, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(3, 10)))
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_tokens=args.max_tokens))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{args.arch}: served {len(done)} requests / {toks} tokens "
          f"in {dt:.1f}s ({engine.ticks} ticks, batch {args.max_batch})")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} -> {r.generated}")


if __name__ == "__main__":
    main()
